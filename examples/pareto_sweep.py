"""Workload-aware macro selection: sweep the compiler across macro geometries
and pick the best accelerator configuration for each assigned model
architecture — the paper's system-level story (vision/language/cloud macros
want different PPA corners).

    PYTHONPATH=src python examples/pareto_sweep.py --arch granite-moe-1b-a400m
"""

import argparse

from repro.configs import get_config, list_archs
from repro.core import (MacroSpec, SubcircuitLibrary, accelerator_report,
                        calibrated_tech_for_reference, gemm_inventory,
                        mso_search)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=list_archs())
    ap.add_argument("--n-macros", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    gemms = gemm_inventory(cfg)
    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()

    print(f"workload: {args.arch} — {len(gemms)} GEMM classes, "
          f"{sum(g.macs for g in gemms) / 1e9:.2f} GMAC per token-batch")
    best = None
    for h, w in ((32, 32), (64, 64), (128, 128), (256, 256)):
        spec = MacroSpec(h=h, w=w, mcr=2, int_precisions=(4, 8),
                         fp_precisions=("FP8",), f_mac_hz=800e6,
                         f_wupdate_hz=800e6, vdd=0.9)
        res = mso_search(spec, scl, tech)
        eff = max(res.frontier, key=lambda p: p.tops_per_w_1b["int_lo"])
        rep = accelerator_report(gemms, eff, n_macros=args.n_macros, ib=8,
                                 wb=8)
        s = rep.summary()
        print(f"  {h:3d}x{w:<3d} {eff.design.name():42s} "
              f"tops={s['effective_tops']:7.3f} util={s['avg_util']:.3f} "
              f"energy_uj={s['energy_uj']:10.1f} area={s['area_mm2']:6.1f}mm2")
        score = s["effective_tops"] / max(s["energy_uj"], 1e-9)
        if best is None or score > best[0]:
            best = (score, h, w, eff.design.name(), s)
    _, h, w, name, s = best
    print(f"\nbest macro for {args.arch}: {h}x{w} [{name}] — "
          f"{s['effective_tops']} TOPS @ {s['energy_uj']} uJ/batch")


if __name__ == "__main__":
    main()
