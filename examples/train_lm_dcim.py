"""End-to-end training driver: a DCIM-quantized (QAT) language model trained
with the full substrate — synthetic data pipeline, AdamW, checkpointing with
restart, metrics.

    PYTHONPATH=src python examples/train_lm_dcim.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm_dcim.py --preset 100m --steps 300

``tiny`` (~3M params) runs a few hundred steps in minutes on this CPU
container; ``100m`` is the same driver at ~100M params for real hardware.
Every linear layer runs the paper's DCIM execution semantics (INT8 weights /
INT8 bit-serial activations via straight-through fake-quant), so the loss
curve *is* the QAT curve of a SynDCIM-mapped model.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.models import get_model
from repro.optim.schedules import linear_warmup_cosine
from repro.parallel.logical import split_logical
from repro.parallel.sharding import MESH_RULES
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


def preset(name: str):
    cfg = smoke_config("llama3.2-3b")
    if name == "tiny":
        return cfg.replace(name="tiny-dcim-lm", n_layers=4, d_model=128,
                           n_heads=4, n_kv_heads=2, d_ff=512, vocab=4096,
                           head_dim=32), 16, 128
    if name == "100m":
        return cfg.replace(name="lm-100m-dcim", n_layers=12, d_model=768,
                           n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768,
                           head_dim=64), 32, 512
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg, batch_size, seq_len = preset(args.preset)
    api = get_model(cfg)
    print(f"model {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params, "
          f"DCIM INT{cfg.dcim_a_bits}xINT{cfg.dcim_w_bits} QAT")

    params, _specs = split_logical(api.init_params(jax.random.PRNGKey(0)),
                                   MESH_RULES)
    opt = adamw_init(params)
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                        global_batch=batch_size))
    lr = linear_warmup_cosine(3e-4, warmup=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(api, lr), donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    if args.resume and mgr.latest_step() is not None:
        (params, opt), start = mgr.restore((params, opt))
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        raw = corpus.batch(step)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  [{dt:.1f}s]")
        if (step + 1) % args.save_every == 0:
            mgr.async_save(step + 1, (params, opt))
    mgr.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
