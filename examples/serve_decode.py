"""Serving driver: prefill a batch of prompts, then batched greedy decode
with in-place KV caches — the serve_step the decode_* dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-4b --steps 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_config
from repro.models import get_model
from repro.parallel.logical import split_logical
from repro.parallel.sharding import MESH_RULES
from repro.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    api = get_model(cfg)
    params, _ = split_logical(api.init_params(jax.random.PRNGKey(0)),
                              MESH_RULES)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)))
    frontend = None
    if cfg.frontend is not None:
        frontend = jnp.asarray(rng.normal(size=(
            args.batch, cfg.frontend.n_tokens, cfg.frontend.d_frontend)),
            jnp.float32)

    t0 = time.time()
    out = greedy_generate(api, params, prompts, args.steps, frontend=frontend)
    dt = time.time() - t0
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"prefill {args.prompt_len} tokens + decode {args.steps} steps "
          f"x batch {args.batch}: {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s on CPU)")
    print(f"generated token ids (row 0): {np.asarray(out[0])[:16]} ...")


if __name__ == "__main__":
    main()
