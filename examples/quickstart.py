"""Quickstart: the paper's end-to-end flow — performance spec in, macro out.

    PYTHONPATH=src python examples/quickstart.py

1. Define a MacroSpec (dimensions, precisions, MCR, target frequency, PPA
   preferences) — the compiler input of Fig. 2.
2. Build the calibrated 40nm tech model + Subcircuit Library (PPA LUTs).
3. Run the Multi-Spec-Oriented searcher (Algorithm 1) -> Pareto frontier.
4. Pick a design, print its PPA report, emit RTL, and *functionally verify*
   the synthesized adder tree at gate level.
"""

import numpy as np

from repro.core import (MacroSpec, SubcircuitLibrary,
                        calibrated_tech_for_reference, emit_verilog,
                        mso_search, tree_netlist, verify_tree)


def main():
    spec = MacroSpec(h=64, w=64, mcr=2,
                     int_precisions=(4, 8), fp_precisions=("FP4", "FP8"),
                     f_mac_hz=800e6, f_wupdate_hz=800e6, vdd=0.9)
    print(f"spec: {spec.h}x{spec.w} MCR={spec.mcr} INT{spec.int_precisions} "
          f"FP{spec.fp_precisions} @ {spec.f_mac_hz / 1e6:.0f} MHz, {spec.vdd} V")

    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()
    print(f"subcircuit library: {len(scl)} characterized PPA records")

    res = mso_search(spec, scl, tech)
    print(f"\nMSO search: {res.n_evaluated} designs evaluated, "
          f"{len(res.frontier)} on the Pareto frontier:")
    for p in res.frontier:
        s = p.summary()
        print(f"  {s['design']:45s} fmax={s['fmax_mhz']:7.1f}MHz "
              f"area={s['area_mm2']:.4f}mm2 TOPS/W={s['tops_w_int_lo']:7.1f} "
              f"TOPS/mm2={s['tops_mm2']:5.1f}")

    # user selection: the most energy-efficient design meeting the spec
    chosen = max(res.frontier, key=lambda p: p.tops_per_w_1b["int_lo"])
    print(f"\nchosen: {chosen.design.name()}")
    print("  searcher audit trail:")
    for a in chosen.design.audit:
        print(f"    - {a}")

    rtl = emit_verilog(chosen)
    print(f"\nemitted RTL: {len(rtl.splitlines())} lines "
          f"(module dcim_macro)")

    nl = tree_netlist(chosen.design)
    ops = np.random.default_rng(0).integers(0, 2, size=(nl.n_inputs, 64)) * \
        np.random.default_rng(1).integers(-8, 8, size=(nl.n_inputs, 64))
    ok = verify_tree(nl, ops)
    print(f"gate-level functional verification of synthesized adder tree: "
          f"{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
