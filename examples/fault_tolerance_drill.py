"""Fault-tolerance drill: train with injected failures and verify the
checkpoint/restart path reproduces the failure-free run exactly (deterministic
counter-based data pipeline => exactly-once step semantics).

    PYTHONPATH=src python examples/fault_tolerance_drill.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager, FailureInjector, run_with_restarts
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.models import get_model
from repro.optim.adamw import adamw_init
from repro.optim.schedules import constant_lr
from repro.parallel.logical import split_logical
from repro.parallel.sharding import MESH_RULES
from repro.train.step import make_train_step


def main():
    cfg = smoke_config("llama3.2-3b")
    api = get_model(cfg)
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=8))
    jit_step = jax.jit(make_train_step(api, constant_lr(1e-3)))

    def make_state():
        params, _ = split_logical(api.init_params(jax.random.PRNGKey(0)),
                                  MESH_RULES)
        return {"params": params, "opt": adamw_init(params), "step": 0}

    def step_fn(step, state):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(step).items()}
        params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt, "step": step,
                "loss": float(metrics["loss"])}

    n_steps = 24
    # clean run
    tmp1 = tempfile.mkdtemp()
    clean = run_with_restarts(step_fn, make_state(), n_steps,
                              CheckpointManager(tmp1, keep=2), save_every=6)
    # faulty run: two injected failures
    tmp2 = tempfile.mkdtemp()
    mgr = CheckpointManager(tmp2, keep=2)
    mgr.save(0, make_state())
    faulty = run_with_restarts(step_fn, make_state(), n_steps, mgr,
                               save_every=6,
                               injector=FailureInjector(fail_at=(8, 15)))
    same = abs(clean["loss"] - faulty["loss"]) < 1e-5
    print(f"clean final loss : {clean['loss']:.6f} (restarts={clean['restarts']})")
    print(f"faulty final loss: {faulty['loss']:.6f} (restarts={faulty['restarts']})")
    print(f"exactly-once restart semantics: {'PASS' if same else 'FAIL'}")
    shutil.rmtree(tmp1)
    shutil.rmtree(tmp2)
    assert same


if __name__ == "__main__":
    main()
