"""SDP floorplanner tests (paper §III-D): geometric invariants + DEF/SDP
emission for searched designs."""


import pytest

from repro.core import (SubcircuitLibrary, calibrated_tech_for_reference,
                        mso_search, pareto_experiment_spec, reference_chip_ppa)
from repro.core.layout import emit_def, emit_sdp_script, place


@pytest.fixture(scope="module")
def chip_fp():
    return place(reference_chip_ppa())


class TestFloorplan:
    def test_no_overlaps(self, chip_fp):
        rs = chip_fp.regions
        for i, a in enumerate(rs):
            for b in rs[i + 1:]:
                assert not a.overlaps(b), (a.name, b.name)

    def test_regions_inside_die(self, chip_fp):
        for r in chip_fp.regions:
            assert r.x >= -1e-6 and r.y >= -1e-6
            assert r.x + r.w <= chip_fp.die_w + 1e-6
            assert r.y + r.h <= chip_fp.die_h + 1e-6

    def test_total_area_matches_macro(self, chip_fp):
        ppa = reference_chip_ppa()
        placed = sum(r.area for r in chip_fp.regions)
        assert placed == pytest.approx(ppa.area_um2, rel=0.02)

    def test_die_matches_fig10_footprint(self, chip_fp):
        # 455x246 um fabricated macro: same area, similar aspect
        assert chip_fp.die_w * chip_fp.die_h == pytest.approx(0.112e6, rel=0.02)
        assert 1.2 < chip_fp.die_w / chip_fp.die_h < 2.6

    def test_structure(self, chip_fp):
        names = [r.name for r in chip_fp.regions]
        assert "wl_drivers" in names and "bl_drivers" in names
        assert any(n.startswith("sram_bank") for n in names)
        assert any(n.startswith("adder_strip") for n in names)
        # interleaved banks and adder strips (SDP pattern)
        banks = [n for n in names if n.startswith(("sram_bank", "adder_strip"))]
        assert banks[0].startswith("sram_bank")
        assert banks[1].startswith("adder_strip")

    def test_def_and_sdp_emission(self, chip_fp):
        d = emit_def(chip_fp)
        assert "DIEAREA" in d and "REGIONS" in d
        s = emit_sdp_script(reference_chip_ppa())
        assert "sdpCreateGroup" in s and "set H 64" in s

    def test_every_frontier_design_places(self):
        tech = calibrated_tech_for_reference()
        scl = SubcircuitLibrary(tech).build()
        res = mso_search(pareto_experiment_spec(), scl, tech)
        for ppa in res.frontier:
            fp = place(ppa)
            assert fp.utilization > 0.9
