import importlib.util
import pathlib


def _install_hypothesis_fallback() -> None:
    """The runtime image may lack hypothesis (CI installs the real one from
    requirements-dev.txt).  Register the deterministic fallback before test
    modules import it, so collection never fails offline."""
    if importlib.util.find_spec("hypothesis") is not None:
        return
    path = pathlib.Path(__file__).with_name("_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("_hypothesis_fallback", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.install()


_install_hypothesis_fallback()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess drills (seconds to minutes)")
