"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles across
shape/dtype sweeps, plus hypothesis property tests.

interpret=True executes the kernel bodies on CPU; on TPU the same
pallas_call lowers to Mosaic with the BlockSpec tiling declared in kernel.py.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.csa_tree import (CSA_MAX_ROWS, csa_tree_pallas,
                                    csa_tree_ref, csa_tree_sum,
                                    csa_tree_tiled_pallas)
from repro.kernels.dcim_mac import (dcim_matmul, dcim_matmul_int,
                                    dcim_matmul_int_pallas,
                                    dcim_matmul_int_pipelined_pallas,
                                    dcim_matmul_pallas,
                                    dcim_matmul_pipelined_pallas)
from repro.kernels.dcim_mac import ref as mac_ref
from repro.kernels.ssm_scan import (ssm_scan, ssm_scan_assoc_ref,
                                    ssm_scan_pallas, ssm_scan_pipelined_pallas,
                                    ssm_scan_ref)
from repro.kernels.tiles import TileConfig

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# dcim_mac
# ---------------------------------------------------------------------------

MAC_SHAPES = [
    (8, 16, 8),        # tiny, fully padded
    (128, 128, 128),   # exactly one block
    (128, 256, 384),   # multi-block K and N
    (130, 96, 200),    # ragged everything
    (1, 512, 64),      # single row (decode-like)
    (256, 128, 256),
]


class TestDcimMac:
    @pytest.mark.parametrize("m,k,n", MAC_SHAPES)
    def test_int_matches_oracle(self, m, k, n):
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        out = dcim_matmul_int_pallas(a, w, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))

    @pytest.mark.parametrize("a_bits,w_bits", [(8, 8), (4, 4), (4, 8), (2, 8),
                                               (8, 4), (1, 8)])
    def test_bitserial_semantics_bit_exact(self, a_bits, w_bits):
        """The MXU path computes exactly the bit-serial DCIM result for every
        supported precision pair (paper INT1/2/4/8)."""
        lo_a, hi_a = mac_ref.quant_range(a_bits) if a_bits > 1 else (0, 1)
        lo_w, hi_w = mac_ref.quant_range(w_bits)
        a = jnp.asarray(RNG.integers(lo_a, hi_a + 1, (64, 96)), jnp.int8)
        w = jnp.asarray(RNG.integers(lo_w, hi_w + 1, (96, 72)), jnp.int8)
        mxu = dcim_matmul_int_pallas(a, w, interpret=True)
        bitserial = mac_ref.dcim_matmul_bitserial_ref(a, w, max(a_bits, 2), w_bits)
        np.testing.assert_array_equal(np.asarray(mxu), np.asarray(bitserial))

    @pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
    def test_dequant_epilogue(self, out_dtype):
        m, k, n = 64, 128, 80
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        asc = jnp.asarray(RNG.uniform(0.01, 2.0, (m,)), jnp.float32)
        wsc = jnp.asarray(RNG.uniform(0.01, 2.0, (n,)), jnp.float32)
        out = dcim_matmul_pallas(a, w, asc, wsc, out_dtype=out_dtype,
                                 interpret=True)
        ref = mac_ref.dcim_matmul_ref(a, w, asc[:, None], wsc[None, :],
                                      out_dtype=out_dtype)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6)

    def test_dispatch_cpu_path_matches(self):
        a = jnp.asarray(RNG.integers(-128, 128, (32, 64)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (64, 48)), jnp.int8)
        xla = dcim_matmul(a, w, 0.5, 2.0, use_pallas=False)
        pls = dcim_matmul(a, w, 0.5, 2.0, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(pls), rtol=1e-6)

    @given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(r.integers(-128, 128, (k, n)), jnp.int8)
        out = dcim_matmul_int_pallas(a, w, bm=32, bn=32, bk=32, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))


class TestDcimMacPipelined:
    """The manual multi-buffered DMA pipeline must be bit-identical to the
    grid kernel / oracle at every depth — pipelining is a schedule, not an
    arithmetic change."""

    @pytest.mark.parametrize("m,k,n", MAC_SHAPES)
    @pytest.mark.parametrize("depth", [2, 4])
    def test_int_matches_oracle(self, m, k, n, depth):
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        out = dcim_matmul_int_pipelined_pallas(a, w, depth=depth,
                                               interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))

    def test_depth_exceeding_k_steps(self):
        """Warm-up must not issue fetches past the last K chunk."""
        a = jnp.asarray(RNG.integers(-128, 128, (32, 128)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (128, 32)), jnp.int8)
        out = dcim_matmul_int_pipelined_pallas(a, w, bk=128, depth=4,
                                               interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))

    def test_dequant_epilogue(self):
        m, k, n = 100, 300, 200      # ragged: every dim pads
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        asc = jnp.asarray(RNG.uniform(0.01, 2.0, (m,)), jnp.float32)
        wsc = jnp.asarray(RNG.uniform(0.01, 2.0, (n,)), jnp.float32)
        out = dcim_matmul_pipelined_pallas(a, w, asc, wsc, depth=2,
                                           interpret=True)
        ref = mac_ref.dcim_matmul_ref(a, w, asc[:, None], wsc[None, :])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6)

    def test_dispatch_tile_config(self):
        """The entry point honours explicit TileConfigs on both paths."""
        a = jnp.asarray(RNG.integers(-128, 128, (40, 70)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (70, 50)), jnp.int8)
        want = np.asarray(mac_ref.dcim_matmul_int_ref(a, w))
        for tc in (TileConfig(bm=32, bn=128, bk=128, depth=2),
                   TileConfig(bm=32, bn=128, bk=128, depth=1)):
            out = dcim_matmul_int(a, w, use_pallas=True, interpret=True,
                                  tile_config=tc)
            np.testing.assert_array_equal(np.asarray(out), want)

    @given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
           scale_kind=st.sampled_from(["scalar", "row", "col", "both"]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_padding_and_scale_broadcast(self, m, k, n, scale_kind,
                                                  seed):
        """Ragged M/K/N (nothing block-aligned) and every scale broadcast
        shape agree with the oracle through the pipelined path."""
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(r.integers(-128, 128, (k, n)), jnp.int8)
        asc = (0.37 if scale_kind in ("scalar", "col")
               else jnp.asarray(r.uniform(0.01, 2.0, (m,)), jnp.float32))
        wsc = (1.5 if scale_kind in ("scalar", "row")
               else jnp.asarray(r.uniform(0.01, 2.0, (n,)), jnp.float32))
        out = dcim_matmul(a, w, asc, wsc, use_pallas=True, interpret=True,
                          tile_config=TileConfig(bm=32, bn=128, bk=128,
                                                 depth=2))
        asc_ref = jnp.broadcast_to(jnp.asarray(asc, jnp.float32), (m,))
        wsc_ref = jnp.broadcast_to(jnp.asarray(wsc, jnp.float32), (n,))
        ref = mac_ref.dcim_matmul_ref(a, w, asc_ref[:, None],
                                      wsc_ref[None, :])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# csa_tree
# ---------------------------------------------------------------------------


class TestCsaTree:
    @pytest.mark.parametrize("h", [2, 3, 4, 7, 8, 16, 33, 64, 128])
    @pytest.mark.parametrize("use_compressors", [True, False])
    def test_matches_sum(self, h, use_compressors):
        x = jnp.asarray(RNG.integers(-2**16, 2**16, (h, 257)), jnp.int32)
        out = csa_tree_pallas(x, use_compressors=use_compressors,
                              interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(csa_tree_ref(x)))

    def test_bitwise_products(self):
        """The DCIM case: summing H rows of {0,1} x weight products."""
        h, n = 64, 512
        bits = RNG.integers(0, 2, (h, n))
        w = RNG.integers(-8, 8, (h, n))
        x = jnp.asarray(bits * w, jnp.int32)
        out = csa_tree_pallas(x, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), (bits * w).sum(0))

    @given(h=st.integers(2, 40), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_carry_save_invariant(self, h, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(-10**6, 10**6, (h, n)), jnp.int32)
        out = csa_tree_pallas(x, bn=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x).sum(0))


class TestCsaTreeTiled:
    """The H <= 512 whole-rows assumption is now an explicit guard, and the
    tiled-H variant lifts it bit-exactly."""

    def test_whole_rows_guard_raises(self):
        x = jnp.zeros((CSA_MAX_ROWS + 1, 128), jnp.int32)
        with pytest.raises(ValueError, match="csa_tree_tiled_pallas"):
            csa_tree_pallas(x, interpret=True)

    def test_just_above_limit_routes_to_tiled(self):
        """Regression for the old silent assumption: H one past the limit
        must work through the public entry point, exactly."""
        h = CSA_MAX_ROWS + 1
        x = jnp.asarray(RNG.integers(-2**16, 2**16, (h, 140)), jnp.int32)
        out = csa_tree_sum(x, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(x).sum(0, dtype=np.int64)
                                      .astype(np.int32))

    @pytest.mark.parametrize("h", [5, 64, 130, 512, 700])
    @pytest.mark.parametrize("bh", [32, 128])
    def test_tiled_matches_sum(self, h, bh):
        x = jnp.asarray(RNG.integers(-2**16, 2**16, (h, 257)), jnp.int32)
        out = csa_tree_tiled_pallas(x, bh=bh, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(csa_tree_ref(x)))

    def test_tiled_matches_whole_rows_kernel(self):
        """Same bits as the whole-rows kernel where both apply (int32 wrap)."""
        x = jnp.asarray(RNG.integers(-2**30, 2**30, (96, 256)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(csa_tree_tiled_pallas(x, bh=32, interpret=True)),
            np.asarray(csa_tree_pallas(x, interpret=True)))

    def test_explicit_tile_config_routes_to_tiled(self):
        x = jnp.asarray(RNG.integers(-2**16, 2**16, (64, 256)), jnp.int32)
        out = csa_tree_sum(x, use_pallas=True, interpret=True,
                           tile_config=TileConfig(bh=32, bn=128))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(csa_tree_ref(x)))


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------

SCAN_SHAPES = [(16, 8), (128, 128), (130, 64), (257, 130), (512, 256), (1, 32)]


class TestSsmScan:
    @pytest.mark.parametrize("t,d", SCAN_SHAPES)
    def test_matches_sequential_ref(self, t, d):
        a = jnp.asarray(RNG.uniform(0.7, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pallas(a, b, h0, bt=64, bd=64, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_assoc_ref_matches_sequential(self):
        t, d = 300, 96
        a = jnp.asarray(RNG.uniform(0.5, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        s1, f1 = ssm_scan_ref(a, b, h0)
        s2, f2 = ssm_scan_assoc_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5,
                                   atol=2e-5)

    def test_identity_decay_is_cumsum(self):
        t, d = 100, 16
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        a = jnp.ones((t, d), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        s, f = ssm_scan_pallas(a, b, h0, bt=32, bd=16, interpret=True)
        np.testing.assert_allclose(np.asarray(s), np.cumsum(np.asarray(b), 0),
                                   rtol=1e-4, atol=1e-4)

    @given(t=st.integers(1, 80), d=st.integers(1, 40),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random(self, t, d, seed):
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.uniform(0.0, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pallas(a, b, h0, bt=32, bd=32, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=3e-5, atol=3e-5)


class TestSsmScanPipelined:
    """Multi-buffered streaming scan vs the sequential oracle: identical
    per-chunk arithmetic, so the tolerance contract matches the grid kernel."""

    @pytest.mark.parametrize("t,d", SCAN_SHAPES)
    @pytest.mark.parametrize("depth", [2, 4])
    def test_matches_sequential_ref(self, t, d, depth):
        a = jnp.asarray(RNG.uniform(0.7, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pipelined_pallas(a, b, h0, bt=64, bd=64,
                                               depth=depth, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_grid_kernel_exactly(self):
        """Same chunk shape => same reduction order => same floats bit-wise."""
        t, d = 256, 128
        a = jnp.asarray(RNG.uniform(0.7, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        s_grid, f_grid = ssm_scan_pallas(a, b, h0, bt=64, bd=64,
                                         interpret=True)
        s_pipe, f_pipe = ssm_scan_pipelined_pallas(a, b, h0, bt=64, bd=64,
                                                   depth=2, interpret=True)
        np.testing.assert_array_equal(np.asarray(s_grid), np.asarray(s_pipe))
        np.testing.assert_array_equal(np.asarray(f_grid), np.asarray(f_pipe))

    def test_dispatch_tile_config(self):
        t, d = 200, 96
        a = jnp.asarray(RNG.uniform(0.7, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        s_ref, _ = ssm_scan_ref(a, b, h0)
        for tc in (TileConfig(bt=64, bd=128, depth=2),
                   TileConfig(bt=64, bd=128, depth=1)):
            s, _ = ssm_scan(a, b, h0, use_pallas=True, interpret=True,
                            tile_config=tc)
            np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                       rtol=2e-5, atol=2e-5)

    @given(t=st.integers(1, 100), d=st.integers(1, 50),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_ragged_shapes(self, t, d, seed):
        """T not a multiple of the chunk, D not 128-aligned — the doubling
        scan must agree with the sequential oracle through the padding."""
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.uniform(0.0, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pipelined_pallas(a, b, h0, bt=32, bd=32,
                                               depth=2, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                                   rtol=3e-5, atol=3e-5)

    @given(t=st.integers(1, 150), seed=st.integers(0, 2**31 - 1),
           eps=st.floats(0.0, 0.02))
    @settings(max_examples=10, deadline=None)
    def test_property_near_identity_decay_stable(self, t, seed, eps):
        """a ~= 1 (the numerically touchy long-memory regime): the log-depth
        prefix products must stay close to the sequential recurrence instead
        of drifting."""
        d = 24
        r = np.random.default_rng(seed)
        a = jnp.asarray(np.full((t, d), 1.0 - eps), jnp.float32)
        b = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pipelined_pallas(a, b, h0, bt=32, bd=32,
                                               depth=2, interpret=True)
        scale = max(1.0, float(np.abs(np.asarray(s_ref)).max()))
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4 * scale)
