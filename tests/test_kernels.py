"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles across
shape/dtype sweeps, plus hypothesis property tests.

interpret=True executes the kernel bodies on CPU; on TPU the same
pallas_call lowers to Mosaic with the BlockSpec tiling declared in kernel.py.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.csa_tree import csa_tree_pallas, csa_tree_ref
from repro.kernels.dcim_mac import (dcim_matmul, dcim_matmul_int_pallas,
                                    dcim_matmul_pallas)
from repro.kernels.dcim_mac import ref as mac_ref
from repro.kernels.ssm_scan import (ssm_scan_assoc_ref, ssm_scan_pallas,
                                    ssm_scan_ref)

RNG = np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# dcim_mac
# ---------------------------------------------------------------------------

MAC_SHAPES = [
    (8, 16, 8),        # tiny, fully padded
    (128, 128, 128),   # exactly one block
    (128, 256, 384),   # multi-block K and N
    (130, 96, 200),    # ragged everything
    (1, 512, 64),      # single row (decode-like)
    (256, 128, 256),
]


class TestDcimMac:
    @pytest.mark.parametrize("m,k,n", MAC_SHAPES)
    def test_int_matches_oracle(self, m, k, n):
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        out = dcim_matmul_int_pallas(a, w, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))

    @pytest.mark.parametrize("a_bits,w_bits", [(8, 8), (4, 4), (4, 8), (2, 8),
                                               (8, 4), (1, 8)])
    def test_bitserial_semantics_bit_exact(self, a_bits, w_bits):
        """The MXU path computes exactly the bit-serial DCIM result for every
        supported precision pair (paper INT1/2/4/8)."""
        lo_a, hi_a = mac_ref.quant_range(a_bits) if a_bits > 1 else (0, 1)
        lo_w, hi_w = mac_ref.quant_range(w_bits)
        a = jnp.asarray(RNG.integers(lo_a, hi_a + 1, (64, 96)), jnp.int8)
        w = jnp.asarray(RNG.integers(lo_w, hi_w + 1, (96, 72)), jnp.int8)
        mxu = dcim_matmul_int_pallas(a, w, interpret=True)
        bitserial = mac_ref.dcim_matmul_bitserial_ref(a, w, max(a_bits, 2), w_bits)
        np.testing.assert_array_equal(np.asarray(mxu), np.asarray(bitserial))

    @pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
    def test_dequant_epilogue(self, out_dtype):
        m, k, n = 64, 128, 80
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        asc = jnp.asarray(RNG.uniform(0.01, 2.0, (m,)), jnp.float32)
        wsc = jnp.asarray(RNG.uniform(0.01, 2.0, (n,)), jnp.float32)
        out = dcim_matmul_pallas(a, w, asc, wsc, out_dtype=out_dtype,
                                 interpret=True)
        ref = mac_ref.dcim_matmul_ref(a, w, asc[:, None], wsc[None, :],
                                      out_dtype=out_dtype)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6)

    def test_dispatch_cpu_path_matches(self):
        a = jnp.asarray(RNG.integers(-128, 128, (32, 64)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (64, 48)), jnp.int8)
        xla = dcim_matmul(a, w, 0.5, 2.0, use_pallas=False)
        pls = dcim_matmul(a, w, 0.5, 2.0, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(pls), rtol=1e-6)

    @given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(r.integers(-128, 128, (k, n)), jnp.int8)
        out = dcim_matmul_int_pallas(a, w, bm=32, bn=32, bk=32, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(mac_ref.dcim_matmul_int_ref(a, w)))


# ---------------------------------------------------------------------------
# csa_tree
# ---------------------------------------------------------------------------


class TestCsaTree:
    @pytest.mark.parametrize("h", [2, 3, 4, 7, 8, 16, 33, 64, 128])
    @pytest.mark.parametrize("use_compressors", [True, False])
    def test_matches_sum(self, h, use_compressors):
        x = jnp.asarray(RNG.integers(-2**16, 2**16, (h, 257)), jnp.int32)
        out = csa_tree_pallas(x, use_compressors=use_compressors,
                              interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(csa_tree_ref(x)))

    def test_bitwise_products(self):
        """The DCIM case: summing H rows of {0,1} x weight products."""
        h, n = 64, 512
        bits = RNG.integers(0, 2, (h, n))
        w = RNG.integers(-8, 8, (h, n))
        x = jnp.asarray(bits * w, jnp.int32)
        out = csa_tree_pallas(x, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), (bits * w).sum(0))

    @given(h=st.integers(2, 40), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_carry_save_invariant(self, h, n, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.integers(-10**6, 10**6, (h, n)), jnp.int32)
        out = csa_tree_pallas(x, bn=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x).sum(0))


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------

SCAN_SHAPES = [(16, 8), (128, 128), (130, 64), (257, 130), (512, 256), (1, 32)]


class TestSsmScan:
    @pytest.mark.parametrize("t,d", SCAN_SHAPES)
    def test_matches_sequential_ref(self, t, d):
        a = jnp.asarray(RNG.uniform(0.7, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pallas(a, b, h0, bt=64, bd=64, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_assoc_ref_matches_sequential(self):
        t, d = 300, 96
        a = jnp.asarray(RNG.uniform(0.5, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        s1, f1 = ssm_scan_ref(a, b, h0)
        s2, f2 = ssm_scan_assoc_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5,
                                   atol=2e-5)

    def test_identity_decay_is_cumsum(self):
        t, d = 100, 16
        b = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
        a = jnp.ones((t, d), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        s, f = ssm_scan_pallas(a, b, h0, bt=32, bd=16, interpret=True)
        np.testing.assert_allclose(np.asarray(s), np.cumsum(np.asarray(b), 0),
                                   rtol=1e-4, atol=1e-4)

    @given(t=st.integers(1, 80), d=st.integers(1, 40),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random(self, t, d, seed):
        r = np.random.default_rng(seed)
        a = jnp.asarray(r.uniform(0.0, 1.0, (t, d)), jnp.float32)
        b = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
        h0 = jnp.asarray(r.normal(size=(d,)), jnp.float32)
        s_ref, f_ref = ssm_scan_ref(a, b, h0)
        s_pl, f_pl = ssm_scan_pallas(a, b, h0, bt=32, bd=32, interpret=True)
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=3e-5, atol=3e-5)
