"""End-to-end FP execution through the DCIM pipeline (paper Fig. 1 path):

    FP operands -> FP&INT alignment unit (block max-exponent + mantissa
    shift) -> integer bit-serial MAC (adder tree + S&A) -> OFU rescale

The integer MAC is the same `dcim_matmul_int` kernel validated bit-exactly
against the bit-serial oracle; this test closes the loop by showing the
aligned-integer path approximates the f32 matmul to block-FP accuracy — i.e.
the compiled macro's FP8/BF16 modes are numerically faithful."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.dcim_mac import dcim_matmul_int
from repro.quant import block_fp_align, fp8_e4m3_quant

RNG = np.random.default_rng(11)


def _aligned_matmul(a_fp: jnp.ndarray, w_fp: jnp.ndarray, man_bits: int):
    """The alignment-unit execution: per-row blocks for activations, per-col
    blocks for weights; integer MAC; OFU rescale."""
    a_man, a_scale = block_fp_align(a_fp, man_bits, block_axis=-1)  # (M,K)
    w_man, w_scale = block_fp_align(w_fp.T, man_bits, block_axis=-1)  # (N,K)
    # mantissas fit int8 only if man_bits <= 6; use int32 MAC ref for larger
    acc = jnp.matmul(a_man.astype(jnp.int64), w_man.T.astype(jnp.int64))
    return acc.astype(jnp.float32) * a_scale * w_scale.T


@pytest.mark.parametrize("man_bits,rtol", [(7, 0.02), (5, 0.08), (3, 0.3)])
def test_aligned_fp_matmul_approximates_f32(man_bits, rtol):
    a = jnp.asarray(RNG.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 48)), jnp.float32)
    got = _aligned_matmul(a, w, man_bits)
    ref = jnp.matmul(a, w)
    scale = float(jnp.abs(ref).max())
    err = float(jnp.abs(got - ref).max()) / scale
    assert err < rtol, (man_bits, err)


def test_alignment_feeds_int8_kernel_exactly():
    """With man_bits<=6 the aligned mantissas fit int8 and run on the actual
    DCIM kernel; result must equal the int64 reference exactly."""
    a = jnp.asarray(RNG.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 24)), jnp.float32)
    a_man, a_scale = block_fp_align(a, 6, -1)
    w_man, w_scale = block_fp_align(w.T, 6, -1)
    acc_kernel = dcim_matmul_int(a_man.astype(jnp.int8),
                                 w_man.T.astype(jnp.int8), use_pallas=True,
                                 interpret=True)
    acc_ref = jnp.matmul(a_man.astype(jnp.int64), w_man.T.astype(jnp.int64))
    np.testing.assert_array_equal(np.asarray(acc_kernel),
                                  np.asarray(acc_ref.astype(jnp.int32)))


def test_fp8_mode_error_profile():
    """FP8 (E4M3) quantization of operands before the aligned path — the
    macro's FP8 mode — stays within a few percent on normalized data."""
    a = jnp.asarray(RNG.normal(size=(32, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 32)) * 0.1, jnp.float32)
    a8 = fp8_e4m3_quant(a)
    w8 = fp8_e4m3_quant(w)
    got = _aligned_matmul(a8, w8, 7)
    ref = jnp.matmul(a, w)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.08, rel
