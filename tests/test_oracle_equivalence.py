"""Differential oracle harness: every execution path of the MSO engine
agrees bit-for-bit with the scalar reference.

The engine has grown five ways to run Algorithm 1 — the scalar per-point
hierarchy (``mso_search``), the single-spec batched lattice replay
(``backend="batched"``), the multi-spec vmapped pass (``mso_search_many``),
the device-sharded pass (``mso_search_many_sharded``, jit-NamedSharding and
pmap modes), and the multi-host ``('host', 'spec')`` strategy
(:mod:`repro.core.multihost`, single-host fallback).  PRs 1-2 proved their equivalences ad hoc; this is the
systematic replacement: one parametrized harness asserting, for every
alternate path, against the scalar oracle,

  * Alg.-1 selection order — the explored design sequence is identical;
  * frontier membership — same designs, in the same order;
  * PPA values — every scalar field of every frontier point is bit-exact.

across the §I scenario set and hypothesis-generated random specs, on however
many devices the process sees (CI re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the in-file
subprocess drills cover the 8-device cases regardless).

All four paths now route through the shared execution engine
(:mod:`repro.core.engine`); this file additionally pins that routing (no
pack/pad/place copies left in the path modules) and the device-sharded
Pareto extraction (``pareto.nondominated_mask_sharded`` bit-identical to the
host mask on >= 100k points, both placement modes).
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import calibrated_tech_for_reference, mso_search
from repro.core.macro import MacroSpec
from repro.core.multispec import mso_search_many, scenario_specs
from repro.core.pareto import (PARETO_EPS, nondominated_mask,
                               nondominated_mask_sharded)
from repro.core.shardspec import (mso_search_many_sharded, resolve_mode,
                                  spec_variants)

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


# ---------------------------------------------------------------------------
# The differential contract
# ---------------------------------------------------------------------------


def assert_ppa_equal(a, b):
    """Bit-exact equality of every scalar field of two MacroPPAs."""
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.tops_1b == b.tops_1b
    assert a.tops_per_w_1b == b.tops_per_w_1b
    assert a.tops_per_mm2_1b == b.tops_per_mm2_1b
    assert a.meets_timing == b.meets_timing


def assert_search_identical(got, oracle):
    """The full differential contract for one spec's SearchResult."""
    assert got.spec == oracle.spec
    assert got.n_evaluated == oracle.n_evaluated
    # Alg.-1 selection order: the explored sequence, not just its set.
    assert [p.design.name() for p in got.explored] == \
           [p.design.name() for p in oracle.explored]
    # Frontier membership + bit-exact PPA per member.
    assert len(got.frontier) == len(oracle.frontier)
    for x, y in zip(got.frontier, oracle.frontier):
        assert_ppa_equal(x, y)


# Every alternate execution path, as (name, many-specs runner).  The scalar
# oracle is run per spec by the assertions below.
PATHS = {
    "batched": lambda specs, tech, res: [
        mso_search(s, None, tech, resolution=res, backend="batched")
        for s in specs],
    "multispec": lambda specs, tech, res:
        mso_search_many(specs, None, tech, resolution=res),
    "sharded-jit": lambda specs, tech, res:
        mso_search_many_sharded(specs, None, tech, resolution=res,
                                mode="jit"),
    "sharded-pmap": lambda specs, tech, res:
        mso_search_many_sharded(specs, None, tech, resolution=res,
                                mode="pmap"),
    # the ('host', 'spec') multi-host strategy (repro.core.multihost);
    # resolve falls back to the single-host pick where it is unavailable,
    # so this path is exercised (and must agree) on every runtime.
    "sharded-multihost": lambda specs, tech, res:
        mso_search_many_sharded(specs, None, tech, resolution=res,
                                mode="multihost"),
}


def _oracle(specs, tech, res):
    return [mso_search(s, None, tech, resolution=res) for s in specs]


# ---------------------------------------------------------------------------
# Scenario specs (+ ragged variant tail) vs the scalar oracle
# ---------------------------------------------------------------------------


class TestScenarioEquivalence:
    @pytest.fixture(scope="class")
    def scenario_set(self):
        # 4 scenarios + 3 posture variants = 7 specs: ragged on any even
        # device count, so the sharded paths exercise padding/masking here.
        return list(scenario_specs().values()) + spec_variants(3, seed=7)

    @pytest.fixture(scope="class")
    def oracle(self, scenario_set, tech):
        return _oracle(scenario_set, tech, 4)

    @pytest.mark.parametrize("path", sorted(PATHS))
    def test_path_matches_scalar_oracle(self, path, scenario_set, tech,
                                        oracle):
        results = PATHS[path](scenario_set, tech, 4)
        assert len(results) == len(oracle)
        for got, ref in zip(results, oracle):
            assert_search_identical(got, ref)


# ---------------------------------------------------------------------------
# Hypothesis-generated specs vs the scalar oracle
# ---------------------------------------------------------------------------


class TestRandomSpecEquivalence:
    @settings(max_examples=6, deadline=None)
    @given(h=st.sampled_from([8, 16, 32, 64]),
           w=st.sampled_from([16, 32, 64]),
           mcr=st.sampled_from([1, 2, 4]),
           ints=st.sampled_from([(2, 4), (4, 8)]),
           fps=st.sampled_from([("FP4", "FP8"), ("FP8",)]),
           f_mac=st.sampled_from([250e6, 500e6, 800e6, 1.1e9]),
           vdd=st.sampled_from([0.7, 0.9, 1.2]),
           slow_update=st.booleans())
    def test_all_paths_match_scalar_oracle(self, h, w, mcr, ints, fps, f_mac,
                                           vdd, slow_update):
        tech = calibrated_tech_for_reference()
        spec = MacroSpec(h=h, w=w, mcr=mcr, int_precisions=ints,
                         fp_precisions=fps, f_mac_hz=f_mac,
                         f_wupdate_hz=f_mac / 8 if slow_update else f_mac,
                         vdd=vdd)
        (ref,) = _oracle([spec], tech, 3)
        for path, runner in sorted(PATHS.items()):
            (got,) = runner([spec], tech, 3)
            assert_search_identical(got, ref)


# ---------------------------------------------------------------------------
# Sharded-path mechanics: mode resolution + ragged padding on 8 fake devices
# ---------------------------------------------------------------------------


class TestShardedMechanics:
    def test_mode_resolution(self):
        assert resolve_mode("auto") in ("jit", "pmap")
        assert resolve_mode("jit") == "jit"
        assert resolve_mode("pmap") == "pmap"
        with pytest.raises(ValueError):
            resolve_mode("tpu-pod")

    def test_spec_variants_deterministic_and_grouped(self, tech):
        a = spec_variants(12, seed=3)
        b = spec_variants(12, seed=3)
        assert a == b
        assert len({(s.h, s.w, s.int_precisions, s.fp_precisions)
                    for s in a}) == 1      # one geometry -> one vmap group
        assert len(set(a)) == len(a)       # duplicate-free request

    def test_ragged_counts_match_unsharded(self, tech):
        """Spec counts that do not divide the device count still return
        bit-identical per-spec results (padding is computed and discarded)."""
        for n in (1, 3, 5):
            specs = spec_variants(n, seed=n)
            ref = mso_search_many(specs, None, tech, resolution=3)
            for mode in ("jit", "pmap"):
                got = mso_search_many_sharded(specs, None, tech,
                                              resolution=3, mode=mode)
                for g, r in zip(got, ref):
                    assert_search_identical(g, r)

    def test_eight_fake_devices_bit_identical(self):
        """Subprocess drill (device count is fixed at first jax init): the
        sharded paths on 8 fake host devices, with a ragged 13-spec request,
        stay bit-identical to the unsharded multispec pass."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import jax
            from repro.core import calibrated_tech_for_reference
            from repro.core.multispec import mso_search_many
            from repro.core.shardspec import (mso_search_many_sharded,
                                              spec_variants)

            tech = calibrated_tech_for_reference()
            specs = spec_variants(13, seed=5)       # ragged on 8 devices
            ref = mso_search_many(specs, None, tech, resolution=3)
            verdict = {"devices": len(jax.devices())}
            for mode in ("jit", "pmap"):
                got = mso_search_many_sharded(specs, None, tech,
                                              resolution=3, mode=mode)
                verdict[mode] = all(
                    [p.design.name() for p in g.explored]
                    == [p.design.name() for p in r.explored]
                    and len(g.frontier) == len(r.frontier)
                    and all(x.paths == y.paths
                            and x.fmax_hz == y.fmax_hz
                            and x.area_um2 == y.area_um2
                            and x.area_breakdown == y.area_breakdown
                            and x.e_cycle_fj == y.e_cycle_fj
                            and x.tops_per_w_1b == y.tops_per_w_1b
                            and x.latency_cycles == y.latency_cycles
                            for x, y in zip(g.frontier, r.frontier))
                    for g, r in zip(got, ref))
            print(json.dumps(verdict))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"scenario failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["jit"] and out["pmap"]


# ---------------------------------------------------------------------------
# Engine routing: every execution path is a strategy over repro.core.engine
# ---------------------------------------------------------------------------


class TestEngineRouting:
    def test_strategies_registered_and_probed(self):
        from repro.core import engine
        assert {"jit", "vmap", "sharded-jit", "pmap",
                "multihost"} <= set(engine.STRATEGIES)
        for s in engine.STRATEGIES.values():
            assert callable(s.available) and callable(s.run)
        # the capability-probed dispatcher is the single mode authority
        assert engine.resolve_sharded_mode("auto") in ("jit", "pmap")
        with pytest.raises(ValueError):
            engine.place("warp-drive")

    def test_path_modules_are_thin_strategies(self):
        """The refactor's contract: multispec/shardspec carry no pack/pad/
        place copies of their own — shared pipeline code lives in the engine
        and the path modules only alias it."""
        from repro.core import engine, multispec, shardspec
        assert multispec._group_key is engine.group_key
        assert multispec._eval_kernel_many is engine._eval_kernel_many
        assert shardspec.resolve_mode is engine.resolve_sharded_mode
        for stale in ("_pack_group", "_unpack_group", "_grouped",
                      "_evaluate_group"):
            assert not hasattr(multispec, stale), f"copy left: {stale}"
        for stale in ("_pad_lanes", "_evaluate_group_sharded",
                      "_supports_named_sharding", "_eval_kernel_pmap"):
            assert not hasattr(shardspec, stale), f"copy left: {stale}"

    def test_plan_groups_and_execute_orders(self, tech):
        """plan() buckets same-signature specs into one group and execute()
        returns results in input order across groups."""
        from repro.core import engine
        from repro.core import subcircuits as sc
        specs = spec_variants(3, seed=2)
        mixed = [specs[0],
                 MacroSpec(h=32, w=32, mcr=2, int_precisions=(4, 8),
                           fp_precisions=("FP8",), f_mac_hz=500e6,
                           f_wupdate_hz=500e6, vdd=0.9),
                 specs[1]]
        p = engine.plan(mixed, tech, (sc.MemCellKind.SRAM_6T,), mode="vmap")
        assert sorted(len(g) for g in p.groups) == [1, 2]
        out = engine.execute(p)
        assert [lat.spec for lat, _, _ in out] == mixed


# ---------------------------------------------------------------------------
# Sharded Pareto extraction == host extraction, bit for bit
# ---------------------------------------------------------------------------


def _extraction_points(seed: int, n: int, k: int = 3) -> np.ndarray:
    """Random objectives salted with exact duplicates, eps-near ties and a
    strictly dominated row — the cases where a wrong eps band or a survivor-
    only refinement would diverge from the host mask."""
    rng = np.random.default_rng(seed)
    objs = rng.uniform(0.1, 10.0, size=(n, k))
    if n >= 8:
        objs[n // 2] = objs[0]                    # exact duplicate
        objs[n // 3] = objs[1] + PARETO_EPS / 4   # inside the tie band
        objs[n // 4] = objs[2] + 1.0              # strictly dominated
    return objs


class TestShardedParetoExtraction:
    """``nondominated_mask_sharded`` must return bit-identical frontier
    membership and output order vs the host ``nondominated_mask`` — on 1
    device in a bare tier-1 run, on 8 fake host devices in the CI re-run of
    this file, and on a pinned 8-device subprocess drill regardless."""

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.sampled_from([1, 7, 64, 257, 2048]),
           k=st.sampled_from([1, 2, 3, 4]),
           mode=st.sampled_from(["jit", "pmap"]))
    def test_mask_bit_identical(self, seed, n, k, mode):
        objs = _extraction_points(seed, n, k)
        host = nondominated_mask(objs)
        shard = nondominated_mask_sharded(objs, mode=mode)
        assert np.array_equal(host, shard)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           chunk=st.sampled_from([64, 100, 1024]))
    def test_mask_chunk_invariant(self, seed, chunk):
        """Block boundaries must not move the verdicts."""
        objs = _extraction_points(seed, 1500, 3)
        host = nondominated_mask(objs)
        for mode in ("jit", "pmap"):
            got = nondominated_mask_sharded(objs, chunk=chunk, mode=mode)
            assert np.array_equal(host, got)

    def test_frontier_order_identical_through_shared_tail(self, tech):
        """The engine's frontier tail orders identically through the host and
        the sharded mask (same survivor indices, same objective-tuple
        sort)."""
        from repro.core.engine import extract_frontier
        objs = _extraction_points(11, 4096, 3)
        assert extract_frontier(objs, nondominated_mask) == \
            extract_frontier(objs, nondominated_mask_sharded)

    def test_lattice_scale_100k_bit_identical(self):
        """The satellite contract: >= 100k random points, host vs sharded,
        identical membership and order (flatnonzero sequences equal)."""
        objs = _extraction_points(0, 100_000, 3)
        host = nondominated_mask(objs)
        for mode in ("jit", "pmap"):
            shard = nondominated_mask_sharded(objs, mode=mode)
            assert np.array_equal(host, shard), f"mask diverged in {mode}"
            assert np.array_equal(np.flatnonzero(host),
                                  np.flatnonzero(shard))

    def test_sharded_sweep_frontier_matches_unsharded(self, tech):
        """design_space_sweep_many_sharded extracts its frontiers through the
        sharded mask — indices must match the unsharded sweeps exactly."""
        from repro.core.multispec import design_space_sweep_many
        from repro.core.shardspec import design_space_sweep_many_sharded
        specs = spec_variants(3, seed=9)
        ref = design_space_sweep_many(specs, tech)
        for mode in ("jit", "pmap"):
            got = design_space_sweep_many_sharded(specs, tech, mode=mode)
            for g, r in zip(got, ref):
                assert g.extract_mask is not None
                assert g.frontier_indices() == r.frontier_indices()

    def test_extraction_eight_fake_devices_bit_identical(self):
        """Subprocess drill (device count is fixed at first jax init): 100k
        points on 8 fake host devices, both modes, bit-identical to the host
        mask computed in the same process."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import numpy as np
            import jax
            from repro.core.pareto import (PARETO_EPS, nondominated_mask,
                                           nondominated_mask_sharded)

            rng = np.random.default_rng(0)
            objs = rng.uniform(0.1, 10.0, size=(100_000, 3))
            objs[50_000] = objs[0]
            objs[33_333] = objs[1] + PARETO_EPS / 4
            host = nondominated_mask(objs)
            verdict = {"devices": len(jax.devices())}
            for mode in ("jit", "pmap"):
                shard = nondominated_mask_sharded(objs, mode=mode)
                verdict[mode] = bool(
                    np.array_equal(host, shard)
                    and np.array_equal(np.flatnonzero(host),
                                       np.flatnonzero(shard)))
            print(json.dumps(verdict))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"drill failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["jit"] and out["pmap"]


# ---------------------------------------------------------------------------
# Registry-backed lattice: seed + each optional axis, every path, 1/8 devices
# ---------------------------------------------------------------------------


def _axis_configs():
    from repro.core import subcircuits as sc
    from repro.core.axes import LatticeConfig
    one = (sc.MemCellKind.SRAM_6T,)
    return {
        "seed": LatticeConfig(memcells=one),
        "precision": LatticeConfig(memcells=one, precision_modes=3),
        "approx_cell": LatticeConfig(memcells=one,
                                     approx_cells=sc.APPROX_CELLS),
        "precision+approx": LatticeConfig(memcells=one, precision_modes=2,
                                          approx_cells=sc.APPROX_CELLS[:3]),
    }


class TestRegistryLatticeEquivalence:
    """The tentpole's differential contract: the registry-composed lattice —
    the seed axes AND each new optional axis (precision modes, approximate
    adder-tree cells) — evaluates bit-identically through every execution
    path, and every batched point agrees with the scalar per-design
    roll-up."""

    @pytest.fixture(scope="class")
    def spec(self):
        return MacroSpec()

    @pytest.mark.parametrize("name", sorted(_axis_configs()))
    def test_batched_points_match_scalar_rollup(self, name, spec, tech):
        """The scalar-oracle gate for each registered axis: a sample of
        lattice points (always including nonzero new-axis coordinates)
        materializes to the same PPA the scalar hierarchy computes."""
        from repro.core.batched import design_space_sweep
        from repro.core.macro import rollup
        cfg = _axis_configs()[name]
        sweep = design_space_sweep(spec, tech, config=cfg)
        lat = sweep.lattice
        rng = np.random.default_rng(len(lat))
        picks = {0, len(lat) - 1} | set(
            int(i) for i in rng.integers(0, len(lat), size=24))
        for i in sorted(picks):
            got = sweep.materialize(i)
            ref = rollup(lat.design_at(i), tech)
            assert_ppa_equal(got, ref)

    @pytest.mark.parametrize("name", sorted(_axis_configs()))
    def test_multispec_and_sharded_match_batched(self, name, spec, tech):
        """Objectives and frontier membership identical across the single-
        spec batched sweep, the vmapped multi-spec pass and both sharded
        placements on however many devices tier-1 sees."""
        from repro.core.batched import design_space_sweep
        from repro.core.multispec import design_space_sweep_many
        from repro.core.shardspec import design_space_sweep_many_sharded
        cfg = _axis_configs()[name]
        ref = design_space_sweep(spec, tech, config=cfg)
        runs = {"multispec": design_space_sweep_many(
                    [spec], tech, memcells=cfg.memcells, config=cfg)[0]}
        for mode in ("jit", "pmap"):
            runs[f"sharded-{mode}"] = design_space_sweep_many_sharded(
                [spec], tech, memcells=cfg.memcells, mode=mode,
                config=cfg)[0]
        ref_obj = ref.objectives()
        for path, sweep in runs.items():
            assert sweep.lattice.dims == ref.lattice.dims, path
            assert np.array_equal(ref_obj, sweep.objectives()), path
            assert sweep.frontier_indices() == ref.frontier_indices(), path

    def test_extended_lattice_embeds_seed_block(self, spec, tech):
        """New axes append AFTER the seed axes with the seed design at
        coordinate 0 — so the seed sweep is a strided sub-block of the
        extended sweep, bit for bit."""
        import dataclasses
        from repro.core.batched import design_space_sweep
        cfgs = _axis_configs()
        seed_sweep = design_space_sweep(spec, tech, config=cfgs["seed"])
        ext_sweep = design_space_sweep(spec, tech, config=cfgs["precision"])
        scale = ext_sweep.lattice.axis("precision").size
        assert len(ext_sweep.lattice) == len(seed_sweep.lattice) * scale
        assert np.array_equal(seed_sweep.objectives(),
                              ext_sweep.objectives()[::scale])
        for i in (0, 7, 31):
            assert dataclasses.asdict(seed_sweep.lattice.design_at(i)) == \
                dataclasses.asdict(ext_sweep.lattice.design_at(i * scale))

    def test_registry_lattice_eight_fake_devices(self, tech):
        """Subprocess drill: the extended (precision + approx-cell) lattice
        on 8 fake host devices — both sharded placements bit-identical to
        the vmapped pass."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import numpy as np
            import jax
            from repro.core import calibrated_tech_for_reference
            from repro.core import subcircuits as sc
            from repro.core.axes import LatticeConfig
            from repro.core.macro import MacroSpec
            from repro.core.multispec import design_space_sweep_many
            from repro.core.shardspec import (design_space_sweep_many_sharded,
                                              spec_variants)

            tech = calibrated_tech_for_reference()
            cfg = LatticeConfig(memcells=(sc.MemCellKind.SRAM_6T,),
                                precision_modes=2,
                                approx_cells=sc.APPROX_CELLS[:3])
            specs = [MacroSpec()] + spec_variants(2, seed=11)
            ref = design_space_sweep_many(specs, tech,
                                          memcells=cfg.memcells, config=cfg)
            verdict = {"devices": len(jax.devices())}
            for mode in ("jit", "pmap"):
                got = design_space_sweep_many_sharded(
                    specs, tech, memcells=cfg.memcells, mode=mode,
                    config=cfg)
                verdict[mode] = all(
                    np.array_equal(r.objectives(), g.objectives())
                    and r.frontier_indices() == g.frontier_indices()
                    for r, g in zip(ref, got))
            print(json.dumps(verdict))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"drill failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["jit"] and out["pmap"]


# ---------------------------------------------------------------------------
# Incremental re-synthesis == cold full pass, bit for bit
# ---------------------------------------------------------------------------


class TestIncrementalSweepEquivalence:
    """The service's incremental path — merging cached per-axis slice
    frontiers with a re-evaluated invalidated sublattice — must be
    indistinguishable from re-rolling the whole product."""

    def _service(self, tmp_path, config):
        from repro.service import FrontierCache, SynthesisService
        return SynthesisService(cache=FrontierCache(store_dir=tmp_path),
                                config=config)

    def test_scoped_recalibration_merges_bit_identical(self, tmp_path, tech):
        import dataclasses
        from repro.core import subcircuits as sc
        from repro.core.axes import LatticeConfig
        from repro.service import SynthesisRequest
        cfg = LatticeConfig(memcells=(sc.MemCellKind.SRAM_6T,
                                      sc.MemCellKind.DLATCH_8T))
        svc = self._service(tmp_path / "a", cfg)
        spec = MacroSpec()
        req = SynthesisRequest(spec=spec, tech=tech, kind="sweep")
        svc.serve([req])                       # warm the slice caches
        tech2 = dataclasses.replace(tech, a_sram8t=tech.a_sram8t * 1.05)
        (warm,) = svc.serve([SynthesisRequest(spec=spec, tech=tech2,
                                              kind="sweep")])
        assert svc.stats.incremental_passes == 1
        assert svc.stats.slice_hits >= 1
        cold_svc = self._service(tmp_path / "b", cfg)
        (cold,) = cold_svc.serve([SynthesisRequest(spec=spec, tech=tech2,
                                                   kind="sweep")])
        assert cold_svc.stats.incremental_passes == 0
        assert dataclasses.asdict(warm.result) == \
            dataclasses.asdict(cold.result)

    def test_axis_growth_merges_bit_identical(self, tmp_path, tech):
        import dataclasses
        from repro.core import subcircuits as sc
        from repro.core.axes import LatticeConfig
        from repro.service import SynthesisRequest
        cfg = LatticeConfig(memcells=(sc.MemCellKind.SRAM_6T,))
        svc = self._service(tmp_path / "a", cfg)
        spec = MacroSpec()
        svc.serve([SynthesisRequest(spec=spec, tech=tech, kind="sweep")])
        grown = dataclasses.replace(cfg, rho_steps=cfg.rho_steps + (0.9,))
        (warm,) = svc.serve([SynthesisRequest(spec=spec, tech=tech,
                                              kind="sweep", config=grown)])
        assert svc.stats.incremental_passes == 1
        assert svc.stats.slice_hits == len(cfg.rho_steps)
        cold_svc = self._service(tmp_path / "b", cfg)
        (cold,) = cold_svc.serve([SynthesisRequest(spec=spec, tech=tech,
                                                   kind="sweep",
                                                   config=grown)])
        assert dataclasses.asdict(warm.result) == \
            dataclasses.asdict(cold.result)
