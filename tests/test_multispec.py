"""Multi-spec vmapped co-synthesis engine + serving-time macro selection.

The contract under test (repro.core.multispec / repro.serve.select): the
spec-batched evaluation is bit-identical per spec to the single-spec batched
engine, ``mso_search_many`` returns exactly the frontiers of N independent
``mso_search(backend="batched")`` calls (the PR acceptance pin), grouping
handles heterogeneous lattice shapes, Pareto chunking is memory-bounded, and
serving selection assigns each deployed workload its lowest-wallclock macro.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (calibrated_tech_for_reference, design_space_sweep,
                        design_space_sweep_many, evaluate_many,
                        frontier_union, mso_search, mso_search_batched,
                        mso_search_many, pareto_chunk_size,
                        pareto_experiment_spec, scenario_specs)
from repro.core import batched as B
from repro.core.dse import GemmShape
from repro.serve.select import select_macros


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


def assert_ppa_equal(a, b):
    """Bit-exact equality of every scalar field of two MacroPPAs."""
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.tops_1b == b.tops_1b
    assert a.tops_per_w_1b == b.tops_per_w_1b
    assert a.tops_per_mm2_1b == b.tops_per_mm2_1b
    assert a.meets_timing == b.meets_timing


def assert_search_identical(a, b):
    assert a.n_evaluated == b.n_evaluated
    assert [p.design.name() for p in a.explored] == \
           [p.design.name() for p in b.explored]
    assert len(a.frontier) == len(b.frontier)
    for x, y in zip(a.frontier, b.frontier):
        assert_ppa_equal(x, y)


# ---------------------------------------------------------------------------
# Acceptance pin: fused N-spec search == N independent batched searches
# ---------------------------------------------------------------------------


class TestMultiSpecIdentity:
    def test_scenario_specs_bit_identical_to_per_spec_loop(self, tech):
        specs = list(scenario_specs().values())
        many = mso_search_many(specs, None, tech, resolution=4)
        assert len(many) == len(specs)
        for spec, res in zip(specs, many):
            ref = mso_search(spec, None, tech, resolution=4,
                             backend="batched")
            assert_search_identical(res, ref)

    def test_heterogeneous_lattice_shapes_group_and_match(self, tech):
        """Specs with different split axes / mode counts land in different
        vmap groups; results stay in input order and bit-identical."""
        base = pareto_experiment_spec()
        specs = [base,
                 dataclasses.replace(base, h=8, w=16),        # 2-split lattice
                 dataclasses.replace(base, fp_precisions=("FP8",)),  # 3 modes
                 dataclasses.replace(base, mcr=4),
                 dataclasses.replace(base, vdd=0.7, f_mac_hz=300e6)]
        many = mso_search_many(specs, None, tech, resolution=4)
        for spec, res in zip(specs, many):
            assert res.spec == spec
            assert_search_identical(
                res, mso_search_batched(spec, None, tech, resolution=4))

    def test_single_spec_group_matches(self, tech):
        spec = pareto_experiment_spec()
        (res,) = mso_search_many([spec], None, tech, resolution=4)
        assert_search_identical(
            res, mso_search_batched(spec, None, tech, resolution=4))

    def test_empty_spec_list(self, tech):
        assert mso_search_many([], None, tech) == []

    def test_requires_tech(self):
        with pytest.raises(ValueError):
            mso_search_many([pareto_experiment_spec()], None, None)


class TestEvaluateMany:
    def test_lattice_arrays_bit_identical(self, tech):
        """The fused evaluation's roll-up arrays equal the single-spec
        engine's for every lattice point (NaNs in invalid lanes included)."""
        scen = scenario_specs()
        specs = [scen["vision"], scen["cloud"]]
        evals = evaluate_many(specs, tech)
        for spec, (lattice, tables, ppa) in zip(specs, evals):
            ref = design_space_sweep(spec, tech).ppa
            for fld in ("mac", "sa", "ofu", "crit", "fmax", "area",
                        "latency", "tops_1b", "tops_mm2"):
                assert np.array_equal(getattr(ppa, fld), getattr(ref, fld),
                                      equal_nan=True), fld
            assert np.array_equal(ppa.meets, ref.meets)
            assert set(ppa.e_cycle) == set(ref.e_cycle)
            for m in ppa.e_cycle:
                assert np.array_equal(ppa.e_cycle[m], ref.e_cycle[m],
                                      equal_nan=True), m
            for m in ppa.tops_w:
                assert np.array_equal(ppa.tops_w[m], ref.tops_w[m],
                                      equal_nan=True), m

    def test_sweep_many_frontiers_match_single(self, tech):
        scen = scenario_specs()
        specs = [scen["vision"], scen["wearable"]]
        sweeps = design_space_sweep_many(specs, tech)
        for spec, sweep in zip(specs, sweeps):
            single = design_space_sweep(spec, tech)
            assert sweep.frontier_indices() == single.frontier_indices()


# ---------------------------------------------------------------------------
# Scenario specs + Pareto chunk sizing
# ---------------------------------------------------------------------------


class TestScenarioSpecs:
    def test_four_distinct_valid_scenarios(self):
        specs = scenario_specs()
        assert set(specs) == {"vision", "language", "cloud", "wearable"}
        assert len({(s.mcr, s.f_mac_hz, s.vdd) for s in specs.values()}) == 4

    def test_one_vmap_group(self, tech):
        """The scenario set is deliberately one vmap group (shared geometry)."""
        from repro.core.multispec import _group_key
        from repro.core.batched import DesignLattice, SpecTables
        import repro.core.subcircuits as sc
        keys = set()
        for s in scenario_specs().values():
            lat = DesignLattice.enumerate(s, (sc.MemCellKind.SRAM_6T,))
            keys.add(_group_key(lat, SpecTables(s, tech)))
        assert len(keys) == 1


class TestParetoChunkSize:
    def test_bounds(self):
        assert pareto_chunk_size(0) == 64
        assert pareto_chunk_size(100) == 100          # never above n_points
        assert pareto_chunk_size(10**9) == 64         # floor under huge n
        big = pareto_chunk_size(10_000)
        assert 64 <= big <= 10_000

    def test_budget_scales_chunk(self):
        small = pareto_chunk_size(100_000, budget_bytes=1 << 20)
        large = pareto_chunk_size(100_000, budget_bytes=1 << 30)
        assert small == 64                            # floored tiny budget
        assert large == (1 << 30) // (100_000 * 5)    # footprint-bounded

    def test_mask_invariant_under_sized_chunk(self):
        rng = np.random.default_rng(3)
        objs = rng.uniform(0.1, 10.0, size=(500, 3))
        chunk = pareto_chunk_size(len(objs), budget_bytes=1 << 16)
        assert chunk < 500
        assert np.array_equal(B.pareto_mask(objs, chunk=chunk),
                              B.pareto_mask(objs, chunk=512))


# ---------------------------------------------------------------------------
# Serving-time macro selection
# ---------------------------------------------------------------------------


def _toy_workloads():
    return {
        "vision": [GemmShape("conv_as_gemm", 196, 512, 512, 4),
                   GemmShape("head", 196, 512, 1000)],
        "language": [GemmShape("qkv", 128, 2048, 6144, 16),
                     GemmShape("mlp", 128, 2048, 8192, 16)],
    }


class TestServingSelection:
    @pytest.fixture(scope="class")
    def selection(self, tech):
        return select_macros(_toy_workloads(), tech=tech, resolution=3,
                             n_macros=64)

    def test_assignment_covers_workloads(self, selection):
        assert set(selection.assignment) == set(_toy_workloads())
        assert set(selection.workloads) == set(_toy_workloads())

    def test_assigned_macro_minimizes_wallclock(self, selection):
        for w in selection.workloads:
            wi = selection.codesign.workloads.index(w)
            di = selection.assignment[w]
            assert selection.codesign.wallclock_s[wi, di] == \
                selection.codesign.wallclock_s[wi].min()

    def test_pool_is_frontier_union(self, selection, tech):
        results = mso_search_many(
            [scenario_specs()[n] for n in selection.scenarios], None, tech,
            resolution=3)
        expect = frontier_union(results)
        assert [p.design.name() for p in selection.pool] == \
               [p.design.name() for p in expect]
        assert len(selection.pool_labels) == len(selection.pool)
        for lbl in selection.pool_labels:
            scen, _, design = lbl.partition("/")
            assert scen in selection.scenarios and design

    def test_labels_and_ppa_accessors(self, selection):
        for w in selection.workloads:
            assert selection.label_for(w) == \
                selection.pool_labels[selection.assignment[w]]
            assert selection.ppa_for(w) is selection.pool[selection.assignment[w]]
        s = selection.summary()
        assert s["candidates"] == len(selection.pool)
        assert set(s["assignment"]) == set(selection.workloads)

    def test_rejects_empty_workloads(self, tech):
        with pytest.raises(ValueError):
            select_macros({}, tech=tech)

    def test_frontier_union_keeps_same_name_across_specs(self, tech):
        """Identical design names synthesized for different specs are
        distinct serving candidates (a name does not encode its spec)."""
        scen = scenario_specs()
        results = mso_search_many([scen["vision"], scen["language"]], None,
                                  tech, resolution=3)
        pool = frontier_union(results)
        names = [p.design.name() for p in pool]
        shared = set(p.design.name() for p in results[0].frontier) \
            & set(p.design.name() for p in results[1].frontier)
        for nm in shared:
            assert names.count(nm) == 2

    def test_frontier_union_extract_pools_frontier(self, tech):
        """extract=True filters the pooled candidates to the *pooled* Pareto
        frontier (searcher objectives, shared eps band), keeping pool and
        labels in sync and preserving pool order; extract=False keeps every
        per-spec frontier point (the serving default)."""
        from repro.core.pareto import nondominated_mask
        scen = scenario_specs()
        results = mso_search_many(list(scen.values()), None, tech,
                                  resolution=3)
        names = list(scen)
        pool, labels = frontier_union(results, names)
        extracted, xlabels = frontier_union(results, names, extract=True)
        assert len(extracted) == len(xlabels) <= len(pool)
        # exactly the host-mask survivors of the pooled objective matrix,
        # in pool order
        objs = np.asarray([(p.e_cycle_fj["int_lo"], p.area_um2,
                            1.0 / p.fmax_hz) for p in pool])
        mask = nondominated_mask(objs)
        assert [id(p) for p in extracted] == \
            [id(p) for p, keep in zip(pool, mask) if keep]
        assert xlabels == [lb for lb, keep in zip(labels, mask) if keep]
        # every survivor is genuinely non-dominated within the pool
        kept = np.asarray([(p.e_cycle_fj["int_lo"], p.area_um2,
                            1.0 / p.fmax_hz) for p in extracted])
        assert nondominated_mask(kept).all()
