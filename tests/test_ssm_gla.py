"""Property tests for the chunked decayed-linear-attention substrate — the
recurrence under RWKV6 (exclusive + bonus) and Mamba2 (inclusive).

The chunked evaluation must match the sequential per-token recurrence
EXACTLY (up to f32 roundoff) for every convention, chunk size, and
decay regime — this is the invariant that guarantees train/prefill/decode
consistency for the SSM/hybrid architectures (a real bug here was caught by
tests/test_training.py::test_prefill_decode_matches_forward)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.ssm import causal_conv1d, chunked_gla, gla_decode_step


def _seq_ref(q, k, v, lw, u, inclusive):
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    s = jnp.zeros((b, h, dk, dv))
    ys = []
    for i in range(t):
        y, s = gla_decode_step(q[:, i], k[:, i], v[:, i], lw[:, i], s,
                               u=u, inclusive=inclusive)
        ys.append(y)
    return jnp.stack(ys, 1), s


@pytest.mark.parametrize("inclusive,use_u", [(True, False), (False, True),
                                             (False, False)])
@pytest.mark.parametrize("t,chunk", [(16, 16), (37, 16), (64, 8), (5, 32)])
def test_chunked_matches_sequential(inclusive, use_u, t, chunk):
    rng = np.random.default_rng(42)
    b, h, dk, dv = 2, 3, 8, 8
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0.01, 0.5, size=(b, t, h, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32) if use_u else None
    y1, s1 = chunked_gla(q, k, v, lw, u=u, inclusive=inclusive, chunk=chunk)
    y2, s2 = _seq_ref(q, k, v, lw, u, inclusive)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 48),
       chunk=st.sampled_from([4, 8, 16]),
       decay=st.floats(0.0, 2.0))
@settings(max_examples=15, deadline=None)
def test_property_chunked_gla(seed, t, chunk, decay):
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 1, 2, 4, 4
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0, decay, size=(b, t, h, dk)), jnp.float32)
    y1, s1 = chunked_gla(q, k, v, lw, inclusive=True, chunk=chunk)
    y2, s2 = _seq_ref(q, k, v, lw, None, True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)


def test_state_continuation():
    """Splitting a sequence across two chunked_gla calls with state handoff
    equals one pass."""
    rng = np.random.default_rng(3)
    b, t, h, dk, dv = 1, 32, 2, 4, 4
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)), jnp.float32)
    lw = jnp.asarray(-rng.uniform(0.01, 0.3, size=(b, t, h, dk)), jnp.float32)
    y_full, s_full = chunked_gla(q, k, v, lw, inclusive=True, chunk=8)
    y1, s1 = chunked_gla(q[:, :16], k[:, :16], v[:, :16], lw[:, :16],
                         inclusive=True, chunk=8)
    y2, s2 = chunked_gla(q[:, 16:], k[:, 16:], v[:, 16:], lw[:, 16:],
                         inclusive=True, chunk=8, s0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4)


def test_causal_conv1d_decode_matches_train():
    rng = np.random.default_rng(0)
    b, t, d, ksz = 2, 12, 6, 4
    x = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(ksz, d)), jnp.float32)
    y_full, _ = causal_conv1d(x, w)
    cache = None
    ys = []
    for i in range(t):
        y, cache = causal_conv1d(x[:, i:i + 1], w, cache)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
