"""Batched design-space engine vs the scalar reference oracle.

The contract under test (repro.core.batched): the vectorized lattice roll-up
is bit-identical to macro.rollup, the masked-selection replay of Algorithm 1
returns exactly the scalar mso_search frontier, the vectorized Pareto
extraction agrees with pareto.pareto_front, and the batched workload x design
DSE map equals per-design accelerator_report."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (GemmShape, SubcircuitLibrary, accelerator_report,
                        batched_workload_matrix, calibrated_tech_for_reference,
                        cross_workload_codesign, design_space_sweep,
                        mso_search, mso_search_batched,
                        pareto_experiment_spec, pareto_front, pareto_indices,
                        pareto_mask, reference_chip_ppa, reference_chip_spec,
                        rollup)


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


@pytest.fixture(scope="module")
def scl(tech):
    return SubcircuitLibrary(tech).build()


def assert_ppa_equal(a, b):
    """Bit-exact equality of every scalar field of two MacroPPAs."""
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.tops_1b == b.tops_1b
    assert a.tops_per_w_1b == b.tops_per_w_1b
    assert a.tops_per_mm2_1b == b.tops_per_mm2_1b
    assert a.meets_timing == b.meets_timing


# ---------------------------------------------------------------------------
# Frontier identity (the acceptance contract)
# ---------------------------------------------------------------------------


class TestFrontierIdentity:
    @pytest.mark.parametrize("resolution", [5, 6])
    def test_identical_to_scalar_on_pareto_spec(self, tech, scl, resolution):
        spec = pareto_experiment_spec()
        a = mso_search(spec, scl, tech, resolution=resolution)
        b = mso_search_batched(spec, scl, tech, resolution=resolution)
        assert a.n_evaluated == b.n_evaluated
        assert [p.design.name() for p in a.explored] == \
               [p.design.name() for p in b.explored]
        assert len(a.frontier) == len(b.frontier)
        for x, y in zip(a.frontier, b.frontier):
            assert_ppa_equal(x, y)

    @pytest.mark.parametrize("variant", ["mcr4", "hard", "lowv", "small"])
    def test_identical_on_spec_variants(self, tech, scl, variant):
        spec = {
            "mcr4": dataclasses.replace(pareto_experiment_spec(), mcr=4),
            "hard": dataclasses.replace(pareto_experiment_spec(), h=256,
                                        w=256, f_mac_hz=1.0e9),
            "lowv": dataclasses.replace(pareto_experiment_spec(), vdd=0.7,
                                        f_mac_hz=300e6),
            "small": dataclasses.replace(pareto_experiment_spec(), h=8, w=16),
        }[variant]
        a = mso_search(spec, scl, tech, resolution=5)
        b = mso_search_batched(spec, scl, tech, resolution=5)
        assert [p.design.name() for p in a.explored] == \
               [p.design.name() for p in b.explored]
        for x, y in zip(a.frontier, b.frontier):
            assert_ppa_equal(x, y)

    def test_backend_dispatch(self, tech, scl):
        spec = pareto_experiment_spec()
        res = mso_search(spec, scl, tech, resolution=5, backend="batched")
        assert res.n_evaluated >= 4
        with pytest.raises(ValueError):
            mso_search(spec, scl, tech, backend="quantum")


# ---------------------------------------------------------------------------
# Vectorized roll-up == scalar rollup across the lattice
# ---------------------------------------------------------------------------


class TestBatchedRollup:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_lattice_matches_scalar_rollup(self, tech, seed):
        spec = pareto_experiment_spec()
        sweep = design_space_sweep(spec, tech)
        rng = np.random.default_rng(seed)
        for i in rng.choice(len(sweep.lattice), 8, replace=False):
            i = int(i)
            if not sweep.lattice.valid[i]:
                continue
            batched = sweep.ppa.materialize(i)
            scalar = rollup(batched.design, tech)
            assert_ppa_equal(batched, scalar)

    def test_reference_chip_point(self, tech):
        """The silicon-calibrated reference design lives on the lattice of
        its spec and rolls up to the measured anchors."""
        ref = reference_chip_ppa()
        sweep = design_space_sweep(reference_chip_spec(), tech)
        lat = sweep.lattice
        match = [i for i in range(len(lat))
                 if lat.design_at(i).name() == ref.design.name()
                 and bool(lat.ort[i]) == ref.design.ofu_retimed_into_sa]
        assert match
        b = sweep.ppa.materialize(match[0])
        assert b.fmax_hz == pytest.approx(1.1e9, rel=1e-6)
        assert b.area_um2 / 1e6 == pytest.approx(0.112, rel=1e-3)

    def test_sweep_frontier_feasible_and_nondominated(self, tech):
        sweep = design_space_sweep(pareto_experiment_spec(), tech)
        idx = sweep.frontier_indices()
        assert idx, "frontier never empty"
        objs = sweep.objectives()
        valid = np.flatnonzero(sweep.lattice.valid & sweep.ppa.meets)
        for i in idx:
            assert sweep.ppa.meets[i]
            for j in valid:
                assert not (np.all(objs[j] <= objs[i] - 1e-12)
                            and np.any(objs[j] < objs[i] - 1e-12))


# ---------------------------------------------------------------------------
# Vectorized Pareto extraction == scalar pareto_front
# ---------------------------------------------------------------------------


class TestVectorizedPareto:
    @given(pts=st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10),
                                  st.floats(0.1, 10)),
                        min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_mask_agrees_with_pareto_front(self, pts):
        objs = np.asarray(pts, dtype=np.float64)
        mask = pareto_mask(objs)
        front = pareto_front(pts, lambda p: p)
        # every scalar-front member survives the vectorized mask
        front_set = {tuple(p) for p in front}
        kept = {tuple(objs[i]) for i in np.flatnonzero(mask)}
        assert front_set <= kept
        # and every masked survivor is non-dominated
        for i in np.flatnonzero(mask):
            for j in range(len(pts)):
                assert not (np.all(objs[j] <= objs[i] + 1e-12)
                            and np.any(objs[j] < objs[i] - 1e-12))

    @given(pts=st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                        min_size=1, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_pareto_indices_matches_pareto_front(self, pts):
        front = pareto_front(pts, lambda p: p)
        via_idx = [pts[i] for i in pareto_indices(pts)]
        assert front == via_idx

    def test_chunking_invariant(self):
        rng = np.random.default_rng(0)
        objs = rng.uniform(0.1, 10.0, size=(300, 3))
        m1 = pareto_mask(objs, chunk=7)
        m2 = pareto_mask(objs, chunk=512)
        assert np.array_equal(m1, m2)


# ---------------------------------------------------------------------------
# Batched DSE == scalar accelerator_report
# ---------------------------------------------------------------------------


def _toy_workloads():
    return {
        "vision": [GemmShape("conv_as_gemm", 196, 512, 512, 4),
                   GemmShape("head", 196, 512, 1000)],
        "language": [GemmShape("qkv", 128, 2048, 6144, 16),
                     GemmShape("mlp", 128, 2048, 8192, 16)],
        "moe": [GemmShape("router", 64, 1024, 8),
                GemmShape("expert", 64, 1024, 4096, 8)],
    }


class TestBatchedDSE:
    @pytest.fixture(scope="class")
    def ppas(self, tech):
        res = mso_search_batched(pareto_experiment_spec(), None, tech,
                                 resolution=5)
        return [reference_chip_ppa()] + list(res.explored)

    def test_matrix_matches_scalar_reports(self, ppas):
        for name, gemms in _toy_workloads().items():
            mat = batched_workload_matrix(gemms, ppas, n_macros=64)
            for d, ppa in enumerate(ppas):
                rep = accelerator_report(list(gemms), ppa, n_macros=64)
                assert mat.total_cycles[d] == rep.total_cycles
                assert mat.total_energy_pj[d] == rep.total_energy_pj
                assert mat.wallclock_s[d] == rep.wallclock_s
                assert mat.effective_tops[d] == rep.effective_tops
                assert mat.avg_util[d] == rep.avg_util
                assert mat.area_mm2[d] == rep.area_mm2
                for g, r in enumerate(rep.reports):
                    assert mat.cycles[g, d] == r.cycles
                    assert mat.energy_pj[g, d] == r.energy_pj
                    assert mat.weight_reloads[g, d] == r.weight_reloads
                    assert mat.util[g, d] == r.util

    @given(m=st.integers(1, 512), k=st.integers(1, 8192),
           n=st.integers(1, 8192), count=st.integers(1, 64),
           n_macros=st.sampled_from([4, 64, 256]))
    @settings(max_examples=25, deadline=None)
    def test_single_gemm_property(self, ppas, m, k, n, count, n_macros):
        g = GemmShape("rand", m, k, n, count)
        mat = batched_workload_matrix([g], ppas, n_macros=n_macros)
        for d, ppa in enumerate(ppas):
            rep = accelerator_report([g], ppa, n_macros=n_macros)
            assert mat.total_cycles[d] == rep.total_cycles
            assert mat.total_energy_pj[d] == rep.total_energy_pj
            assert mat.effective_tops[d] == rep.effective_tops

    def test_codesign_frontier(self, ppas):
        report = cross_workload_codesign(_toy_workloads(), ppas, n_macros=64)
        assert report.workloads == ("vision", "language", "moe")
        assert len(report.frontier) >= 1
        objs = [(report.total_wallclock_s[d], report.total_energy_pj[d],
                 report.area_mm2[d]) for d in range(len(ppas))]
        expect = tuple(pareto_indices(objs))
        assert report.frontier == expect
        # best_for picks the fastest design per workload
        for w in report.workloads:
            d = report.best_for(w)
            wi = report.workloads.index(w)
            assert report.wallclock_s[wi, d] == report.wallclock_s[wi].min()
