"""Batched design-space engine vs the scalar reference oracle.

The contract under test (repro.core.batched): the vectorized lattice roll-up
is bit-identical to macro.rollup, the masked-selection replay of Algorithm 1
returns exactly the scalar mso_search frontier, the vectorized Pareto
extraction agrees with pareto.pareto_front, and the batched workload x design
DSE map equals per-design accelerator_report."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (PARETO_EPS, GemmShape, SubcircuitLibrary,
                        accelerator_report, batched_workload_matrix,
                        calibrated_tech_for_reference,
                        cross_workload_codesign, design_space_sweep,
                        dominates, mso_search, mso_search_batched,
                        nondominated_mask, pareto_experiment_spec,
                        pareto_front, pareto_indices, pareto_mask,
                        preference_grid, reference_chip_design,
                        reference_chip_ppa, reference_chip_spec,
                        reporting_frequency, rollup)


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


@pytest.fixture(scope="module")
def scl(tech):
    return SubcircuitLibrary(tech).build()


def assert_ppa_equal(a, b):
    """Bit-exact equality of every scalar field of two MacroPPAs."""
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.tops_1b == b.tops_1b
    assert a.tops_per_w_1b == b.tops_per_w_1b
    assert a.tops_per_mm2_1b == b.tops_per_mm2_1b
    assert a.meets_timing == b.meets_timing


# ---------------------------------------------------------------------------
# Frontier identity (the acceptance contract)
# ---------------------------------------------------------------------------


class TestFrontierIdentity:
    @pytest.mark.parametrize("resolution", [5, 6])
    def test_identical_to_scalar_on_pareto_spec(self, tech, scl, resolution):
        spec = pareto_experiment_spec()
        a = mso_search(spec, scl, tech, resolution=resolution)
        b = mso_search_batched(spec, scl, tech, resolution=resolution)
        assert a.n_evaluated == b.n_evaluated
        assert [p.design.name() for p in a.explored] == \
               [p.design.name() for p in b.explored]
        assert len(a.frontier) == len(b.frontier)
        for x, y in zip(a.frontier, b.frontier):
            assert_ppa_equal(x, y)

    @pytest.mark.parametrize("variant", ["mcr4", "hard", "lowv", "small"])
    def test_identical_on_spec_variants(self, tech, scl, variant):
        spec = {
            "mcr4": dataclasses.replace(pareto_experiment_spec(), mcr=4),
            "hard": dataclasses.replace(pareto_experiment_spec(), h=256,
                                        w=256, f_mac_hz=1.0e9),
            "lowv": dataclasses.replace(pareto_experiment_spec(), vdd=0.7,
                                        f_mac_hz=300e6),
            "small": dataclasses.replace(pareto_experiment_spec(), h=8, w=16),
        }[variant]
        a = mso_search(spec, scl, tech, resolution=5)
        b = mso_search_batched(spec, scl, tech, resolution=5)
        assert [p.design.name() for p in a.explored] == \
               [p.design.name() for p in b.explored]
        for x, y in zip(a.frontier, b.frontier):
            assert_ppa_equal(x, y)

    def test_backend_dispatch(self, tech, scl):
        spec = pareto_experiment_spec()
        res = mso_search(spec, scl, tech, resolution=5, backend="batched")
        assert res.n_evaluated >= 4
        with pytest.raises(ValueError):
            mso_search(spec, scl, tech, backend="quantum")


# ---------------------------------------------------------------------------
# Vectorized roll-up == scalar rollup across the lattice
# ---------------------------------------------------------------------------


class TestBatchedRollup:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_lattice_matches_scalar_rollup(self, tech, seed):
        spec = pareto_experiment_spec()
        sweep = design_space_sweep(spec, tech)
        rng = np.random.default_rng(seed)
        for i in rng.choice(len(sweep.lattice), 8, replace=False):
            i = int(i)
            if not sweep.lattice.valid[i]:
                continue
            batched = sweep.ppa.materialize(i)
            scalar = rollup(batched.design, tech)
            assert_ppa_equal(batched, scalar)

    def test_reference_chip_point(self, tech):
        """The silicon-calibrated reference design lives on the lattice of
        its spec and rolls up to the measured anchors."""
        ref = reference_chip_ppa()
        sweep = design_space_sweep(reference_chip_spec(), tech)
        lat = sweep.lattice
        match = [i for i in range(len(lat))
                 if lat.design_at(i).name() == ref.design.name()
                 and bool(lat.ort[i]) == ref.design.ofu_retimed_into_sa]
        assert match
        b = sweep.ppa.materialize(match[0])
        assert b.fmax_hz == pytest.approx(1.1e9, rel=1e-6)
        assert b.area_um2 / 1e6 == pytest.approx(0.112, rel=1e-3)

    def test_sweep_frontier_feasible_and_nondominated(self, tech):
        sweep = design_space_sweep(pareto_experiment_spec(), tech)
        idx = sweep.frontier_indices()
        assert idx, "frontier never empty"
        objs = sweep.objectives()
        valid = np.flatnonzero(sweep.lattice.valid & sweep.ppa.meets)
        for i in idx:
            assert sweep.ppa.meets[i]
            for j in valid:
                assert not (np.all(objs[j] <= objs[i] - 1e-12)
                            and np.any(objs[j] < objs[i] - 1e-12))


# ---------------------------------------------------------------------------
# Vectorized Pareto extraction == scalar pareto_front
# ---------------------------------------------------------------------------


class TestVectorizedPareto:
    @given(pts=st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10),
                                  st.floats(0.1, 10)),
                        min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_mask_agrees_with_pareto_front(self, pts):
        objs = np.asarray(pts, dtype=np.float64)
        mask = pareto_mask(objs)
        front = pareto_front(pts, lambda p: p)
        # every scalar-front member survives the vectorized mask
        front_set = {tuple(p) for p in front}
        kept = {tuple(objs[i]) for i in np.flatnonzero(mask)}
        assert front_set <= kept
        # and every masked survivor is non-dominated
        for i in np.flatnonzero(mask):
            for j in range(len(pts)):
                assert not (np.all(objs[j] <= objs[i] + 1e-12)
                            and np.any(objs[j] < objs[i] - 1e-12))

    @given(pts=st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                        min_size=1, max_size=40))
    @settings(max_examples=40, deadline=None)
    def test_pareto_indices_matches_pareto_front(self, pts):
        front = pareto_front(pts, lambda p: p)
        via_idx = [pts[i] for i in pareto_indices(pts)]
        assert front == via_idx

    def test_chunking_invariant(self):
        rng = np.random.default_rng(0)
        objs = rng.uniform(0.1, 10.0, size=(300, 3))
        m1 = pareto_mask(objs, chunk=7)
        m2 = pareto_mask(objs, chunk=512)
        assert np.array_equal(m1, m2)


# ---------------------------------------------------------------------------
# Unified tie/epsilon semantics: one PARETO_EPS for every frontier path
# ---------------------------------------------------------------------------


def _near_dup_points(base_pts, jitters):
    """Adversarial near-duplicate cloud: every base point plus copies jittered
    right around the PARETO_EPS band (inside, at, and outside it)."""
    out = []
    for p in base_pts:
        out.append(tuple(p))
        for j in jitters:
            out.append(tuple(x + j for x in p))
    return out


class TestUnifiedEpsilonSemantics:
    def test_shared_constant(self):
        import inspect
        from repro.core import batched, pareto
        assert pareto.PARETO_EPS == 1e-12
        sig = inspect.signature(batched.pareto_mask)
        assert sig.parameters["eps"].default is pareto.PARETO_EPS
        assert inspect.signature(dominates).parameters["eps"].default \
            is pareto.PARETO_EPS

    @given(base=st.lists(st.tuples(st.floats(0.5, 2.0), st.floats(0.5, 2.0),
                                   st.floats(0.5, 2.0)),
                         min_size=1, max_size=12),
           jitter=st.sampled_from([0.0, 3e-13, -3e-13, 9e-13, 2e-12, -2e-12]))
    @settings(max_examples=40, deadline=None)
    def test_pareto_indices_equiv_pareto_mask(self, base, jitter):
        """pareto_indices ≡ pareto_mask on adversarial near-duplicates: same
        dominance verdicts through the one shared eps band; the only
        difference is pareto_indices' documented duplicate collapse."""
        pts = _near_dup_points(base, [jitter, 2 * jitter, 5e-13, -5e-13])
        objs = np.asarray(pts, dtype=np.float64)
        mask_batched = pareto_mask(objs)
        mask_scalar = nondominated_mask(objs)
        # the jax-chunked and numpy masks are the same predicate
        assert np.array_equal(mask_batched, mask_scalar)
        # ... and both match the per-pair scalar dominates() verdicts
        for i in range(len(pts)):
            expect = not any(dominates(pts[j], pts[i])
                             for j in range(len(pts)))
            assert mask_scalar[i] == expect
        idx = pareto_indices(pts)
        # every frontier member survives the mask
        assert all(mask_scalar[i] for i in idx)
        # every mask survivor is a frontier member or a collapsed near-dup
        chosen = objs[idx] if idx else np.empty((0, 3))
        for i in np.flatnonzero(mask_scalar):
            assert i in idx or (
                np.abs(chosen - objs[i]) < PARETO_EPS).all(axis=1).any()

    def test_pareto_indices_scales_to_10k_frontier(self):
        """Regression: pareto_indices at lattice scale (the per-pair Python
        walk was O(N^2) and effectively hung here).  A 2-D anti-chain keeps
        all 10k points non-dominated — the worst case for the frontier walk —
        and the vectorized path must agree with the mask exactly."""
        n = 10_000
        x = np.linspace(0.0, 1.0, n)
        objs = np.stack([x, 1.0 - x], axis=1)
        idx = pareto_indices([tuple(o) for o in objs])
        assert len(idx) == n
        assert np.array_equal(np.sort(idx), np.arange(n))
        # documented order: sorted by objective tuple
        assert idx == sorted(idx, key=lambda i: tuple(objs[i]))
        # and a mixed case with a dominated half collapses correctly
        shifted = objs + 0.5
        both = np.concatenate([objs, shifted])
        idx2 = pareto_indices([tuple(o) for o in both])
        assert sorted(idx2) == list(range(n))

    def test_pareto_indices_dedup_keeps_first_occurrence(self):
        pts = [(2.0, 1.0), (1.0, 2.0), (1.0 + 2e-13, 2.0 - 2e-13),
               (1.0, 2.0)]
        idx = pareto_indices(pts)
        assert idx == [1, 0]      # sorted by objective; near-dups collapsed

    def test_empty_and_singleton(self):
        assert pareto_indices([]) == []
        assert pareto_indices([(1.0, 2.0)]) == [0]
        assert nondominated_mask(np.empty((0, 3))).shape == (0,)


# ---------------------------------------------------------------------------
# Frequency-clamp consistency: one reporting_frequency for every path
# ---------------------------------------------------------------------------


class TestReportingFrequencyClamp:
    @pytest.fixture(scope="class")
    def overdriven(self, tech):
        """A meets-timing design whose fmax exceeds its (relaxed) spec — the
        clamp must down-clock it to f_mac."""
        spec = dataclasses.replace(reference_chip_spec(), f_mac_hz=500e6,
                                   f_wupdate_hz=500e6)
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        ppa = rollup(d, tech)
        assert ppa.meets_timing and ppa.fmax_hz > spec.f_mac_hz
        return ppa

    @pytest.fixture(scope="class")
    def missing(self, tech):
        """A timing-missing design (impossible 5 GHz target): reported at its
        raw fmax, never clamped upward to the unreachable spec."""
        spec = dataclasses.replace(reference_chip_spec(), f_mac_hz=5e9,
                                   f_wupdate_hz=5e9)
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        ppa = rollup(d, tech)
        assert not ppa.meets_timing and ppa.fmax_hz < spec.f_mac_hz
        return ppa

    def test_helper_semantics(self, overdriven, missing):
        f_over = float(reporting_frequency(
            overdriven.fmax_hz, overdriven.design.spec.f_mac_hz,
            overdriven.meets_timing))
        assert f_over == overdriven.design.spec.f_mac_hz
        f_miss = float(reporting_frequency(
            missing.fmax_hz, missing.design.spec.f_mac_hz,
            missing.meets_timing))
        assert f_miss == missing.fmax_hz
        # vectorized call gives the same two answers in one shot
        both = reporting_frequency(
            [overdriven.fmax_hz, missing.fmax_hz],
            [overdriven.design.spec.f_mac_hz, missing.design.spec.f_mac_hz],
            [True, False])
        assert both.tolist() == [f_over, f_miss]

    @pytest.mark.parametrize("which", ["overdriven", "missing"])
    def test_scalar_and_batched_reports_clock_identically(self, which,
                                                          overdriven,
                                                          missing):
        ppa = {"overdriven": overdriven, "missing": missing}[which]
        gemms = [GemmShape("g0", 128, 1024, 1024, 2),
                 GemmShape("g1", 64, 512, 2048)]
        rep = accelerator_report(gemms, ppa, n_macros=64)
        mat = batched_workload_matrix(gemms, [ppa], n_macros=64)
        expect_f = (min(ppa.fmax_hz, ppa.design.spec.f_mac_hz)
                    if ppa.meets_timing else ppa.fmax_hz)
        assert rep.wallclock_s == rep.total_cycles / expect_f
        assert mat.wallclock_s[0] == rep.wallclock_s
        assert mat.effective_tops[0] == rep.effective_tops

    def test_lattice_engine_applies_same_clamp(self, tech, missing):
        """The batched lattice roll-up's reported throughput uses the same
        clamp: a timing-missing lattice point's tops_1b is computed at raw
        fmax, a met one at min(fmax, f_mac)."""
        sweep = design_space_sweep(missing.design.spec, tech)
        fmax = sweep.ppa.fmax
        spec_f = missing.design.spec.f_mac_hz
        f_rep = np.where(sweep.ppa.meets, np.minimum(fmax, spec_f), fmax)
        valid = sweep.lattice.valid
        expect = (2.0 * missing.design.spec.h * missing.design.spec.w
                  * f_rep) / 1e12
        assert np.array_equal(sweep.ppa.tops_1b[valid], expect[valid])


# ---------------------------------------------------------------------------
# preference_grid + codesign invariance properties
# ---------------------------------------------------------------------------


class TestPreferenceGridProperties:
    @given(resolution=st.integers(1, 8))
    @settings(max_examples=8, deadline=None)
    def test_weights_on_simplex_no_zero_vector(self, resolution):
        grid = preference_grid(resolution)
        assert len(grid) == (resolution + 1) * (resolution + 2) // 2
        assert len(set(grid)) == len(grid)
        for w in grid:
            assert len(w) == 3
            assert all(0.0 <= x <= 1.0 for x in w)
            assert sum(w) == pytest.approx(1.0, abs=1e-9)
            assert any(x > 0 for x in w)

    def test_zero_resolution_rejected(self):
        """Regression: preference_grid(0) used to return an empty grid that
        silently yielded empty sweeps downstream; it must refuse instead."""
        for resolution in (0, -1, -7):
            with pytest.raises(ValueError, match="resolution >= 1"):
                preference_grid(resolution)


class TestCodesignPermutationInvariance:
    @pytest.fixture(scope="class")
    def ppas(self, tech):
        res = mso_search_batched(pareto_experiment_spec(), None, tech,
                                 resolution=4)
        return [reference_chip_ppa()] + list(res.explored)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_frontier_invariant_under_workload_permutation(self, ppas, seed):
        base = {
            "vision": [GemmShape("conv", 196, 512, 512, 4)],
            "language": [GemmShape("qkv", 128, 2048, 6144, 8)],
            "moe": [GemmShape("expert", 64, 1024, 4096, 8)],
            "speech": [GemmShape("enc", 96, 384, 1536, 4)],
        }
        rng = np.random.default_rng(seed)
        names = list(base)
        perm = [names[i] for i in rng.permutation(len(names))]
        a = cross_workload_codesign(base, ppas, n_macros=64)
        b = cross_workload_codesign({n: base[n] for n in perm}, ppas,
                                    n_macros=64)
        assert b.workloads == tuple(perm)
        assert np.array_equal(a.total_wallclock_s, b.total_wallclock_s)
        assert np.array_equal(a.total_energy_pj, b.total_energy_pj)
        assert a.frontier == b.frontier
        for n in names:
            ai, bi = a.workloads.index(n), b.workloads.index(n)
            assert np.array_equal(a.wallclock_s[ai], b.wallclock_s[bi])
            assert np.array_equal(a.energy_pj[ai], b.energy_pj[bi])
            assert a.best_for(n) == b.best_for(n)


# ---------------------------------------------------------------------------
# Batched DSE == scalar accelerator_report
# ---------------------------------------------------------------------------


def _toy_workloads():
    return {
        "vision": [GemmShape("conv_as_gemm", 196, 512, 512, 4),
                   GemmShape("head", 196, 512, 1000)],
        "language": [GemmShape("qkv", 128, 2048, 6144, 16),
                     GemmShape("mlp", 128, 2048, 8192, 16)],
        "moe": [GemmShape("router", 64, 1024, 8),
                GemmShape("expert", 64, 1024, 4096, 8)],
    }


class TestBatchedDSE:
    @pytest.fixture(scope="class")
    def ppas(self, tech):
        res = mso_search_batched(pareto_experiment_spec(), None, tech,
                                 resolution=5)
        return [reference_chip_ppa()] + list(res.explored)

    def test_matrix_matches_scalar_reports(self, ppas):
        for name, gemms in _toy_workloads().items():
            mat = batched_workload_matrix(gemms, ppas, n_macros=64)
            for d, ppa in enumerate(ppas):
                rep = accelerator_report(list(gemms), ppa, n_macros=64)
                assert mat.total_cycles[d] == rep.total_cycles
                assert mat.total_energy_pj[d] == rep.total_energy_pj
                assert mat.wallclock_s[d] == rep.wallclock_s
                assert mat.effective_tops[d] == rep.effective_tops
                assert mat.avg_util[d] == rep.avg_util
                assert mat.area_mm2[d] == rep.area_mm2
                for g, r in enumerate(rep.reports):
                    assert mat.cycles[g, d] == r.cycles
                    assert mat.energy_pj[g, d] == r.energy_pj
                    assert mat.weight_reloads[g, d] == r.weight_reloads
                    assert mat.util[g, d] == r.util

    @given(m=st.integers(1, 512), k=st.integers(1, 8192),
           n=st.integers(1, 8192), count=st.integers(1, 64),
           n_macros=st.sampled_from([4, 64, 256]))
    @settings(max_examples=25, deadline=None)
    def test_single_gemm_property(self, ppas, m, k, n, count, n_macros):
        g = GemmShape("rand", m, k, n, count)
        mat = batched_workload_matrix([g], ppas, n_macros=n_macros)
        for d, ppa in enumerate(ppas):
            rep = accelerator_report([g], ppa, n_macros=n_macros)
            assert mat.total_cycles[d] == rep.total_cycles
            assert mat.total_energy_pj[d] == rep.total_energy_pj
            assert mat.effective_tops[d] == rep.effective_tops

    def test_codesign_frontier(self, ppas):
        report = cross_workload_codesign(_toy_workloads(), ppas, n_macros=64)
        assert report.workloads == ("vision", "language", "moe")
        assert len(report.frontier) >= 1
        objs = [(report.total_wallclock_s[d], report.total_energy_pj[d],
                 report.area_mm2[d]) for d in range(len(ppas))]
        expect = tuple(pareto_indices(objs))
        assert report.frontier == expect
        # best_for picks the fastest design per workload
        for w in report.workloads:
            d = report.best_for(w)
            wi = report.workloads.index(w)
            assert report.wallclock_s[wi, d] == report.wallclock_s[wi].min()
