"""Deterministic fallback for ``hypothesis`` when the real package is absent.

The tier-1 suite property-tests with hypothesis, but the pinned runtime image
does not ship it (and the suite must stay runnable offline).  This module
implements the small strategy surface the tests use — ``given``, ``settings``,
``integers``, ``floats``, ``booleans``, ``just``, ``sampled_from``, ``lists``,
``tuples`` — with a seeded PRNG per test so runs are reproducible.  CI installs
the real hypothesis from requirements-dev.txt and this file is never imported
there; ``conftest.install_hypothesis_fallback`` only registers it when
``import hypothesis`` fails.

Semantics intentionally kept: boundary values are drawn first (min/max for
integers and floats, min/max sizes for lists), then uniform samples.  No
shrinking — a failing example is reported verbatim by pytest.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """Base class: a strategy is anything with ``example(rng, i)`` where ``i``
    is the example index (used to emit boundary cases first)."""

    def example(self, rng: random.Random, i: int):
        raise NotImplementedError

    def map(self, fn):
        return _MappedStrategy(self, fn)


class _MappedStrategy(SearchStrategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rng, i):
        return self.fn(self.base.example(rng, i))


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2**31) if min_value is None else min_value
        self.hi = 2**31 - 1 if max_value is None else max_value

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=None, max_value=None, allow_nan=False,
                 allow_infinity=False, width=64):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def example(self, rng, i):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example(self, rng, i):
        if i < 2:
            return bool(i)
        return rng.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng, i):
        return self.value


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty collection")

    def example(self, rng, i):
        if i < len(self.elements):
            return self.elements[i]
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None, unique=False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = min_size + 10 if max_size is None else max_size
        self.unique = unique

    def example(self, rng, i):
        if i == 0:
            size = self.min_size
        elif i == 1:
            size = self.max_size
        else:
            size = rng.randint(self.min_size, self.max_size)
        out, guard = [], 0
        while len(out) < size and guard < size * 20 + 20:
            guard += 1
            v = self.elements.example(rng, 2 + len(out) + guard)
            if self.unique and v in out:
                continue
            out.append(v)
        return out


class _Tuples(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng, i):
        return tuple(s.example(rng, i) for s in self.strategies)


class _OneOf(SearchStrategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng, i):
        if i < len(self.strategies):
            return self.strategies[i].example(rng, i)
        return rng.choice(self.strategies).example(rng, i)


def _make_strategies_module() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")
    st.SearchStrategy = SearchStrategy
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.just = _Just
    st.sampled_from = _SampledFrom
    st.lists = _Lists
    st.tuples = _Tuples
    st.one_of = _OneOf
    return st


def settings(*args, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **kwargs):
    """Decorator recording ``max_examples``; other knobs are accepted and
    ignored (no shrinking/deadline enforcement in the fallback)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        bound = dict(kw_strategies)
        if pos_strategies:
            # Hypothesis maps positional strategies onto the rightmost
            # parameters (after self/fixtures).
            tail = names[len(names) - len(pos_strategies):]
            bound.update(zip(tail, pos_strategies))
        remaining = [p for p in sig.parameters.values()
                     if p.name not in bound]
        max_examples = getattr(fn, "_fallback_max_examples", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_fallback_max_examples", None)
                 or max_examples or DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = {k: s.example(rng, i) for k, s in bound.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example (draw {i}): {drawn!r}") from exc

        # pytest must only see the non-strategy parameters (fixtures/self).
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return deco


def assume(condition) -> bool:
    """Real hypothesis aborts the example; the fallback treats a failed
    assumption as a no-op pass for that draw."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    function_scoped_fixture = "function_scoped_fixture"


def install() -> types.ModuleType:
    """Register the fallback as ``hypothesis`` in sys.modules."""
    mod = types.ModuleType("hypothesis")
    st = _make_strategies_module()
    mod.strategies = st
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return mod
