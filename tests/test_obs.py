"""Tests for :mod:`repro.obs` — tracing, metrics, exporters — and the
instrumentation threaded through the serving stack.

The contract under test has three legs:

  * **spans tell the truth**: the frontend's request-lifecycle span
    boundaries *equal* the ``SynthesisResponse`` timestamps (same clock,
    same values — not approximations), cache-tier spans exist only for
    tiers actually probed, and every request coalesced onto one fused
    engine pass cross-links the same ``engine.pass`` span;
  * **metrics stay compatible**: the components' ``telemetry()`` dicts
    keep byte-identical key sets now that their stats are
    :class:`~repro.obs.metrics.StatsView` registry views;
  * **observation is safe**: tracing off records nothing and costs a
    contextvar read; engine hooks that mutate the hook list mid-pass
    cannot skip or double-fire their peers.
"""

import json
import math
import os
import subprocess
import sys
import textwrap
import threading
from argparse import Namespace
from pathlib import Path

import pytest

from repro.core import calibrated_tech_for_reference, engine
from repro.core import batched as B
from repro.core.shardspec import spec_variants
from repro.obs import (NOOP_SPAN, MetricsRegistry, Tracer,
                       chrome_trace_events, configure, metrics_snapshot,
                       tracer, write_chrome_trace, write_spans_jsonl)
from repro.obs.metrics import Counter, Gauge, Histogram, StatsView
from repro.serve.config import (ServeConfig, load_serve_config,
                                save_serve_config, serve_config_from_args)
from repro.service import (FrontierCache, ServiceFrontend, SynthesisRequest,
                           SynthesisService)
from repro.service.cache import CacheStats
from repro.service.frontend import FrontendStats
from repro.service.registry import ArtifactRegistry, RegistryStats
from repro.service.service import ServiceStats

REPO = Path(__file__).resolve().parent.parent
TECH = calibrated_tech_for_reference()


@pytest.fixture
def traced():
    """Global tracer on at full sampling for one test, restored after."""
    configure(enabled=True, sample=1.0)
    tracer.clear()
    yield tracer
    tracer.configure(enabled=False)
    tracer.clear()


def _by_name(spans, name):
    return [s for s in spans if s.name == name]


# ---------------------------------------------------------------------------
# Metrics primitives
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter(self):
        c = Counter("x")
        assert c.value == 0
        c.inc()
        c.inc(3)
        assert c.value == 4
        c.set(10)
        assert c.value == 10

    def test_gauge(self):
        g = Gauge("depth")
        g.set(7)
        assert g.value == 7.0

    def test_histogram_quantiles(self):
        h = Histogram("lat")
        assert h.summary() == {"count": 0, "sum": 0.0}
        assert h.quantile(0.5) == 0.0
        for v in (0.001, 0.002, 0.003, 0.004, 0.100):
            h.observe(v)
        s = h.summary()
        assert s["count"] == 5
        assert s["min"] == 0.001 and s["max"] == 0.100
        assert abs(s["sum"] - 0.110) < 1e-12
        # p50 lands in the low-millisecond buckets, p99 near the max
        assert 0.001 <= s["p50"] <= 0.005
        assert s["p99"] <= s["max"]
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_histogram_overflow_bucket(self):
        h = Histogram("big", bounds=(1.0, 2.0))
        h.observe(100.0)
        assert h.count == 1
        assert h.quantile(1.0) == 100.0

    def test_registry_get_or_create_and_type_guard(self):
        r = MetricsRegistry("t")
        c = r.counter("a")
        assert r.counter("a") is c
        with pytest.raises(TypeError):
            r.gauge("a")
        r.histogram("h").observe(0.5)
        d = r.as_dict()
        assert d["a"] == 0 and d["h"]["count"] == 1
        assert "a 0" in r.expose() and "h{count} 1" in r.expose()

    def test_metrics_snapshot_namespaces_components(self):
        reg = MetricsRegistry("obs_test_ns")
        reg.counter("obs_test_ns/hits").inc(3)
        snap = metrics_snapshot()
        line = next(ln for ln in snap.splitlines()
                    if ln.startswith("obs_test_ns[")
                    and ln.endswith("obs_test_ns/hits 3"))
        assert line


class TestStatsView:
    def test_view_reads_and_writes_through(self):
        class S(StatsView):
            _NAMESPACE = "s"
            _FIELDS = ("a", "b")

        s = S()
        assert s.a == 0 and s.b == 0
        s.a += 2                  # get-then-set through the counter
        s.b = 5
        assert s.as_dict() == {"a": 2, "b": 5}
        assert s.metrics.counter("s/a").value == 2
        with pytest.raises(AttributeError):
            s.nope

    def test_instances_do_not_share_counters(self):
        a, b = ServiceStats(), ServiceStats()
        a.requests += 3
        assert b.requests == 0


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_disabled_is_noop(self):
        t = Tracer()
        root = t.start_trace("request")
        assert root is NOOP_SPAN and not root
        with root as r:
            r.set_tag("k", "v")          # all no-ops, no crash
        assert t.spans() == []

    def test_child_without_parent_is_noop(self):
        t = Tracer().configure(enabled=True)
        assert t.span("orphan") is NOOP_SPAN
        assert t.spans() == []

    def test_nesting_and_ids(self):
        t = Tracer().configure(enabled=True)
        with t.start_trace("root", tags={"k": 1}) as root:
            with t.span("child") as child:
                assert child.span.trace_id == root.trace_id
                assert child.span.parent_id == root.span_id
                with t.span("grand") as g:
                    assert g.span.parent_id == child.span_id
        spans = t.drain()
        assert [s.name for s in spans] == ["grand", "child", "root"]
        assert spans[2].parent_id is None and spans[2].tags == {"k": 1}
        assert t.spans() == []               # drained

    def test_explicit_timestamps(self):
        t = Tracer().configure(enabled=True)
        root = t.start_trace("r", start_s=10.0)
        root.finish(end_s=12.5)
        (s,) = t.drain()
        assert s.start_s == 10.0 and s.end_s == 12.5
        assert s.duration_s == 2.5

    def test_exception_tags_error(self):
        t = Tracer().configure(enabled=True)
        with pytest.raises(RuntimeError):
            with t.start_trace("r"):
                with t.span("boom"):
                    raise RuntimeError("x")
        spans = t.drain()
        assert _by_name(spans, "boom")[0].tags["error"] == "RuntimeError"
        assert _by_name(spans, "r")[0].tags["error"] == "RuntimeError"

    def test_sampling_validated_and_applied(self):
        t = Tracer().configure(enabled=True)
        with pytest.raises(ValueError):
            t.configure(sample=0.0)
        with pytest.raises(ValueError):
            t.configure(sample=1.5)
        t.configure(sample=1e-9)
        roots = [t.start_trace("r") for _ in range(64)]
        assert all(r is NOOP_SPAN for r in roots)   # effectively never sampled
        t.configure(sample=1.0)
        assert t.start_trace("r") is not NOOP_SPAN

    def test_activate_cross_thread(self):
        t = Tracer().configure(enabled=True)
        root = t.start_trace("root")
        seen = {}

        def worker(ctx):
            with t.activate(ctx):
                with t.span("work") as w:
                    seen["trace"] = w.span.trace_id
                    seen["parent"] = w.span.parent_id

        th = threading.Thread(target=worker, args=(root.context,))
        th.start()
        th.join()
        root.finish()
        assert seen["trace"] == root.trace_id
        assert seen["parent"] == root.span_id

    def test_bounded_buffer_drops_and_counts(self):
        t = Tracer().configure(enabled=True)
        t.MAX_SPANS = 2
        with t.start_trace("root") as root:
            for _ in range(4):
                with t.span("s"):
                    pass
        assert len(t.spans()) == 2
        assert root.span.end_s is not None   # finish still safe past the cap

    def test_finish_idempotent(self):
        t = Tracer().configure(enabled=True)
        root = t.start_trace("r")
        root.finish(end_s=1.0)
        root.finish(end_s=9.0)               # second finish is a no-op
        (s,) = t.drain()
        assert s.end_s == 1.0


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


class TestExport:
    def _spans(self):
        t = Tracer().configure(enabled=True)
        with t.start_trace("request", start_s=1.0, tags={"kind": "search"}) as r:
            t.start("cache.mem", parent=r.context, start_s=1.1).finish(end_s=1.2)
            r.finish(end_s=2.0)
        with t.start_trace("engine.pass", start_s=1.5) as p:
            p.finish(end_s=1.9)
        return t.drain()

    def test_jsonl_round_trip(self, tmp_path):
        spans = self._spans()
        path = tmp_path / "spans.jsonl"
        assert write_spans_jsonl(spans, path) == 3
        lines = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert len(lines) == 3
        assert {ln["name"] for ln in lines} == {"request", "cache.mem",
                                               "engine.pass"}
        req = next(ln for ln in lines if ln["name"] == "request")
        assert req["tags"] == {"kind": "search"}
        assert req["duration_s"] == 1.0

    def test_chrome_trace_events(self, tmp_path):
        spans = self._spans()
        events = chrome_trace_events(spans)
        xs = [e for e in events if e["ph"] == "X"]
        ms = [e for e in events if e["ph"] == "M"]
        assert len(xs) == 3 and len(ms) == 2      # one lane per trace
        cache = next(e for e in xs if e["name"] == "cache.mem")
        # microseconds relative to the earliest span (start_s=1.0)
        assert abs(cache["ts"] - 0.1e6) < 1.0
        assert abs(cache["dur"] - 0.1e6) < 1.0
        assert cache["cat"] == "cache"
        assert cache["args"]["parent_id"]
        req = next(e for e in xs if e["name"] == "request")
        assert req["args"]["kind"] == "search"
        lane_names = {m["args"]["name"] for m in ms}
        assert any(n.startswith("request[") for n in lane_names)
        assert any(n.startswith("engine.pass[") for n in lane_names)
        path = tmp_path / "trace.json"
        assert write_chrome_trace(spans, path) == 3
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit"}

    def test_empty_export(self, tmp_path):
        assert chrome_trace_events([]) == []
        assert write_chrome_trace([], tmp_path / "t.json") == 0


# ---------------------------------------------------------------------------
# Telemetry compatibility: byte-identical key sets
# ---------------------------------------------------------------------------


class TestTelemetryCompat:
    def test_service_stats_keys(self):
        assert tuple(ServiceStats().as_dict()) == (
            "requests", "cache_hits", "coalesced", "misses", "fused_passes",
            "slice_hits", "incremental_passes", "claims_acquired",
            "claim_waits", "claim_hits", "claim_timeouts")

    def test_cache_stats_keys(self):
        assert tuple(CacheStats().as_dict()) == (
            "gets", "hits", "disk_hits", "shared_hits", "misses", "puts",
            "evictions", "evictions_lost", "corrupt")

    def test_registry_stats_keys(self):
        assert tuple(RegistryStats().as_dict()) == (
            "hits", "misses", "fills", "fill_noops", "corrupt",
            "claims_acquired", "claims_lost", "claims_broken",
            "claims_released", "evictions")

    def test_frontend_stats_keys(self):
        assert tuple(FrontendStats().as_dict()) == (
            "submitted", "served", "shedded", "batches", "max_batch",
            "depth_hwm")

    def test_registry_telemetry_adds_entries(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        t = reg.telemetry()
        assert set(t) == set(RegistryStats().as_dict()) | {"entries"}

    def test_service_telemetry_sections(self, tmp_path):
        svc = SynthesisService(tech=TECH, resolution=3)
        assert set(svc.telemetry()) == {"service", "cache"}
        svc = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(registry=ArtifactRegistry(tmp_path)))
        assert set(svc.telemetry()) == {"service", "cache", "registry"}


# ---------------------------------------------------------------------------
# Service instrumentation: tier spans, engine-pass cross-links
# ---------------------------------------------------------------------------


class TestServiceSpans:
    def _serve(self, svc, specs):
        roots = [tracer.start_trace("request") for _ in specs]
        responses = svc.serve([SynthesisRequest(spec=s) for s in specs],
                              contexts=[r.context for r in roots])
        for r in roots:
            r.finish()
        return responses

    def test_mem_only_cache_probes_one_tier(self, traced):
        svc = SynthesisService(tech=TECH, resolution=3)
        specs = spec_variants(2, seed=21)
        self._serve(svc, specs)
        spans = tracer.drain()
        assert len(_by_name(spans, "cache.mem")) == 2
        assert not _by_name(spans, "cache.disk")
        assert not _by_name(spans, "cache.registry")
        assert not _by_name(spans, "cache.claim")
        assert all(s.tags["outcome"] == "miss"
                   for s in _by_name(spans, "cache.mem"))

    def test_all_tiers_probed_when_configured(self, traced, tmp_path):
        svc = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(store_dir=tmp_path / "store",
                                registry=ArtifactRegistry(tmp_path / "reg")))
        (spec,) = spec_variants(1, seed=22)
        self._serve(svc, [spec])
        spans = tracer.drain()
        for tier in ("cache.mem", "cache.disk", "cache.registry"):
            (s,) = _by_name(spans, tier)
            assert s.tags["outcome"] == "miss"
        (claim,) = _by_name(spans, "cache.claim")
        assert claim.tags["outcome"] == "acquired"
        # warm pass: memory answers, deeper tiers never probed again
        self._serve(svc, [spec])
        spans = tracer.drain()
        (mem,) = _by_name(spans, "cache.mem")
        assert mem.tags["outcome"] == "hit"
        assert not _by_name(spans, "cache.disk")
        assert not _by_name(spans, "cache.registry")

    def test_shared_engine_pass_cross_links(self, traced):
        svc = SynthesisService(tech=TECH, resolution=3)
        specs = spec_variants(2, seed=23)
        stream = [specs[0], specs[1], specs[0]]      # one coalesced dup
        self._serve(svc, stream)
        spans = tracer.drain()
        (engine_pass,) = _by_name(spans, "engine.pass")
        assert engine_pass.tags["n_requests"] == 2   # dup coalesced away
        links = _by_name(spans, "request.engine")
        assert len(links) == 3
        assert {l.tags["engine_pass"] for l in links} == {engine_pass.span_id}
        assert {l.tags["engine_trace"] for l in links} == {engine_pass.trace_id}
        assert sorted(l.tags["coalesced"] for l in links) == [False, False,
                                                              True]
        # the links live in the REQUESTS' traces, not the pass's own
        assert all(l.trace_id != engine_pass.trace_id for l in links)
        # phase children inside the pass trace
        for phase in ("engine.plan", "engine.place", "engine.execute"):
            (p,) = _by_name(spans, phase)
            assert p.trace_id == engine_pass.trace_id
        extracts = _by_name(spans, "engine.extract")
        assert len(extracts) == 2
        execute = _by_name(spans, "engine.execute")[0]
        assert execute.tags["n_specs"] == 2
        place = _by_name(spans, "engine.place")[0]
        assert place.tags["mode"] and place.tags["n_dev"] >= 1

    def test_untraced_serve_records_nothing(self, traced):
        svc = SynthesisService(tech=TECH, resolution=3)
        svc.serve([SynthesisRequest(spec=spec_variants(1, seed=24)[0])])
        # no contexts passed -> no request-side spans; the fused pass still
        # records its own engine.pass trace (it is a trace root)
        spans = tracer.drain()
        assert not _by_name(spans, "cache.mem")
        assert len(_by_name(spans, "engine.pass")) == 1

    def test_tracing_off_records_nothing_at_all(self):
        assert not tracer.enabled
        svc = SynthesisService(tech=TECH, resolution=3)
        svc.serve([SynthesisRequest(spec=spec_variants(1, seed=25)[0])])
        assert tracer.spans() == []


# ---------------------------------------------------------------------------
# Frontend: span boundaries EQUAL response timestamps, scheduler thread
# ---------------------------------------------------------------------------


class TestFrontendSpans:
    def test_span_boundaries_equal_response_timestamps(self, traced):
        svc = SynthesisService(tech=TECH, resolution=3)
        specs = spec_variants(3, seed=31)
        with ServiceFrontend(svc, window=0.02, max_batch=8) as front:
            tickets = [front.submit(SynthesisRequest(spec=s)) for s in specs]
            responses = [t.result(timeout=600) for t in tickets]
        spans = tracer.drain()
        roots = _by_name(spans, "request")
        assert len(roots) == 3
        for resp in responses:
            root = next(s for s in roots if s.start_s == resp.queued_at)
            assert root.end_s == resp.served_at
            assert root.tags["served_from"] == resp.served_from
            queued = next(s for s in _by_name(spans, "request.queued")
                          if s.parent_id == root.span_id)
            batched = next(s for s in _by_name(spans, "request.batched")
                           if s.parent_id == root.span_id)
            # EXACT equality: same clock, same stamps — not "within 1ms"
            assert queued.start_s == resp.queued_at
            assert queued.end_s == resp.batched_at
            assert batched.start_s == resp.batched_at
            assert batched.end_s == resp.served_at
            assert batched.tags["batch_size"] >= 1
        # per-request latency histogram observed once per served request
        from repro.obs.metrics import get_registry
        assert get_registry().histogram(
            "frontend/request_latency_s").count >= 3

    def test_shed_finishes_span_with_reason(self, traced):
        svc = SynthesisService(tech=TECH, resolution=3)
        front = ServiceFrontend(svc, max_depth=1, start=False)
        specs = spec_variants(3, seed=32)
        front.submit(SynthesisRequest(spec=specs[0]))
        t2 = front.submit(SynthesisRequest(spec=specs[1]))   # over depth
        assert t2.done()
        shed = next(s for s in tracer.spans() if s.name == "request"
                    and "shedded" in s.tags)
        assert shed.tags["shedded"] == "queue_full"
        front.close(drain=False)


# ---------------------------------------------------------------------------
# Engine hook-list mutation hazards (regression)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_plan():
    (spec,) = spec_variants(1, seed=41)
    lattice = B.DesignLattice.enumerate(spec)
    tables = B.SpecTables(spec, TECH)
    return engine.plan_for([lattice], [tables])


class TestHookMutationSafety:
    def test_self_removing_execute_hook_does_not_skip_peers(self, small_plan):
        calls = []

        def hook_a(plan):
            calls.append("a")
            engine.remove_execute_hook(hook_a)

        def hook_b(plan):
            calls.append("b")

        engine.add_execute_hook(hook_a)
        engine.add_execute_hook(hook_b)
        try:
            engine.execute(small_plan)
            assert calls == ["a", "b"]       # b neither skipped nor doubled
            engine.execute(small_plan)
            assert calls == ["a", "b", "b"]  # a really removed itself
        finally:
            for h in (hook_a, hook_b):
                try:
                    engine.remove_execute_hook(h)
                except ValueError:
                    pass

    def test_self_removing_latency_hook_does_not_skip_peers(self, small_plan):
        calls = []

        def hook_a(plan, elapsed_s):
            calls.append(("a", elapsed_s > 0))
            engine.remove_latency_hook(hook_a)

        def hook_b(plan, elapsed_s):
            calls.append(("b", elapsed_s > 0))

        engine.add_latency_hook(hook_a)
        engine.add_latency_hook(hook_b)
        try:
            engine.execute(small_plan)
            assert calls == [("a", True), ("b", True)]
        finally:
            for h in (hook_a, hook_b):
                try:
                    engine.remove_latency_hook(h)
                except ValueError:
                    pass

    def test_hook_added_during_pass_fires_next_pass_only(self, small_plan):
        calls = []

        def late(plan):
            calls.append("late")

        def adder(plan):
            calls.append("adder")
            engine.add_execute_hook(late)

        engine.add_execute_hook(adder)
        try:
            engine.execute(small_plan)
            assert calls == ["adder"]        # snapshot iteration: not yet
            engine.execute(small_plan)
            assert calls == ["adder", "late", "adder"] or \
                calls == ["adder", "adder", "late"]
        finally:
            for h in (adder, late):
                try:
                    engine.remove_execute_hook(h)
                except ValueError:
                    pass


# ---------------------------------------------------------------------------
# ServeConfig: trace / trace_sample / kernel_profile posture
# ---------------------------------------------------------------------------


def _args(**kw):
    base = dict(dcim_config=None, dcim_select=False, dcim_pref=None,
                dcim_profile=None, dcim_cache=None, dcim_macros=None,
                dcim_trace=None, dcim_trace_sample=None,
                dcim_kernel_profile=None)
    base.update(kw)
    return Namespace(**base)


class TestServeConfigObs:
    def test_round_trip_with_obs_fields(self, tmp_path):
        cfg = ServeConfig(trace="trace.json", trace_sample=0.25,
                          kernel_profile="kp.json")
        path = tmp_path / "serve.json"
        save_serve_config(path, cfg)
        assert load_serve_config(path) == cfg

    def test_trace_sample_validated(self):
        with pytest.raises(ValueError):
            ServeConfig(trace_sample=0.0)
        with pytest.raises(ValueError):
            ServeConfig(trace_sample=1.0001)
        assert ServeConfig(trace_sample=1).trace_sample == 1.0

    def test_legacy_artifact_without_obs_keys_loads(self, tmp_path):
        path = tmp_path / "serve.json"
        path.write_text(json.dumps({"schema": "syndcim-serve-config/v1",
                                    "select": True, "macros": 64}))
        cfg = load_serve_config(path)
        assert cfg.trace is None and cfg.trace_sample == 1.0
        assert cfg.kernel_profile is None

    def test_cli_flags_override_file(self, tmp_path):
        path = tmp_path / "serve.json"
        save_serve_config(path, ServeConfig(trace="file.json",
                                            trace_sample=0.5))
        got = serve_config_from_args(_args(dcim_config=str(path),
                                           dcim_trace="cli.json",
                                           dcim_trace_sample=0.75,
                                           dcim_kernel_profile="kp.json"))
        assert got.trace == "cli.json" and got.trace_sample == 0.75
        assert got.kernel_profile == "kp.json"
        got = serve_config_from_args(_args(dcim_config=str(path)))
        assert got.trace == "file.json" and got.trace_sample == 0.5


# ---------------------------------------------------------------------------
# Kernel-profile artifact round trip
# ---------------------------------------------------------------------------


class TestKernelProfileArtifact:
    def _profiles(self):
        from repro.kernels.profile import KernelProfile
        from repro.kernels.tiles import TileConfig
        return [
            KernelProfile(kernel="dcim_mac", shape=(128, 128, 128),
                          tile=TileConfig(), t_copy_us=10.0,
                          t_compute_us=40.0, t_fused_us=50.0,
                          bytes_moved=1000, flops=2000,
                          compute_measured=True),
            KernelProfile(kernel="ssm_scan", shape=(512, 128),
                          tile=TileConfig(), t_copy_us=30.0,
                          t_compute_us=10.0, t_fused_us=30.0,
                          bytes_moved=500, flops=800,
                          compute_measured=True),
        ]

    def test_payload_round_trip(self, tmp_path):
        from repro.kernels.profile import (PROFILE_SCHEMA,
                                           fraction_from_profile_artifact,
                                           fraction_from_profiles,
                                           load_profile_artifact,
                                           profiles_payload)
        profiles = self._profiles()
        payload = profiles_payload(profiles)
        assert payload["schema"] == PROFILE_SCHEMA
        expect = fraction_from_profiles(profiles)
        assert math.isclose(payload["fraction"], expect)
        path = tmp_path / "kp.json"
        path.write_text(json.dumps(payload))
        data = load_profile_artifact(path)
        assert len(data["profiles"]) == 2
        assert math.isclose(fraction_from_profile_artifact(path), expect)

    def test_legacy_bare_list_upgraded(self, tmp_path):
        from repro.kernels.profile import (fraction_from_profile_artifact,
                                           fraction_from_profiles,
                                           load_profile_artifact)
        profiles = self._profiles()
        path = tmp_path / "legacy.json"
        path.write_text(json.dumps([p.as_dict() for p in profiles]))
        data = load_profile_artifact(path)
        assert data["backend"] is None
        assert math.isclose(fraction_from_profile_artifact(path),
                            fraction_from_profiles(profiles))

    def test_bad_schema_and_bad_fraction_rejected(self, tmp_path):
        from repro.kernels.profile import (fraction_from_profile_artifact,
                                           load_profile_artifact)
        path = tmp_path / "bad.json"
        path.write_text(json.dumps({"schema": "other/v1"}))
        with pytest.raises(ValueError, match="not a kernel profile"):
            load_profile_artifact(path)
        path.write_text(json.dumps({"schema": "syndcim-kernel-profile/v1",
                                    "fraction": 0.0, "profiles": []}))
        with pytest.raises(ValueError, match="fraction"):
            fraction_from_profile_artifact(path)

    def test_select_macros_threads_kernel_fraction(self):
        from repro.roofline.dcim import dcim_serving_bound
        from repro.core.dse import GemmShape
        gemms = [GemmShape("g", 128, 128, 128)]
        full = dcim_serving_bound(gemms, 1e-3)
        derated = dcim_serving_bound(gemms, 1e-3, kernel_fraction=0.5)
        assert derated.tokens_per_s < full.tokens_per_s


# ---------------------------------------------------------------------------
# Kernel dispatch instrumentation
# ---------------------------------------------------------------------------


class TestKernelDispatchSpans:
    def test_dcim_mac_interpret_dispatch(self, traced):
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels import dcim_matmul_int
        from repro.obs.metrics import get_registry
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-8, 8, (8, 128)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (128, 128)), jnp.int8)
        before = get_registry().counter("kernel/dcim_mac/dispatch").value
        with tracer.start_trace("request"):
            dcim_matmul_int(a, w, use_pallas=True, interpret=True)
        spans = tracer.drain()
        (k,) = _by_name(spans, "kernel.dcim_mac")
        assert k.tags["shape"] == "8x128x128"
        assert k.tags["route"] in ("pipelined", "grid")
        assert k.tags["tile_source"] == "default"
        assert isinstance(k.tags["tile"], dict)
        reg = get_registry()
        assert reg.counter("kernel/dcim_mac/dispatch").value == before + 1
        assert reg.counter(
            f"kernel/dcim_mac/route/{k.tags['route']}").value >= 1

    def test_xla_path_source_none(self, traced):
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels import dcim_matmul_int
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-8, 8, (4, 16)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (16, 8)), jnp.int8)
        with tracer.start_trace("request"):
            dcim_matmul_int(a, w, use_pallas=False)
        (k,) = _by_name(tracer.drain(), "kernel.dcim_mac")
        assert k.tags["route"] == "xla" and k.tags["tile_source"] == "none"
        assert "tile" not in k.tags

    def test_lookup_with_source_default(self):
        from repro.kernels.autotune import lookup_with_source
        cfg, source = lookup_with_source("dcim_mac", (64, 64, 64))
        assert source in ("memo", "registry", "default")
        assert cfg is not None


# ---------------------------------------------------------------------------
# 8-fake-device drill: spans under the real scheduler thread + sharding
# ---------------------------------------------------------------------------


class TestObsEightDevices:
    def test_eight_fake_devices_span_alignment(self):
        """Subprocess drill: tracing on, a 6-spec stream through the async
        frontend over a multihost-mode service on 8 fake devices — every
        request's span boundaries equal its response stamps, all requests
        cross-link one engine pass."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import jax
            from repro.core import calibrated_tech_for_reference
            from repro.core.shardspec import spec_variants
            from repro.obs import configure, tracer
            from repro.service import (ServiceFrontend, SynthesisRequest,
                                       SynthesisService)

            configure(enabled=True, sample=1.0)
            tech = calibrated_tech_for_reference()
            specs = spec_variants(6, seed=9)
            svc = SynthesisService(tech=tech, resolution=3,
                                   mode="multihost")
            with ServiceFrontend(svc, window=0.05, max_batch=8) as front:
                tickets = [front.submit(SynthesisRequest(spec=s))
                           for s in specs]
                responses = [t.result(timeout=600) for t in tickets]
            spans = tracer.drain()
            roots = [s for s in spans if s.name == "request"]
            aligned = all(
                any(s.start_s == r.queued_at and s.end_s == r.served_at
                    for s in roots)
                for r in responses)
            passes = [s for s in spans if s.name == "engine.pass"]
            links = [s for s in spans if s.name == "request.engine"]
            pass_ids = {s.span_id for s in passes}
            linked = all(l.tags["engine_pass"] in pass_ids for l in links)
            print(json.dumps({
                "devices": len(jax.devices()),
                "requests": len(roots),
                "aligned": aligned,
                "passes": len(passes),
                "links": len(links),
                "linked": linked,
                "served": front.stats.served,
                "shedded": front.stats.shedded}))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"drill failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["requests"] == 6 and out["served"] == 6
        assert out["shedded"] == 0
        assert out["aligned"]
        assert out["passes"] >= 1 and out["linked"]
        assert out["links"] == 6
