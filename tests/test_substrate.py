"""Substrate tests: quantizers, optimizer, schedules, data pipeline,
checkpoint manager, failure/restart drill, gradient compression math."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ckpt import CheckpointManager, FailureInjector, run_with_restarts
from repro.data import DataConfig, SyntheticCorpus, host_sharded_loader
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress_int8,
                         cosine_schedule, decompress_int8,
                         linear_warmup_cosine)
from repro.quant import (block_fp_align, dequantize, fake_quant,
                         fp8_e4m3_quant, quantize_int)

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------


class TestQuant:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_roundtrip_error_bounded(self, bits):
        x = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
        q, s = quantize_int(x, bits)
        err = jnp.abs(dequantize(q, s) - x)
        assert float(err.max()) <= float(s.max()) * 0.5 + 1e-6

    def test_fake_quant_straight_through(self):
        x = jnp.asarray(RNG.normal(size=(8, 8)), jnp.float32)
        g = jax.grad(lambda y: jnp.sum(fake_quant(y, 8, -1) * 3.0))(x)
        np.testing.assert_allclose(np.asarray(g), 3.0)

    def test_block_fp_align_is_alignment_unit(self):
        """Shared exponent + integer mantissas reconstruct within LSB/2."""
        x = jnp.asarray(RNG.normal(size=(16, 64)) * 10, jnp.float32)
        man, scale = block_fp_align(x, man_bits=7)
        rec = man.astype(jnp.float32) * scale
        assert float(jnp.abs(rec - x).max() / scale.max()) <= 1.0
        # mantissas are integers in range
        assert man.dtype == jnp.int32
        assert int(jnp.abs(man).max()) <= 2 ** 7

    def test_fp8_saturates(self):
        x = jnp.asarray([1e6, -1e6, 0.5], jnp.float32)
        y = fp8_e4m3_quant(x)
        assert float(y[0]) <= 448.0 and float(y[1]) >= -448.0

    @given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_quant_idempotent(self, bits, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(size=(32,)), jnp.float32)
        y1 = fake_quant(x, bits, None)
        y2 = fake_quant(y1, bits, None)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(weight_decay=0.0)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw_update(g, opt, params, jnp.float32(0.05),
                                          cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip_applies(self):
        params = {"w": jnp.zeros((4,))}
        opt = adamw_init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = adamw_update(g, opt, params, jnp.float32(0.1),
                               AdamWConfig(grad_clip=1.0))
        assert float(m["grad_norm"]) > 1.0  # raw norm reported

    def test_schedules(self):
        s = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0, abs=1e-3)
        assert float(s(100)) < float(s(50))
        c = cosine_schedule(2.0, 100)
        assert float(c(0)) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def test_deterministic_and_shifted_labels(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
        c = SyntheticCorpus(cfg)
        b1, b2 = c.batch(5), c.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
        b3 = c.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
        c = SyntheticCorpus(cfg)
        full = c.batch(3)
        part0 = c.batch(3, 0, 4)
        part1 = c.batch(3, 4, 8)
        np.testing.assert_array_equal(
            np.concatenate([part0["tokens"], part1["tokens"]]), full["tokens"])

    def test_loader_prefetch(self):
        cfg = DataConfig(vocab=50, seq_len=4, global_batch=4)
        c = SyntheticCorpus(cfg)
        it = host_sharded_loader(c, host_id=1, n_hosts=2, start_step=7)
        step, batch = next(it)
        assert step == 7
        np.testing.assert_array_equal(batch["tokens"], c.batch(7, 2, 4)["tokens"])

    def test_zipf_marginal(self):
        cfg = DataConfig(vocab=1000, seq_len=256, global_batch=16)
        toks = SyntheticCorpus(cfg).batch(0)["tokens"]
        # token 0 (rank 1) must be much more frequent than median ranks
        f0 = (toks == 0).mean()
        fmid = (toks == 500).mean()
        assert f0 > 10 * max(fmid, 1e-6)


# ---------------------------------------------------------------------------
# checkpointing + failure drill
# ---------------------------------------------------------------------------


class TestCkpt:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)},
                "step": 3}
        mgr.save(3, tree)
        restored, step = mgr.restore(tree)
        assert step == 3
        np.testing.assert_array_equal(restored["a"], tree["a"])

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.ones(2), "step": s})
        steps = sorted(int(p.name.split("_")[1])
                       for p in tmp_path.glob("step_*"))
        assert steps == [3, 4]

    def test_async_save_fence(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3)
        mgr.async_save(1, {"x": jnp.full((1000,), 7.0), "step": 1})
        assert mgr.wait(timeout=30)
        assert mgr.latest_step() == 1

    def test_restart_drill_exactly_once(self, tmp_path):
        """Injected failures at steps 7 and 13; the run must complete with the
        same final state as a failure-free run (deterministic data)."""
        def step_fn(step, state):
            state = dict(state)
            state["acc"] = state["acc"] + np.float64(step)
            return state

        mgr = CheckpointManager(tmp_path / "a", keep=3)
        mgr.save(0, {"acc": np.float64(0), "step": 0})
        out = run_with_restarts(step_fn, {"acc": np.float64(0), "step": 0},
                                20, mgr, save_every=5,
                                injector=FailureInjector(fail_at=(7, 13)))
        assert out["restarts"] == 2
        assert out["acc"] == sum(range(20))

    def test_atomicity_no_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3)
        mgr.save(1, {"x": jnp.ones(3), "step": 1})
        for p in tmp_path.glob("step_*"):
            assert (p / "manifest.json").exists()


# ---------------------------------------------------------------------------
# gradient compression math
# ---------------------------------------------------------------------------


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        g = jnp.asarray(RNG.normal(size=(512,)) * 0.01, jnp.float32)
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(50):
            q, s, err = compress_int8(g, err)
            acc = acc + q.astype(jnp.float32) * s
        # mean reconstructed grad approaches true g (EF removes bias)
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                                   atol=5e-4)

    def test_compress_bounds(self):
        g = jnp.asarray(RNG.normal(size=(64,)), jnp.float32)
        q, s, e = compress_int8(g, jnp.zeros_like(g))
        assert q.dtype == jnp.int8
        rec = q.astype(jnp.float32) * s
        assert float(jnp.abs(rec - g).max()) <= float(s) * 0.5 + 1e-7

    def test_decompress_int8_mean(self):
        q_sum = jnp.asarray([100, -100], jnp.int32)
        out = decompress_int8(q_sum, jnp.float32(0.02), 2)
        np.testing.assert_allclose(np.asarray(out), [1.0, -1.0])
