"""Unit tests for the HLO cost walker — the backbone of §Roofline.

Compiles small SPMD programs on 8 fake devices (subprocess — device count is
per-process) and checks the walker's FLOPs / collective-bytes / trip-count
accounting against hand-computed values."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.roofline.hlo_parse import (Shape, analyze_hlo_text, parse_hlo,
                                      parse_shapes)

REPO = Path(__file__).resolve().parent.parent


def run_scenario(code: str, timeout=600) -> dict:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


class TestShapeParsing:
    def test_scalar_and_tuple(self):
        assert parse_shapes("f32[]")[0].dims == ()
        shs = parse_shapes("(s32[], f32[64,64], bf16[2,3])")
        assert [s.dtype for s in shs] == ["s32", "f32", "bf16"]
        assert shs[2].bytes == 12

    def test_bytes(self):
        assert Shape("bf16", (128, 256)).bytes == 128 * 256 * 2
        assert Shape("pred", (8,)).bytes == 8


@pytest.mark.slow
class TestWalkerOnCompiledHLO:
    def test_scan_trip_counts_and_dot_flops(self):
        out = run_scenario("""
            import json, jax, jax.numpy as jnp
            from jax import lax
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.roofline.hlo_parse import analyze_hlo_text
            mk = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
                  if hasattr(jax.sharding, "AxisType") else {})
            mesh = jax.make_mesh((2, 4), ("data", "model"), **mk)
            def body(x, w):
                return jnp.tanh(x @ w), 0
            def f(x, ws):
                y, _ = lax.scan(body, x, ws)
                return y.sum()
            xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
            ws = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
            with mesh:
                comp = jax.jit(f, in_shardings=(
                    NamedSharding(mesh, P("data", None)),
                    NamedSharding(mesh, P(None, None, "model")))).lower(xs, ws).compile()
            res = analyze_hlo_text(comp.as_text(), 8, bf16_normalize=False)
            print(json.dumps({
                "flops": res["flops_per_device"],
                "trips": list(res["while_trip_counts"].values()),
                "ag": res["coll_counts"].get("all-gather", {}).get("bytes", 0)}))
        """)
        # 6 scan steps x 2*64*64*256 per-device dot flops (+ small elementwise)
        expect_dot = 6 * 2 * 64 * 64 * 256
        assert expect_dot <= out["flops"] <= expect_dot * 1.01
        assert 6 in out["trips"]
        # all-gather of the x shard over 'model' (g=4): 6 x 64x256x4B x 3/4
        assert out["ag"] == pytest.approx(6 * 64 * 256 * 4 * 0.75)

    def test_allreduce_ring_accounting(self):
        out = run_scenario("""
            import json, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.roofline.hlo_parse import analyze_hlo_text
            mk = ({"axis_types": (jax.sharding.AxisType.Auto,)}
                  if hasattr(jax.sharding, "AxisType") else {})
            mesh = jax.make_mesh((8,), ("data",), **mk)
            def f(x):
                return x.sum(axis=0)   # cross-device reduction
            xs = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
            with mesh:
                comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),),
                               out_shardings=NamedSharding(mesh, P(None))) \
                    .lower(xs).compile()
            res = analyze_hlo_text(comp.as_text(), 8, bf16_normalize=False)
            ar = res["coll_counts"].get("all-reduce", {"bytes": 0})
            print(json.dumps({"ar_bytes": ar["bytes"]}))
        """)
        # all-reduce of f32[1024] over 8 devices: 2 * 4096B * 7/8
        assert out["ar_bytes"] == pytest.approx(2 * 4096 * 7 / 8, rel=0.01)


class TestWalkerSynthetic:
    HLO = textwrap.dedent("""\
        HloModule test

        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %x = f32[8,8] get-tuple-element(%p), index=1
          %one = s32[] constant(1)
          %i2 = s32[] add(%i, %one)
          %y = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
        }

        %cond (p2: (s32[], f32[8,8])) -> pred[] {
          %p2 = (s32[], f32[8,8]) parameter(0)
          %i3 = s32[] get-tuple-element(%p2), index=0
          %lim = s32[] constant(5)
          ROOT %lt = pred[] compare(%i3, %lim), direction=LT
        }

        ENTRY %main (a: f32[8,8]) -> f32[8,8] {
          %a = f32[8,8] parameter(0)
          %zero = s32[] constant(0)
          %init = (s32[], f32[8,8]) tuple(%zero, %a)
          %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
          ROOT %out = f32[8,8] get-tuple-element(%w), index=1
        }
    """)

    def test_trip_count_from_condition_constant(self):
        res = analyze_hlo_text(self.HLO, 1, bf16_normalize=False)
        assert res["while_trip_counts"] == {"w": 5}
        # 5 iterations x 2*8*8*8 dot flops
        assert res["flops_per_device"] == pytest.approx(5 * 2 * 8 * 8 * 8,
                                                        rel=0.05)

    def test_parse_structure(self):
        comps = parse_hlo(self.HLO)
        assert set(comps) == {"body", "cond", "main"}
        assert comps["body"].instrs[-1].opcode == "tuple"
