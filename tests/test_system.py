"""End-to-end behaviour tests for the paper's system: the full
spec -> search -> Pareto -> RTL -> functional-verification pipeline, and the
compiler-to-framework bridge (macro design driving the DCIM-quantized model
layer + the accelerator-level DSE)."""


import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (GemmShape, SubcircuitLibrary,
                        accelerator_report, calibrated_tech_for_reference,
                        emit_verilog, mso_search, pareto_experiment_spec,
                        reference_chip_ppa, tree_netlist, verify_tree)
from repro.kernels.dcim_mac import dcim_matmul_int_pallas
from repro.kernels.dcim_mac import ref as mac_ref
from repro.quant import quantize_int


@pytest.fixture(scope="module")
def compiled_frontier():
    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()
    return mso_search(pareto_experiment_spec(), scl, tech)


class TestEndToEndCompiler:
    def test_spec_to_layout_pipeline(self, compiled_frontier):
        """The paper's Fig. 2 flow produces, for one user spec: a Pareto set,
        RTL for each design, and gate-level-verified adder trees."""
        res = compiled_frontier
        assert len(res.frontier) >= 3
        rng = np.random.default_rng(0)
        for ppa in res.frontier:
            rtl = emit_verilog(ppa)
            assert "dcim_macro" in rtl and ppa.design.memcell.value in rtl
            nl = tree_netlist(ppa.design)
            ops = rng.integers(0, 2, (nl.n_inputs, 16)) * \
                rng.integers(-8, 8, (nl.n_inputs, 16))
            assert verify_tree(nl, ops)

    def test_macro_semantics_equal_kernel_semantics(self):
        """What the synthesized macro computes (bit-serial oracle) is exactly
        what the framework's kernel computes — the compiler-to-model bridge."""
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        xq, xs = quantize_int(x, 8)
        wq, ws = quantize_int(w, 8, axis=0)
        macro_result = mac_ref.dcim_matmul_bitserial_ref(xq, wq, 8, 8)
        kernel_result = dcim_matmul_int_pallas(xq, wq, interpret=True)
        np.testing.assert_array_equal(np.asarray(macro_result),
                                      np.asarray(kernel_result))

    def test_workload_to_accelerator_report(self):
        """System DSE: an assigned arch's GEMMs mapped onto the searched
        macro produce a coherent accelerator report."""
        cfg = get_config("llama3.2-3b")
        gemms = [GemmShape("wq", 128, cfg.d_model, cfg.n_heads * cfg.hd,
                           cfg.n_layers)]
        rep = accelerator_report(gemms, reference_chip_ppa(), n_macros=64)
        assert rep.total_cycles > 0 and rep.effective_tops > 0
        assert rep.area_mm2 == pytest.approx(64 * 0.112, rel=1e-3)

    def test_spec_constraints_propagate(self, compiled_frontier):
        """Every frontier design meets the user's frequency at the user's
        voltage — the defining property of spec-oriented synthesis."""
        spec = compiled_frontier.spec
        for ppa in compiled_frontier.frontier:
            assert ppa.fmax_hz >= spec.f_mac_hz * 0.999
            assert ppa.design.spec.vdd == spec.vdd
