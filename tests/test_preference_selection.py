"""Property tests for preference-aware serving-time macro selection.

The contract of :func:`repro.serve.select.preference_select` (and its
``select_macros(preference=...)`` wiring):

  * rescale invariance — multiplying every weight by c > 0 never changes
    the pick (scalarization is normalized against frontier minima);
  * permutation invariance — permuting the candidate pool, or permuting
    (objective columns, weights) together, never changes the picked
    candidate's objectives;
  * degenerate all-zero weights fall back to pure wallclock;
  * the selected macro is always on the pooled Pareto frontier — an
    eps-dominated candidate (shared PARETO_EPS band) is never selected.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import calibrated_tech_for_reference
from repro.core.dse import GemmShape
from repro.core.pareto import PARETO_EPS, dominates, nondominated_mask
from repro.serve.select import (PROFILE_SCHEMA, PreferenceProfile,
                                load_preference_profile, preference_select,
                                preferred_macro, save_preference_profile,
                                select_macros)


def _objs(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    objs = rng.uniform(0.1, 10.0, size=(n, 3))
    if seed % 3 == 0 and n >= 4:    # salt in exact duplicates + eps-near ties
        objs[n // 2] = objs[0]
        objs[n // 3] = objs[1] + PARETO_EPS / 4
    return objs


def _weights(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 10_000)
    w = rng.uniform(0.0, 1.0, size=3)
    w[int(rng.integers(3))] += 0.1      # at least one strictly positive
    return w


# ---------------------------------------------------------------------------
# Scalarization properties on synthetic objective matrices
# ---------------------------------------------------------------------------


class TestPreferenceSelectProperties:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.integers(min_value=1, max_value=60),
           scale=st.sampled_from([1e-6, 0.5, 3.0, 1e6]))
    def test_weight_rescale_invariance(self, seed, n, scale):
        objs, w = _objs(seed, n), _weights(seed)
        assert preference_select(objs, w) == preference_select(objs, scale * w)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.integers(min_value=1, max_value=60))
    def test_pool_permutation_invariance(self, seed, n):
        """Shuffling the candidate pool never changes the picked
        candidate's objective vector."""
        objs, w = _objs(seed, n), _weights(seed)
        perm = np.random.default_rng(seed + 1).permutation(n)
        i = preference_select(objs, w)
        j = preference_select(objs[perm], w)
        assert tuple(objs[perm][j]) == tuple(objs[i])

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.integers(min_value=1, max_value=60),
           perm=st.sampled_from([(1, 0, 2), (2, 1, 0), (0, 2, 1), (1, 2, 0)]))
    def test_objective_weight_permutation_consistency(self, seed, n, perm):
        """Permuting objective columns together with their weights selects
        the same candidate (no objective is special-cased)."""
        objs, w = _objs(seed, n), _weights(seed)
        p = list(perm)
        assert preference_select(objs[:, p], w[p]) == \
            preference_select(objs, w)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.integers(min_value=1, max_value=60))
    def test_zero_weights_fall_back_to_wallclock(self, seed, n):
        objs = _objs(seed, n)
        assert preference_select(objs, (0.0, 0.0, 0.0)) == \
            preference_select(objs, (1.0, 0.0, 0.0))

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n=st.integers(min_value=1, max_value=60))
    def test_selected_is_never_eps_dominated(self, seed, n):
        objs, w = _objs(seed, n), _weights(seed)
        i = preference_select(objs, w)
        assert nondominated_mask(objs)[i]
        for j in range(n):      # per-pair verdicts, shared eps semantics
            assert not dominates(objs[j], objs[i])

    def test_extreme_weight_tracks_its_objective(self):
        """An all-in weight on one objective picks that objective's frontier
        minimum."""
        objs = _objs(12, 40)
        for axis in range(3):
            w = np.zeros(3)
            w[axis] = 1.0
            i = preference_select(objs, w)
            cand = np.flatnonzero(nondominated_mask(objs))
            assert objs[i, axis] == objs[cand, axis].min()

    def test_rejects_bad_weights(self):
        objs = _objs(1, 10)
        with pytest.raises(ValueError):
            preference_select(objs, (1.0, -0.5, 0.0))
        with pytest.raises(ValueError):
            preference_select(objs, (1.0, 0.0))
        with pytest.raises(ValueError):
            preference_select(objs, (np.nan, 0.0, 1.0))
        with pytest.raises(ValueError):
            preference_select(np.empty((0, 3)), (1.0, 0.0, 0.0))


# ---------------------------------------------------------------------------
# Wired through select_macros on a real synthesized pool
# ---------------------------------------------------------------------------


def _toy_workloads():
    return {
        "vision": [GemmShape("conv_as_gemm", 196, 512, 512, 4),
                   GemmShape("head", 196, 512, 1000)],
        "language": [GemmShape("qkv", 128, 2048, 6144, 16),
                     GemmShape("mlp", 128, 2048, 8192, 16)],
    }


class TestPreferenceSelectionEndToEnd:
    @pytest.fixture(scope="class")
    def tech(self):
        return calibrated_tech_for_reference()

    @pytest.fixture(scope="class")
    def energy_selection(self, tech):
        return select_macros(_toy_workloads(), tech=tech, resolution=3,
                             n_macros=64, preference=(0.2, 0.6, 0.2))

    def test_selected_on_pooled_frontier(self, energy_selection):
        sel = energy_selection
        rep = sel.codesign
        for w in sel.workloads:
            wi = rep.workloads.index(w)
            objs = np.stack([rep.wallclock_s[wi], rep.energy_pj[wi],
                             rep.area_mm2], axis=1)
            assert nondominated_mask(objs)[sel.assignment[w]]

    def test_rescaled_preference_same_assignment(self, energy_selection,
                                                 tech):
        scaled = select_macros(_toy_workloads(), tech=tech, resolution=3,
                               n_macros=64, preference=(2.0, 6.0, 2.0))
        assert scaled.assignment == energy_selection.assignment

    def test_preferred_macro_matches_assignment(self, energy_selection):
        sel = energy_selection
        for w in sel.workloads:
            assert preferred_macro(sel.codesign, w, sel.preference) == \
                sel.assignment[w]

    def test_serving_estimates_cover_workloads(self, energy_selection):
        sel = energy_selection
        assert set(sel.serving) == set(sel.workloads)
        for w in sel.workloads:
            est = sel.serving_for(w)
            assert est.tokens_per_s > 0
            assert est.bound_s == max(est.t_macro_s, est.t_hbm_s)
            assert est.macro == sel.label_for(w)
            assert est.bottleneck in ("macro-compute", "hbm")

    def test_wallclock_preference_matches_frontier_restricted_min(self, tech):
        """preference=(1,0,0) picks the frontier member with the minimal
        wallclock (the legacy argmin, restricted to non-dominated picks)."""
        sel = select_macros(_toy_workloads(), tech=tech, resolution=3,
                            n_macros=64, preference=(1.0, 0.0, 0.0))
        rep = sel.codesign
        for w in sel.workloads:
            wi = rep.workloads.index(w)
            objs = np.stack([rep.wallclock_s[wi], rep.energy_pj[wi],
                             rep.area_mm2], axis=1)
            cand = np.flatnonzero(nondominated_mask(objs))
            assert rep.wallclock_s[wi, sel.assignment[w]] == \
                rep.wallclock_s[wi][cand].min()

    def test_default_selection_unchanged_without_preference(self, tech):
        """No preference -> the legacy lowest-wallclock assignment (and the
        serving roofline still reported)."""
        sel = select_macros(_toy_workloads(), tech=tech, resolution=3,
                            n_macros=64)
        assert sel.preference is None
        for w in sel.workloads:
            wi = sel.codesign.workloads.index(w)
            assert sel.codesign.wallclock_s[wi, sel.assignment[w]] == \
                sel.codesign.wallclock_s[wi].min()
        assert set(sel.serving) == set(sel.workloads)


# ---------------------------------------------------------------------------
# Preference profiles: persisted per-deployment-config weights
# ---------------------------------------------------------------------------


class TestPreferenceProfiles:
    @pytest.fixture(scope="class")
    def tech(self):
        return calibrated_tech_for_reference()

    def test_round_trip(self, tmp_path):
        """save -> load reproduces workload weights, the explicit-wallclock
        None entry, and the default — the --dcim-profile artifact contract."""
        profile = PreferenceProfile(
            workloads={"vision": (0.2, 0.6, 0.2), "language": None},
            default=(1.0, 0.0, 0.0))
        path = tmp_path / "profile.json"
        save_preference_profile(path, profile)
        back = load_preference_profile(path)
        assert back.workloads == profile.workloads
        assert back.default == profile.default
        assert back.weights_for("vision") == (0.2, 0.6, 0.2)
        assert back.weights_for("language") is None        # explicit wallclock
        assert back.weights_for("unseen") == (1.0, 0.0, 0.0)  # default

    def test_missing_file_is_empty_profile(self, tmp_path):
        profile = load_preference_profile(tmp_path / "absent.json")
        assert profile.workloads == {}
        assert profile.default is None
        assert profile.weights_for("anything") is None

    def test_with_workload_updates_and_persists(self, tmp_path):
        path = tmp_path / "profile.json"
        profile = load_preference_profile(path)          # empty
        profile = profile.with_workload("qwen3-4b", (0.1, 0.8, 0.1))
        profile = profile.with_workload("whisper-tiny", None)
        save_preference_profile(path, profile)
        back = load_preference_profile(path)
        assert back.weights_for("qwen3-4b") == (0.1, 0.8, 0.1)
        assert back.weights_for("whisper-tiny") is None

    def test_rejects_bad_artifacts(self, tmp_path):
        bad_schema = tmp_path / "bad_schema.json"
        bad_schema.write_text('{"schema": "something-else/v9"}')
        with pytest.raises(ValueError, match="schema"):
            load_preference_profile(bad_schema)
        bad_weights = tmp_path / "bad_weights.json"
        bad_weights.write_text(json.dumps({
            "schema": PROFILE_SCHEMA, "default": None,
            "workloads": {"vision": [1.0, -2.0, 0.0]}}))
        with pytest.raises(ValueError, match="finite and >= 0"):
            load_preference_profile(bad_weights)
        with pytest.raises(ValueError):
            PreferenceProfile().with_workload("w", (1.0, 0.0))

    def test_profile_drives_selection_per_workload(self, tech):
        """A profile naming both workloads reproduces exactly the assignments
        of the equivalent explicit-preference runs: weighted where it has
        weights, legacy wallclock where it records None."""
        profile = PreferenceProfile(
            workloads={"vision": (0.2, 0.6, 0.2), "language": None})
        sel = select_macros(_toy_workloads(), tech=tech, resolution=3,
                            n_macros=64, profile=profile)
        ref_pref = select_macros(_toy_workloads(), tech=tech, resolution=3,
                                 n_macros=64, preference=(0.2, 0.6, 0.2))
        ref_wall = select_macros(_toy_workloads(), tech=tech, resolution=3,
                                 n_macros=64)
        assert sel.assignment["vision"] == ref_pref.assignment["vision"]
        assert sel.assignment["language"] == ref_wall.assignment["language"]
        assert sel.preferences_applied == {"vision": (0.2, 0.6, 0.2),
                                           "language": None}

    def test_profile_default_and_global_fallback(self, tech):
        """Workloads the profile does not name fall back to the profile
        default when set, else to the call's global preference."""
        profile = PreferenceProfile(workloads={},
                                    default=(0.2, 0.6, 0.2))
        sel = select_macros(_toy_workloads(), tech=tech, resolution=3,
                            n_macros=64, profile=profile,
                            preference=(1.0, 0.0, 0.0))
        assert sel.preferences_applied == {"vision": (0.2, 0.6, 0.2),
                                           "language": (0.2, 0.6, 0.2)}
        ref = select_macros(_toy_workloads(), tech=tech, resolution=3,
                            n_macros=64, preference=(0.2, 0.6, 0.2))
        assert sel.assignment == ref.assignment
        # no default, nothing named -> the global preference applies
        sel2 = select_macros(_toy_workloads(), tech=tech, resolution=3,
                             n_macros=64, profile=PreferenceProfile(),
                             preference=(0.2, 0.6, 0.2))
        assert sel2.assignment == ref.assignment
        assert sel2.preferences_applied == {"vision": (0.2, 0.6, 0.2),
                                            "language": (0.2, 0.6, 0.2)}
