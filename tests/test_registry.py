"""Differential + stress tests for the shared artifact-registry cache tier.

The registry's contract is the FrontierCache contract stretched across a
fleet: any frontier synthesized by any process is a validated, bit-identical
hit in every other process sharing the store, concurrent writers of the same
key are safe by construction (unique-temp atomic rename + content
addressing), claim files elect exactly one synthesizing host per missing
key, and a scoped tech recalibration evicts exactly the affected
axis-value's entries fleet-wide while every other key stays warm.

Process-level guarantees are drilled with real subprocess pools over one
shared tmpdir store (same-key writer races, claim contention, the
two-service acceptance drill); accounting invariants are property-tested
(hypothesis; deterministic fallback shim offline).
"""

import dataclasses
import functools
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import threading
import time
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import calibrated_tech_for_reference, engine
from repro.core import subcircuits as sc
from repro.core.axes import LatticeConfig, seed_config
from repro.core.macro import MacroSpec
from repro.core.multispec import mso_search_many
from repro.core.shardspec import spec_variants
from repro.service import (ArtifactRegistry, FrontierCache,
                           SynthesisRequest, SynthesisService, key_scope,
                           load_artifact, slice_key, stale_digests)
from repro.service.artifacts import result_to_payload

REPO = Path(__file__).resolve().parent.parent
TECH = calibrated_tech_for_reference()


@pytest.fixture()
def execute_counter():
    calls = []
    engine.add_execute_hook(calls.append)
    yield calls
    engine.remove_execute_hook(calls.append)


@functools.lru_cache(maxsize=1)
def one_result():
    """One real synthesized SearchResult, reused as the payload of every
    accounting/stress test that only cares about file discipline."""
    return mso_search_many(spec_variants(1, seed=97), None, TECH,
                           resolution=3)[0]


def assert_ppa_equal(a, b):
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.meets_timing == b.meets_timing


def assert_search_identical(got, oracle):
    assert got.spec == oracle.spec
    assert got.n_evaluated == oracle.n_evaluated
    assert [p.design.name() for p in got.explored] == \
           [p.design.name() for p in oracle.explored]
    assert len(got.frontier) == len(oracle.frontier)
    for x, y in zip(got.frontier, oracle.frontier):
        assert_ppa_equal(x, y)


# ---------------------------------------------------------------------------
# CAS-safe writers: unique temp names + atomic rename
# ---------------------------------------------------------------------------


class TestAtomicWriters:
    def test_no_fixed_temp_name(self, tmp_path):
        """The PR-5 bug: the temp file was the fixed name ``<key>.tmp``, so
        two same-key writers on shared storage clobbered each other's
        partial writes.  The temp name must now be unique per write."""
        cache = FrontierCache(store_dir=tmp_path)
        cache.save_artifact("k", one_result())
        assert not (tmp_path / "k.tmp").exists()
        assert not list(tmp_path.glob("*.tmp"))      # nothing left behind

    def test_concurrent_same_key_writers_thread_hammer(self, tmp_path):
        """N threads rewriting one key while a reader validates every
        observation: with the fixed temp name this raced (missing temp on
        replace, partial JSON); unique temps make every observed state a
        complete artifact."""
        cache = FrontierCache(store_dir=tmp_path)
        res = one_result()
        errors = []

        def writer():
            try:
                for _ in range(25):
                    cache.save_artifact("hot", res)
            except Exception as e:             # pragma: no cover
                errors.append(e)

        stop = threading.Event()

        def reader():
            path = tmp_path / "hot.json"
            try:
                while not stop.is_set():
                    if path.exists():
                        key, _ = load_artifact(path)
                        assert key == "hot"
            except Exception as e:             # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=writer) for _ in range(4)]
        rt = threading.Thread(target=reader)
        rt.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        rt.join()
        assert not errors
        assert not list(tmp_path.glob("*.tmp"))


# ---------------------------------------------------------------------------
# Quarantine at rejection time
# ---------------------------------------------------------------------------


class TestQuarantine:
    def test_rejected_artifact_quarantined_not_left_in_place(self, tmp_path):
        """The PR-5 healing gap: a rejected artifact was 'left for the next
        put to overwrite', but a process that held the key in its LRU never
        re-put, so the poison survived to warm-start the next process.  Now
        the artifact is renamed to ``<key>.corrupt`` the moment validation
        rejects it."""
        cache = FrontierCache(store_dir=tmp_path)
        cache.put("k", one_result())
        path = cache.artifact_path("k")
        path.write_text("not json {")

        fresh = FrontierCache(store_dir=tmp_path)
        assert fresh.get("k") is None
        assert fresh.stats.corrupt == 1
        assert not path.exists()                       # quarantined...
        assert path.with_suffix(".corrupt").exists()   # ...not deleted

        # ...so a third process sees a clean miss, not another rejection
        third = FrontierCache(store_dir=tmp_path)
        assert third.get("k") is None
        assert third.stats.corrupt == 0

    def test_registry_heals_poisoned_local_artifact(self, tmp_path):
        """With a shared registry below, a corrupt local artifact falls
        through to the fleet copy and is re-persisted locally — quarantine
        plus promotion is self-healing."""
        registry = ArtifactRegistry(tmp_path / "reg")
        cache = FrontierCache(store_dir=tmp_path / "local",
                              registry=registry)
        res = one_result()
        cache.put("k", res)
        cache.artifact_path("k").write_text("garbage")

        fresh = FrontierCache(store_dir=tmp_path / "local",
                              registry=ArtifactRegistry(tmp_path / "reg"))
        got = fresh.get("k")
        assert got is not None
        assert_search_identical(got, res)
        assert fresh.stats.corrupt == 1
        assert fresh.stats.shared_hits == 1
        # the local artifact is healed in place
        key, healed = load_artifact(fresh.artifact_path("k"))
        assert key == "k"
        assert_search_identical(healed, res)

    def test_registry_quarantines_corrupt_shared_artifact(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        registry.publish("k", one_result())
        registry.object_path("k").write_text("]]]")
        assert registry.fetch("k") is None
        assert registry.stats.corrupt == 1
        assert not registry.object_path("k").exists()
        assert registry.object_path("k").with_suffix(".corrupt").exists()
        # quarantined entries disappear from the key listing
        assert registry.keys() == []


# ---------------------------------------------------------------------------
# CacheStats accounting invariant (property-tested)
# ---------------------------------------------------------------------------


class TestStatsAccounting:
    @given(ops=st.lists(st.tuples(st.sampled_from(["get", "put", "corrupt"]),
                                  st.integers(min_value=0, max_value=5)),
                        max_size=40),
           capacity=st.integers(min_value=1, max_value=3),
           with_store=st.booleans(), with_registry=st.booleans())
    @settings(max_examples=20, deadline=None)
    def test_every_get_resolves_in_exactly_one_tier(self, ops, capacity,
                                                    with_store,
                                                    with_registry):
        res = one_result()
        with tempfile.TemporaryDirectory() as td:
            td = Path(td)
            cache = FrontierCache(
                capacity=capacity,
                store_dir=td / "store" if with_store else None,
                registry=(ArtifactRegistry(td / "reg")
                          if with_registry else None))
            gets = 0
            for op, ki in ops:
                key = f"k{ki}"
                if op == "put":
                    cache.put(key, res)
                elif op == "get":
                    cache.get(key)
                    gets += 1
                elif op == "corrupt":
                    path = cache.artifact_path(key)
                    if path is not None and path.exists():
                        path.write_text("junk")
            s = cache.stats
            assert s.gets == gets
            assert s.gets == s.hits + s.disk_hits + s.shared_hits + s.misses
            assert len(cache) <= capacity
            if with_store:
                # every eviction had a surviving local artifact
                assert s.evictions_lost == 0
            if not with_store and not with_registry:
                # memory-only cache: no eviction can claim a disk survivor
                assert s.evictions == 0

    def test_disk_hit_at_capacity_one_counts_once(self, tmp_path):
        """The capacity-1 edge: a disk hit promotes into a full LRU, which
        immediately evicts the previous resident — the get must still count
        exactly one disk hit and the eviction must count as disk-surviving,
        with no phantom miss."""
        res = one_result()
        cache = FrontierCache(capacity=1, store_dir=tmp_path)
        cache.put("a", res)
        cache.put("b", res)              # evicts "a" from memory
        assert cache.get("a") is not None   # disk hit, evicts "b"
        s = cache.stats
        assert (s.gets, s.hits, s.disk_hits, s.misses) == (1, 0, 1, 0)
        assert s.evictions == 2 and s.evictions_lost == 0


# ---------------------------------------------------------------------------
# The registry protocol: publish/fetch, claims, wait
# ---------------------------------------------------------------------------


class TestRegistryProtocol:
    def test_publish_fetch_round_trip_bit_identical(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        res = one_result()
        registry.publish("k", res, scope={"lattice": "d0"})
        got = ArtifactRegistry(tmp_path).fetch("k")
        assert_search_identical(got, res)
        assert registry.scope_of("k") == {"lattice": "d0"}
        assert registry.keys() == ["k"]

    def test_fetch_missing_is_counted_miss(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        assert registry.fetch("nope") is None
        assert registry.stats.misses == 1

    def test_republish_is_noop(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        registry.publish("k", one_result())
        registry.publish("k", one_result())
        assert registry.stats.fills == 1
        assert registry.stats.fill_noops == 1

    def test_claim_single_winner_release_reclaim(self, tmp_path):
        a = ArtifactRegistry(tmp_path)
        b = ArtifactRegistry(tmp_path)
        claim = a.claim("k")
        assert claim is not None
        assert b.claim("k") is None
        assert b.stats.claims_lost == 1
        claim.release()
        assert a.stats.claims_released == 1
        again = b.claim("k")
        assert again is not None
        again.release()

    def test_stale_claim_broken_after_ttl(self, tmp_path):
        holder = ArtifactRegistry(tmp_path, claim_ttl_s=0.05)
        holder.claim("k")                    # never released (crashed host)
        time.sleep(0.08)
        taker = ArtifactRegistry(tmp_path, claim_ttl_s=0.05)
        claim = taker.claim("k")
        assert claim is not None
        assert taker.stats.claims_broken == 1
        claim.release()

    def test_wait_sees_concurrent_publish(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        t = threading.Timer(0.05, registry.publish, ("k", one_result()))
        t.start()
        try:
            assert registry.wait("k", timeout_s=5.0)
        finally:
            t.join()
        assert registry.wait("missing", timeout_s=0.05) is False

    def test_invalidate_key_drops_artifact_and_meta(self, tmp_path):
        registry = ArtifactRegistry(tmp_path)
        registry.publish("k", one_result(), scope={"x": "d"})
        assert registry.invalidate_key("k")
        assert registry.keys() == []
        assert registry.scope_of("k") is None
        assert registry.stats.evictions == 1
        assert not registry.invalidate_key("k")


# ---------------------------------------------------------------------------
# Three-tier lookup order + promotion
# ---------------------------------------------------------------------------


class TestThreeTierLookup:
    def test_registry_only_cache_round_trip(self, tmp_path):
        res = one_result()
        a = FrontierCache(registry=ArtifactRegistry(tmp_path))
        a.put("k", res)
        b = FrontierCache(registry=ArtifactRegistry(tmp_path))
        got = b.get("k")
        assert_search_identical(got, res)
        assert b.stats.shared_hits == 1
        assert b.get("k") is got             # promoted into the LRU
        assert b.stats.hits == 1

    def test_local_disk_preferred_over_registry(self, tmp_path):
        registry = ArtifactRegistry(tmp_path / "reg")
        a = FrontierCache(store_dir=tmp_path / "local", registry=registry)
        a.put("k", one_result())
        b = FrontierCache(store_dir=tmp_path / "local",
                          registry=ArtifactRegistry(tmp_path / "reg"))
        assert b.get("k") is not None
        assert b.stats.disk_hits == 1
        assert b.registry.stats.hits == 0    # the shared tier never probed

    def test_registry_hit_promoted_to_local_store(self, tmp_path):
        seed = FrontierCache(registry=ArtifactRegistry(tmp_path / "reg"))
        seed.put("k", one_result())
        b = FrontierCache(store_dir=tmp_path / "local",
                          registry=ArtifactRegistry(tmp_path / "reg"))
        assert b.get("k") is not None
        assert b.artifact_path("k").exists()
        c = FrontierCache(store_dir=tmp_path / "local")   # no registry
        assert c.get("k") is not None
        assert c.stats.disk_hits == 1


# ---------------------------------------------------------------------------
# The two-service fleet drill (in-process): zero engine executions, claims
# ---------------------------------------------------------------------------


class TestSharedServiceDrill:
    def test_second_service_full_shared_hits_zero_executions(
            self, tmp_path, execute_counter):
        specs = spec_variants(3, seed=61)
        reg_root = tmp_path / "registry"
        first = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(store_dir=tmp_path / "host-a",
                                registry=ArtifactRegistry(reg_root)))
        cold = first.serve([SynthesisRequest(spec=s) for s in specs])
        assert first.stats.claims_acquired == len(specs)
        n_cold = len(execute_counter)
        assert n_cold >= 1

        second = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(store_dir=tmp_path / "host-b",
                                registry=ArtifactRegistry(reg_root)))
        warm = second.serve([SynthesisRequest(spec=s) for s in specs])
        assert len(execute_counter) == n_cold   # zero engine executions
        assert second.stats.fused_passes == 0
        assert second.stats.misses == 0
        assert second.cache.stats.shared_hits == len(specs)
        for w, c in zip(warm, cold):
            assert w.served_from == "cache"
            assert_search_identical(w.result, c.result)

    def test_telemetry_rollup_sections(self, tmp_path):
        svc = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(registry=ArtifactRegistry(tmp_path)))
        svc.serve([SynthesisRequest(spec=spec_variants(1, seed=67)[0])])
        t = svc.telemetry()
        assert set(t) == {"service", "cache", "registry"}
        assert t["service"]["claims_acquired"] == 1
        assert t["cache"]["shared_hits"] == 0
        assert t["registry"]["fills"] == 1
        assert t["registry"]["entries"] == 1

    def test_claim_wait_serves_peer_publish(self, tmp_path):
        """A service that loses the claim race waits for the winner's
        publish and serves it as a cache hit — no duplicate synthesis."""
        spec = spec_variants(1, seed=71)[0]
        registry = ArtifactRegistry(tmp_path)
        svc = SynthesisService(
            tech=TECH, resolution=3, claim_wait_s=30.0,
            cache=FrontierCache(registry=ArtifactRegistry(tmp_path)))
        key = svc.key_for(SynthesisRequest(spec=spec))
        peer_claim = registry.claim(key)       # a "peer host" holds the key
        ref = mso_search_many([spec], None, TECH, resolution=3)[0]

        def peer_publishes():
            registry.publish(key, ref)
            peer_claim.release()

        t = threading.Timer(0.1, peer_publishes)
        t.start()
        try:
            (resp,) = svc.serve([SynthesisRequest(spec=spec)])
        finally:
            t.join()
        assert resp.served_from == "cache"
        assert svc.stats.claim_waits == 1
        assert svc.stats.claim_hits == 1
        assert svc.stats.fused_passes == 0
        assert_search_identical(resp.result, ref)

    def test_claim_wait_timeout_synthesizes_anyway(self, tmp_path):
        """A crashed claim holder costs at most the wait — the loser then
        synthesizes itself; a claim is never a correctness gate."""
        spec = spec_variants(1, seed=73)[0]
        registry = ArtifactRegistry(tmp_path)
        svc = SynthesisService(
            tech=TECH, resolution=3, claim_wait_s=0.05,
            cache=FrontierCache(registry=ArtifactRegistry(tmp_path)))
        key = svc.key_for(SynthesisRequest(spec=spec))
        registry.claim(key)                    # never released
        (resp,) = svc.serve([SynthesisRequest(spec=spec)])
        assert resp.served_from == "engine"
        assert svc.stats.claim_waits == 1
        assert svc.stats.claim_timeouts == 1
        ref = mso_search_many([spec], None, TECH, resolution=3)[0]
        assert_search_identical(resp.result, ref)


# ---------------------------------------------------------------------------
# Scoped fleet-wide invalidation
# ---------------------------------------------------------------------------


class TestScopedInvalidation:
    CFG = LatticeConfig(memcells=(sc.MemCellKind.SRAM_6T,
                                  sc.MemCellKind.DLATCH_8T))

    def test_recalibration_evicts_exactly_the_stale_entries(self, tmp_path):
        spec = MacroSpec()
        reg_root = tmp_path / "registry"
        svc = SynthesisService(
            tech=TECH, config=self.CFG,
            cache=FrontierCache(store_dir=tmp_path / "host-a",
                                registry=ArtifactRegistry(reg_root)))
        svc.serve([SynthesisRequest(spec=spec, tech=TECH, kind="sweep")])
        registry = ArtifactRegistry(reg_root)
        all_keys = set(registry.keys())
        # sweep key + one slice record per value of every sliceable axis
        n_slices = (len(self.CFG.memcells) + len(self.CFG.multmuxes)
                    + len(self.CFG.rho_steps) + len(self.CFG.pipe_steps))
        assert len(all_keys) == 1 + n_slices

        # recalibrate a field scoped to DLATCH_8T only
        bumped = dataclasses.replace(TECH, a_sram8t=TECH.a_sram8t * 1.05)
        evicted = set(registry.invalidate_digests(
            stale_digests(TECH, bumped, self.CFG)))
        survivors = set(registry.keys())
        assert evicted | survivors == all_keys and not (evicted & survivors)

        # exactly the slices of unchanged memcell values stay warm — and
        # their addresses are the SAME under the new tech, so they are
        # immediately reusable
        warm_6t = slice_key(spec, TECH, "memcell", 0, config=self.CFG)
        assert survivors == {warm_6t}
        assert slice_key(spec, bumped, "memcell", 0,
                         config=self.CFG) == warm_6t
        assert registry.stats.evictions == len(evicted)

    def test_fleetwide_incremental_resweep_after_invalidation(self,
                                                              tmp_path):
        """The acceptance drill: host A sweeps, the fleet recalibrates a
        memcell-scoped constant, host B re-sweeps under the new tech — it
        reuses the surviving slice from the shared registry (incremental,
        not cold) and its merged result is bit-identical to a cold run."""
        spec = MacroSpec()
        reg_root = tmp_path / "registry"
        host_a = SynthesisService(
            tech=TECH, config=self.CFG,
            cache=FrontierCache(store_dir=tmp_path / "a",
                                registry=ArtifactRegistry(reg_root)))
        host_a.serve([SynthesisRequest(spec=spec, tech=TECH, kind="sweep")])

        bumped = dataclasses.replace(TECH, a_sram8t=TECH.a_sram8t * 1.05)
        registry = ArtifactRegistry(reg_root)
        registry.invalidate_digests(stale_digests(TECH, bumped, self.CFG))

        host_b = SynthesisService(
            tech=bumped, config=self.CFG,
            cache=FrontierCache(store_dir=tmp_path / "b",
                                registry=ArtifactRegistry(reg_root)))
        (warm,) = host_b.serve([SynthesisRequest(spec=spec, tech=bumped,
                                                 kind="sweep")])
        assert host_b.stats.incremental_passes == 1
        assert host_b.stats.slice_hits == 1          # the surviving 6T slice
        assert host_b.cache.stats.shared_hits >= 1   # ...came off the fleet

        cold_svc = SynthesisService(
            tech=bumped, config=self.CFG,
            cache=FrontierCache(store_dir=tmp_path / "c"))
        (cold,) = cold_svc.serve([SynthesisRequest(spec=spec, tech=bumped,
                                                   kind="sweep")])
        assert cold_svc.stats.incremental_passes == 0
        assert dataclasses.asdict(warm.result) == \
            dataclasses.asdict(cold.result)

    def test_scope_records_published_for_search_and_slices(self, tmp_path):
        svc = SynthesisService(
            tech=TECH, resolution=3,
            cache=FrontierCache(registry=ArtifactRegistry(tmp_path)))
        req = SynthesisRequest(spec=spec_variants(1, seed=79)[0])
        svc.serve([req])
        registry = ArtifactRegistry(tmp_path)
        (key,) = registry.keys()
        assert key == svc.key_for(req)
        scope = registry.scope_of(key)
        assert scope == key_scope(TECH, seed_config(svc.memcells))
        assert "lattice" in scope and "__global__" in scope


# ---------------------------------------------------------------------------
# Multi-process drills over one shared tmpdir store
# ---------------------------------------------------------------------------


def _run_workers(codes_and_args, timeout=600):
    """Launch one subprocess per (code, argv) pair concurrently; returns the
    completed processes after asserting every one exited 0."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    procs = [subprocess.Popen([sys.executable, "-c", code, *map(str, argv)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env,
                              cwd=REPO)
             for code, argv in codes_and_args]
    done = [p.communicate(timeout=timeout) for p in procs]
    for p, (out, err) in zip(procs, done):
        assert p.returncode == 0, f"worker failed:\n{err[-3000:]}"
    return [out for out, _ in done]


_WRITER_CODE = textwrap.dedent("""
    import json, sys
    from pathlib import Path
    from repro.service import ArtifactRegistry, FrontierCache
    from repro.service.artifacts import result_from_payload

    payloads_path, store, reg_root, worker, iters = sys.argv[1:6]
    payloads = json.loads(Path(payloads_path).read_text())
    results = {k: result_from_payload(p) for k, p in payloads.items()}
    cache = FrontierCache(store_dir=store,
                          registry=ArtifactRegistry(reg_root))
    order = sorted(results)
    if int(worker) % 2:
        order = order[::-1]        # interleave key orders across workers
    for _ in range(int(iters)):
        for k in order:
            cache.put(k, results[k])
    readback = FrontierCache(store_dir=store,
                             registry=ArtifactRegistry(reg_root))
    ok = all(readback.get(k) is not None for k in results)
    print(json.dumps({"ok": ok, "corrupt": readback.stats.corrupt}))
""")


class TestMultiProcessStress:
    N_WORKERS = 6
    ITERS = 20

    def test_concurrent_writers_same_and_different_keys(self, tmp_path):
        """N subprocesses hammer one shared store (same keys AND disjoint
        keys) while racing on the registry: every artifact reads back
        valid, zero CacheArtifactErrors, no temp litter, and the frontiers
        are bit-identical to the single-process originals."""
        specs = spec_variants(2, seed=83)
        results = mso_search_many(specs, None, TECH, resolution=3)
        svc = SynthesisService(tech=TECH, resolution=3)
        shared_keys = [svc.key_for(SynthesisRequest(spec=s)) for s in specs]
        store, reg_root = tmp_path / "store", tmp_path / "registry"

        workers = []
        for w in range(self.N_WORKERS):
            payloads = {k: result_to_payload(r)
                        for k, r in zip(shared_keys, results)}
            # every worker also owns one private key -> mixed contention
            payloads[f"{shared_keys[0]}-w{w}"] = result_to_payload(
                results[0])
            ppath = tmp_path / f"payloads-{w}.json"
            ppath.write_text(json.dumps(payloads))
            workers.append((_WRITER_CODE,
                            [ppath, store, reg_root, w, self.ITERS]))
        outs = _run_workers(workers)
        for out in outs:
            status = json.loads(out.strip().splitlines()[-1])
            assert status == {"ok": True, "corrupt": 0}

        assert not list(store.glob("*.tmp"))
        assert not list((reg_root / "objects").glob("*.tmp"))
        assert not list(store.glob(".*.tmp"))
        assert not list((reg_root / "objects").glob(".*.tmp"))

        final = FrontierCache(store_dir=store,
                              registry=ArtifactRegistry(reg_root))
        for k, ref in zip(shared_keys, results):
            got = final.get(k)
            assert got is not None
            assert_search_identical(got, ref)
        for w in range(self.N_WORKERS):
            assert final.get(f"{shared_keys[0]}-w{w}") is not None
        assert final.stats.corrupt == 0
        registry = ArtifactRegistry(reg_root)
        assert len(registry.keys()) == len(shared_keys) + self.N_WORKERS


_CLAIM_CODE = textwrap.dedent("""
    import json, sys, time
    from pathlib import Path
    from repro.service import ArtifactRegistry

    reg_root, gate_dir, worker = sys.argv[1:4]
    registry = ArtifactRegistry(reg_root)
    gate = Path(gate_dir)
    (gate / f"ready-{worker}").touch()
    while not (gate / "go").exists():
        time.sleep(0.002)
    claim = registry.claim("contended")
    print(json.dumps({"acquired": claim is not None}))
""")


class TestClaimContention:
    N_WORKERS = 8

    def test_exactly_one_cross_process_claim_winner(self, tmp_path):
        """All workers rendezvous on a gate file, then race O_EXCL claim
        creation on one key: exactly one process may win."""
        gate = tmp_path / "gate"
        gate.mkdir()
        env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        procs = [subprocess.Popen(
            [sys.executable, "-c", _CLAIM_CODE,
             str(tmp_path / "registry"), str(gate), str(w)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=REPO) for w in range(self.N_WORKERS)]
        deadline = time.monotonic() + 300
        while (len(list(gate.glob("ready-*"))) < self.N_WORKERS
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert len(list(gate.glob("ready-*"))) == self.N_WORKERS
        (gate / "go").touch()
        outs = []
        for p in procs:
            out, err = p.communicate(timeout=300)
            assert p.returncode == 0, f"claimer failed:\n{err[-2000:]}"
            outs.append(json.loads(out.strip().splitlines()[-1]))
        winners = sum(1 for o in outs if o["acquired"])
        assert winners == 1


_SERVICE_CODE = textwrap.dedent("""
    import json, sys
    from repro.core import calibrated_tech_for_reference, engine
    from repro.core.shardspec import spec_variants
    from repro.service import (ArtifactRegistry, FrontierCache,
                               SynthesisRequest, SynthesisService)
    from repro.service.artifacts import result_to_payload

    reg_root, local_store, out_path = sys.argv[1:4]
    calls = []
    engine.add_execute_hook(calls.append)
    svc = SynthesisService(
        tech=calibrated_tech_for_reference(), resolution=3,
        cache=FrontierCache(store_dir=local_store,
                            registry=ArtifactRegistry(reg_root)))
    specs = spec_variants(3, seed=89)
    responses = svc.serve([SynthesisRequest(spec=s) for s in specs])
    json.dump({"executes": len(calls),
               "service": svc.stats.as_dict(),
               "cache": svc.cache.stats.as_dict(),
               "results": [result_to_payload(r.result)
                           for r in responses]}, open(out_path, "w"))
""")


class TestTwoProcessDrill:
    def test_second_process_zero_executions_bit_identical(self, tmp_path):
        """The acceptance drill, with real process isolation: service B (a
        separate process, separate local store) answers every spec service
        A synthesized with ZERO engine executions, bit-identical payloads,
        purely off the shared registry."""
        reg_root = tmp_path / "registry"
        out_a, out_b = tmp_path / "a.json", tmp_path / "b.json"
        (first,) = _run_workers(
            [(_SERVICE_CODE, [reg_root, tmp_path / "host-a", out_a])])
        (second,) = _run_workers(
            [(_SERVICE_CODE, [reg_root, tmp_path / "host-b", out_b])])
        a = json.loads(out_a.read_text())
        b = json.loads(out_b.read_text())
        assert a["executes"] >= 1
        assert a["service"]["claims_acquired"] == 3
        assert b["executes"] == 0
        assert b["service"]["fused_passes"] == 0
        assert b["service"]["misses"] == 0
        assert b["cache"]["shared_hits"] == 3
        # lossless payload equality == bit-identical frontiers
        assert a["results"] == b["results"]
