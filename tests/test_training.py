"""Integration: end-to-end training loop behaviour (loss decreases under
DCIM QAT), microbatched gradient accumulation equivalence, serve round-trip
consistency between prefill and decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.models import get_model
from repro.optim.adamw import adamw_init
from repro.optim.schedules import constant_lr
from repro.parallel.logical import split_logical
from repro.parallel.sharding import MESH_RULES
from repro.train.step import make_train_step


def _setup(arch="llama3.2-3b", seed=0):
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params, _ = split_logical(api.init_params(jax.random.PRNGKey(seed)),
                              MESH_RULES)
    return cfg, api, params


class TestTrainingLoop:
    def test_loss_decreases_under_dcim_qat(self):
        cfg, api, params = _setup()
        corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                            global_batch=8))
        step = jax.jit(make_train_step(api, constant_lr(3e-3)),
                       donate_argnums=(0, 1))
        opt = adamw_init(params)
        losses = []
        for i in range(12):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.1, losses
        assert all(np.isfinite(losses))

    def test_microbatch_equals_full_batch_grads(self):
        """4-way grad accumulation ~= single-batch step (same update)."""
        cfg, api, params = _setup()
        corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=16,
                                            global_batch=8))
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(0).items()}
        opt = adamw_init(params)
        full = jax.jit(make_train_step(api, constant_lr(1e-3)))
        micro = jax.jit(make_train_step(api, constant_lr(1e-3),
                                        microbatches=4))
        p1, _, m1 = full(params, opt, batch)
        p2, _, m2 = micro(params, opt, batch)
        # losses match (mean over microbatches == full-batch mean)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
        # parameter updates match closely
        d = jax.tree.map(lambda a, b:
                         float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
                         p1, p2)
        assert max(jax.tree.leaves(d)) < 5e-2

    def test_dcim_qat_vs_baseline_losses_comparable(self):
        """The paper-faithful DCIM INT8 QAT path must train ~as well as the
        plain (dcim_enabled=False) baseline on this toy task."""
        def run(enabled):
            cfg, api, params = _setup()
            cfg2 = cfg.replace(dcim_enabled=enabled)
            api2 = get_model(cfg2)
            corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                                global_batch=8))
            step = jax.jit(make_train_step(api2, constant_lr(3e-3)))
            opt = adamw_init(params)
            for i in range(10):
                b = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
                params, opt, m = step(params, opt, b)
            return float(m["loss"])

        qat = run(True)
        base = run(False)
        assert abs(qat - base) < 0.5, (qat, base)


class TestServeConsistency:
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-7b",
                                      "zamba2-1.2b", "granite-moe-1b-a400m"])
    def test_prefill_decode_matches_forward(self, arch):
        """Teacher-forced decode after prefill must reproduce the training
        forward's next-token logits (same parameters, same tokens).

        MoE note: capacity dropping depends on the dispatch-group composition
        (48-token forward groups vs 1-token decode groups), so consistency
        only holds in the no-drop regime — the smoke config gets a capacity
        factor large enough that no token ever drops."""
        cfg, api, params = _setup(arch)
        if cfg.moe is not None:
            import dataclasses
            from repro.models import get_model as _gm
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      capacity_factor=8.0))
            api = _gm(cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)))
        batch = {"tokens": toks}
        if cfg.frontend is not None:
            batch["frontend"] = jnp.asarray(
                rng.normal(size=(2, cfg.frontend.n_tokens,
                                 cfg.frontend.d_frontend)), jnp.float32)
        logits_fwd, _ = jax.jit(api.forward_train)(params, batch)

        pre, state = api.prefill(params, toks[:, :16], 32,
                                 frontend=batch.get("frontend"))
        # decode tokens 16..23 teacher-forced; compare logits to the forward
        errs = []
        for t in range(16, 24):
            step_logits, state = api.decode_step(params, state,
                                                 toks[:, t:t + 1])
            ref = logits_fwd[:, t]
            got = step_logits[:, 0]
            errs.append(float(jnp.max(jnp.abs(got - ref))))
        assert max(errs) < 0.15, errs
