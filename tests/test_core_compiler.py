"""Unit + property tests for the SynDCIM core compiler (the paper's Alg. 1,
Fig. 4 CSA family, SCL, Pareto search, and silicon-calibration anchors)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (CSADesign, GemmShape, MacroSpec, MultMuxKind,
                        SubcircuitLibrary, accelerator_report, build_netlist,
                        calibrated_tech_for_reference, characterize,
                        emit_verilog, mso_search, pareto_experiment_spec,
                        pareto_front, reference_chip_design,
                        reference_chip_ppa, reference_chip_spec, rollup,
                        simulate, synthesize_one, tree_netlist, verify_tree)


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


@pytest.fixture(scope="module")
def scl(tech):
    return SubcircuitLibrary(tech).build()


# ---------------------------------------------------------------------------
# Silicon calibration anchors (paper Fig. 9 / Fig. 10 / Table II)
# ---------------------------------------------------------------------------


class TestSiliconAnchors:
    def test_fmax_1p1ghz_at_1p2v(self):
        ppa = reference_chip_ppa()
        assert ppa.fmax_hz == pytest.approx(1.1e9, rel=1e-6)

    def test_fmax_300mhz_at_0p7v(self):
        # Not a calibration knob — follows from the alpha-power law fit.
        ppa = reference_chip_ppa(vdd=0.7)
        assert ppa.fmax_hz == pytest.approx(300e6, rel=0.05)

    def test_9tops_at_1p2v(self):
        ppa = reference_chip_ppa()
        assert ppa.tops_1b == pytest.approx(9.0, rel=0.01)

    def test_area_0p112mm2(self):
        ppa = reference_chip_ppa()
        assert ppa.area_um2 / 1e6 == pytest.approx(0.112, rel=1e-3)

    def test_1921_tops_per_w_at_0p7v(self):
        ppa = reference_chip_ppa(vdd=0.7)
        assert ppa.tops_per_w_1b["int_lo"] == pytest.approx(1921.0, rel=0.01)

    def test_80p5_tops_per_mm2(self):
        ppa = reference_chip_ppa()
        assert ppa.tops_per_mm2_1b == pytest.approx(80.5, rel=0.01)

    def test_fp_overhead_fig7(self, tech):
        """FP8 ~ +10% power vs INT4; BF16 ~ +20% vs INT8 (Fig. 7)."""
        spec = dataclasses.replace(reference_chip_spec(),
                                   int_precisions=(4, 8),
                                   fp_precisions=("FP8", "BF16"))
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        e = rollup(d, tech).e_cycle_fj
        fp8 = e["FP8"] / e["int_lo"] - 1
        bf16 = e["BF16"] / e["int_hi"] - 1
        assert 0.05 < fp8 < 0.18
        assert 0.12 < bf16 < 0.30
        assert bf16 > fp8

    def test_energy_efficiency_scales_with_dimension(self, tech):
        """Fig. 7: larger arrays amortize peripherals -> higher TOPS/W."""
        effs = []
        for dim in (32, 64, 128, 256):
            spec = dataclasses.replace(reference_chip_spec(), h=dim, w=dim,
                                       vdd=0.7)
            d = dataclasses.replace(reference_chip_design(), spec=spec)
            effs.append(rollup(d, tech).tops_per_w_1b["int_lo"])
        assert all(b > a for a, b in zip(effs, effs[1:]))


# ---------------------------------------------------------------------------
# CSA family (Fig. 4)
# ---------------------------------------------------------------------------


class TestCSA:
    def test_fa_substitution_shortens_critical_path(self, tech):
        """rho↓ (more FAs) -> faster, but more energy+area (paper §III-B)."""
        prev = None
        for rho in (1.0, 0.75, 0.5, 0.25, 0.0):
            rep = characterize(CSADesign(rho=rho), 64, 2, tech)
            if prev is not None:
                assert rep.crit_path_rel < prev.crit_path_rel
                assert rep.energy_rel > prev.energy_rel
                assert rep.area_um2 > prev.area_um2
            prev = rep

    def test_reorder_speedup(self, tech):
        base = characterize(CSADesign(rho=1.0), 64, 2, tech)
        ro = characterize(CSADesign(rho=1.0, reorder=True), 64, 2, tech)
        assert ro.crit_path_rel < base.crit_path_rel
        assert ro.energy_rel == base.energy_rel  # rewiring is free in energy

    def test_retiming_moves_rca_off_path(self, tech):
        base = characterize(CSADesign(rho=1.0), 64, 2, tech)
        rt = characterize(CSADesign(rho=1.0, retimed=True), 64, 2, tech)
        assert rt.crit_path_rel < base.crit_path_rel
        assert rt.latency_cycles == base.latency_cycles + 1

    def test_split_shortens_tree(self, tech):
        base = characterize(CSADesign(rho=1.0, retimed=True), 256, 2, tech)
        sp = characterize(CSADesign(rho=1.0, retimed=True, split=2), 256, 2, tech)
        assert sp.crit_path_rel < base.crit_path_rel
        assert sp.latency_cycles == base.latency_cycles + 1

    @given(h=st.sampled_from([4, 8, 16, 32, 64, 128, 256]),
           rho=st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]))
    @settings(max_examples=30, deadline=None)
    def test_characterize_is_sane(self, tech, h, rho):
        rep = characterize(CSADesign(rho=rho), h, 2, tech)
        assert rep.crit_path_rel > 0
        assert rep.energy_rel > 0
        assert rep.area_um2 > 0
        assert rep.acc_width >= 2 + int(np.ceil(np.log2(h)))


# ---------------------------------------------------------------------------
# Gate-level functional simulation (post-synthesis verification stage)
# ---------------------------------------------------------------------------


class TestGateSim:
    @given(h=st.sampled_from([4, 8, 16, 32, 64]),
           rho=st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_synthesized_tree_sums_exactly(self, h, rho, seed):
        nl = build_netlist(CSADesign(rho=rho), h)
        rng = np.random.default_rng(seed)
        ops = rng.integers(-2**20, 2**20, size=(h, 8))
        out = simulate(nl, ops)
        np.testing.assert_array_equal(out, ops.sum(axis=0))

    def test_whole_macro_tree_netlist(self, tech):
        ppa = reference_chip_ppa()
        nl = tree_netlist(ppa.design)
        rng = np.random.default_rng(0)
        ops = rng.integers(0, 2, size=(nl.n_inputs, 33))  # bitwise products
        assert verify_tree(nl, ops)

    def test_verilog_emission_mentions_design_choices(self):
        ppa = reference_chip_ppa()
        v = emit_verilog(ppa)
        assert "dcim_macro" in v
        assert ppa.design.memcell.value in v
        assert "adder tree" in v


# ---------------------------------------------------------------------------
# MSO searcher (Algorithm 1)
# ---------------------------------------------------------------------------


class TestSearcher:
    def test_pareto_spec_frontier(self, tech, scl):
        res = mso_search(pareto_experiment_spec(), scl, tech)
        assert res.n_evaluated >= 4
        assert len(res.frontier) >= 3
        # Every frontier design meets the 800 MHz @ 0.9 V constraint.
        for p in res.frontier:
            assert p.meets_timing
            assert p.fmax_hz >= 800e6 * 0.999
        # The frontier spans an energy-efficient and a fast corner.
        fmaxes = [p.fmax_hz for p in res.frontier]
        effs = [p.tops_per_w_1b["int_lo"] for p in res.frontier]
        assert max(fmaxes) / min(fmaxes) > 1.1
        assert max(effs) / min(effs) > 1.05

    def test_objective_tops_exceeds_spec(self, tech, scl):
        """Alg. 1 objective: TOPS(Macro) > TOPS(SPEC)."""
        spec = pareto_experiment_spec()
        ppa = synthesize_one(spec, scl, tech, prefs=(1.0, 0.0, 0.0))
        spec_tops = 2 * spec.h * spec.w * spec.f_mac_hz / 1e12
        assert ppa.tops_1b >= spec_tops * 0.999

    def test_hard_spec_uses_column_split(self, tech, scl):
        hard = dataclasses.replace(pareto_experiment_spec(), h=256, w=256,
                                   f_mac_hz=1.0e9)
        res = mso_search(hard, scl, tech)
        assert any(p.design.csa.split > 1 for p in res.frontier)
        assert all(p.meets_timing for p in res.frontier)

    def test_infeasible_spec_reports_unmet(self, tech, scl):
        impossible = dataclasses.replace(pareto_experiment_spec(),
                                         f_mac_hz=10e9)
        ppa = synthesize_one(impossible, scl, tech, prefs=(0, 0, 1.0))
        assert not ppa.meets_timing
        assert any("UNMET" in a for a in ppa.design.audit)

    def test_mcr_constraint_respected(self, tech, scl):
        """OAI22 fused mult/mux must never be selected for MCR > 2."""
        spec = dataclasses.replace(pareto_experiment_spec(), mcr=4)
        res = mso_search(spec, scl, tech)
        for p in res.explored:
            assert p.design.multmux is not MultMuxKind.OAI22_FUSED

    def test_audit_trail_records_techniques(self, tech, scl):
        hard = dataclasses.replace(pareto_experiment_spec(), f_mac_hz=1.2e9)
        ppa = synthesize_one(hard, scl, tech, prefs=(0, 0, 1.0))
        joined = " ".join(ppa.design.audit)
        assert "tt1" in joined or "tt2" in joined or "tt3" in joined


# ---------------------------------------------------------------------------
# Pareto utilities
# ---------------------------------------------------------------------------


class TestPareto:
    @given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                    min_size=1, max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_front_is_nondominated(self, pts):
        front = pareto_front(pts, lambda p: p)
        assert front, "front never empty"
        for f in front:
            for p in pts:
                assert not (p[0] < f[0] - 1e-12 and p[1] < f[1] - 1e-12)

    def test_front_subset_and_sorted(self):
        pts = [(1, 5), (2, 2), (5, 1), (3, 3), (6, 6)]
        front = pareto_front(pts, lambda p: p)
        assert front == [(1, 5), (2, 2), (5, 1)]


# ---------------------------------------------------------------------------
# Spec validation + SCL
# ---------------------------------------------------------------------------


class TestSpecAndSCL:
    def test_spec_validation(self):
        with pytest.raises(ValueError):
            MacroSpec(h=48, w=64)  # not power of two
        with pytest.raises(ValueError):
            MacroSpec(fp_precisions=("FP13",))
        with pytest.raises(ValueError):
            MacroSpec(int_precisions=())

    def test_scl_lut_hit_and_offgrid_scaling(self, scl):
        d = CSADesign(rho=0.5)
        on = scl.adder_tree(d, 64)     # on grid
        off = scl.adder_tree(d, 96)    # off grid -> model fallback
        assert on.delay_rel > 0 and off.delay_rel > 0
        assert off.energy_rel > on.energy_rel  # more rows, more energy

    def test_query_sorted_by_energy(self, scl):
        rows = scl.query_adder_trees(64)
        energies = [r.energy_rel for _, r in rows]
        assert energies == sorted(energies)

    def test_fastest_tree_is_fa_heavy(self, scl):
        design, rec = scl.fastest_adder_tree(64)
        assert design.rho <= 0.25
        assert design.retimed


# ---------------------------------------------------------------------------
# System DSE (workload -> macro array)
# ---------------------------------------------------------------------------


class TestDSE:
    def test_gemm_mapping_conservation(self):
        ppa = reference_chip_ppa()
        g = GemmShape("ffn", m=128, k=512, n=2048)
        rep = accelerator_report([g], ppa, n_macros=16, ib=8, wb=8)
        assert rep.total_cycles > 0
        assert rep.effective_tops > 0
        assert 0 < rep.avg_util <= 1.0
        assert rep.area_mm2 == pytest.approx(16 * 0.112, rel=1e-3)

    def test_more_macros_fewer_cycles(self):
        ppa = reference_chip_ppa()
        g = GemmShape("big", m=256, k=4096, n=4096)
        slow = accelerator_report([g], ppa, n_macros=4)
        fast = accelerator_report([g], ppa, n_macros=64)
        assert fast.total_cycles < slow.total_cycles

    def test_mcr_reduces_weight_reloads(self, tech):
        g = GemmShape("ffn", m=64, k=2048, n=2048)
        spec1 = dataclasses.replace(reference_chip_spec(), mcr=1)
        spec4 = dataclasses.replace(reference_chip_spec(), mcr=4)
        d1 = dataclasses.replace(reference_chip_design(), spec=spec1)
        d4 = dataclasses.replace(reference_chip_design(), spec=spec4)
        r1 = accelerator_report([g], rollup(d1, tech), n_macros=8)
        r4 = accelerator_report([g], rollup(d4, tech), n_macros=8)
        assert (r4.reports[0].weight_reloads < r1.reports[0].weight_reloads)
