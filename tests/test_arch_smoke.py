"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-grad / prefill+decode step on CPU; assert shapes + finiteness.

The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_config, list_archs, smoke_config
from repro.models import cross_entropy_loss, get_model
from repro.parallel.logical import split_logical
from repro.parallel.sharding import MESH_RULES

ARCHS = list_archs()
B, S = 2, 64


def _batch(cfg, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))}
    if cfg.frontend is not None:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend.n_tokens,
                             cfg.frontend.d_frontend)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def zoo():
    """init each smoke model once per test session."""
    out = {}
    for name in ARCHS:
        cfg = smoke_config(name)
        api = get_model(cfg)
        params_l = api.init_params(jax.random.PRNGKey(0))
        params, specs = split_logical(params_l, MESH_RULES)
        out[name] = (cfg, api, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(zoo, name):
    cfg, api, params = zoo[name]
    batch = _batch(cfg)
    logits, aux = jax.jit(api.forward_train)(params, batch)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/inf logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_grad_step(zoo, name):
    cfg, api, params = zoo[name]
    batch = _batch(cfg)

    def loss_fn(p):
        logits, aux = api.forward_train(p, batch)
        return cross_entropy_loss(logits, batch["labels"]) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    sq = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert np.isfinite(sq) and sq > 0.0, "grads vanished or NaN"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode(zoo, name):
    cfg, api, params = zoo[name]
    batch = _batch(cfg)
    cache_len = S + 4
    logits, state = jax.jit(
        lambda p, t, f: api.prefill(p, t, cache_len, frontend=f),
        static_argnames=())(params, batch["tokens"],
                            batch.get("frontend"))
    assert logits.shape == (B, S, cfg.vocab)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    logits2, state2 = jax.jit(api.decode_step)(params, state, tok)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())
    assert int(state2["pos"]) == int(state["pos"]) + 1


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    cfg = get_config(name)
    expect = {
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect, (got, expect)
    if name.startswith("granite"):
        assert cfg.moe.top_k == 8
        assert cfg.moe.n_experts == (32 if "1b" in name else 40)
    if name == "zamba2-1.2b":
        assert cfg.ssm.state == 64
    if name == "qwen3-4b":
        assert cfg.qk_norm


def test_shape_classes():
    from repro.configs import SHAPES
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    # long_500k only for sub-quadratic families
    for name in ARCHS:
        cfg = get_config(name)
        shapes = applicable_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
