"""Multi-device distribution drills on 8 fake host devices.

Each test spawns a subprocess (XLA device count is fixed at first jax init,
so the 8-device platform needs its own process) running a scenario script:

  * sharded train step on a (2, 4) ('data','model') mesh: loss decreases,
    params stay sharded;
  * int8 error-feedback gradient all-reduce via shard_map over a pod axis
    matches the dense all-reduce within tolerance;
  * elastic re-mesh: checkpoint saved from an 8-device mesh restores onto
    4- and 2-device meshes bit-identically.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_scenario(code: str, timeout=600) -> dict:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout, cwd=REPO)
    assert r.returncode == 0, f"scenario failed:\n{r.stderr[-3000:]}"
    last = [l for l in r.stdout.strip().splitlines() if l.startswith("{")][-1]
    return json.loads(last)


@pytest.mark.slow
def test_sharded_train_step_8dev():
    out = run_scenario("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import get_model
        from repro.parallel.logical import split_logical, values_of
        from repro.parallel.sharding import rules_for_mesh
        from repro.train.step import make_train_step
        from repro.optim.adamw import adamw_init
        from repro.optim.schedules import constant_lr
        from repro.data import DataConfig, SyntheticCorpus

        cfg = smoke_config('llama3.2-3b')
        api = get_model(cfg)
        mk = ({'axis_types': (jax.sharding.AxisType.Auto,) * 2}
              if hasattr(jax.sharding, 'AxisType') else {})
        mesh = jax.make_mesh((2, 4), ('data', 'model'), **mk)
        rules = rules_for_mesh(mesh)
        ltree = api.init_params(jax.random.PRNGKey(0))
        params, specs = split_logical(ltree, rules)
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)
        params = jax.device_put(params, shardings)
        opt = adamw_init(params)
        corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                            global_batch=8))
        step = jax.jit(make_train_step(api, constant_lr(3e-3)),
                       donate_argnums=(0, 1))
        losses = []
        with mesh:
            for i in range(8):
                b = corpus.batch(i)
                batch = {k: jnp.asarray(v) for k, v in b.items()}
                params, opt, m = step(params, opt, batch)
                losses.append(float(m['loss']))
        w = params['blocks']['attn']['wq']
        print(json.dumps({
            'first': losses[0], 'last': losses[-1],
            'n_shards': len(w.sharding.device_set),
            'finite': all(l == l for l in losses)}))
    """)
    assert out["finite"]
    assert out["last"] < out["first"]
    assert out["n_shards"] == 8


@pytest.mark.slow
def test_compressed_pod_allreduce_matches_dense():
    out = run_scenario("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import compressed_psum_tree, ef_state_init

        mk = ({'axis_types': (jax.sharding.AxisType.Auto,) * 2}
              if hasattr(jax.sharding, 'AxisType') else {})
        mesh = jax.make_mesh((2, 4), ('pod', 'data'), **mk)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64)) * 0.01, jnp.float32)
        grads = {'w': g}
        err = ef_state_init(grads)

        def f(gr, er):
            return compressed_psum_tree(gr, er, 'pod')

        spec = {'w': P('pod', None)}
        fn = shard_map(f, mesh=mesh, in_specs=(spec, spec),
                       out_specs=(spec, spec))
        mean_g, new_err = fn(grads, err)
        # dense reference: mean over the pod axis groups
        dense = (np.asarray(g).reshape(2, 4, 64).mean(0))
        got = np.asarray(mean_g['w']).reshape(2, 4, 64)
        err_max = float(np.abs(got - dense[None]).max())
        print(json.dumps({'err_max': err_max,
                          'scale': float(np.abs(dense).max())}))
    """)
    assert out["err_max"] <= max(1e-4, out["scale"] * 0.02)


@pytest.mark.slow
def test_elastic_remesh_roundtrip(tmp_path):
    out = run_scenario(f"""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager, reshard_tree

        tree = {{'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                'b': jnp.ones((8,))}}
        spec = {{'w': P('data', 'model'), 'b': P('data')}}

        mk = {{'axis_types': (jax.sharding.AxisType.Auto,) * 2}} \\
            if hasattr(jax.sharding, 'AxisType') else {{}}
        m8 = jax.make_mesh((4, 2), ('data', 'model'), **mk)
        placed = reshard_tree(tree, m8, spec)
        mgr = CheckpointManager(r'{tmp_path}', keep=2)
        mgr.save(1, placed)

        # "failure": restart on a smaller mesh (4 devices)
        devs = jax.devices()[:4]
        import numpy as _np
        m4 = jax.sharding.Mesh(_np.array(devs).reshape(2, 2),
                               ('data', 'model'))
        restored, step = mgr.restore(placed)
        placed4 = reshard_tree(restored, m4, spec)
        same = bool((_np.asarray(placed4['w']) ==
                     _np.asarray(tree['w'])).all())
        print(json.dumps({{'same': same, 'step': step,
                          'n_dev': len(placed4['w'].sharding.device_set)}}))
    """)
    assert out["same"] and out["step"] == 1 and out["n_dev"] == 4
