"""Differential tests for the online synthesis service (`repro.service`).

The service's whole contract is "cheaper dispatch, same bits": every answer
it serves — in-memory cache hit, disk-artifact hit, coalesced duplicate, or
fused-miss lane — must be bit-identical to a fresh unbatched engine run of
the same spec.  Same harness style as ``tests/test_oracle_equivalence.py``:
Alg.-1 selection order, frontier membership, and bit-exact PPA per frontier
point, plus the dispatch-side contracts (N singleton requests == ONE fused
engine pass; a repeat request == ZERO engine executions; a corrupted disk
artifact is rejected, never served) and the multi-host strategy's
equivalence on 1 and 8 fake devices.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import calibrated_tech_for_reference, engine
from repro.core.macro import MacroSpec
from repro.core.multispec import mso_search_many, scenario_specs
from repro.core.shardspec import spec_variants
from repro.serve.select import apply_profile, select_macros
from repro.service import (CacheArtifactError, FrontierCache,
                           SynthesisService, cache_key, lattice_signature,
                           result_from_payload, result_to_payload, spec_key)

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


@pytest.fixture()
def execute_counter():
    """Counter hook on ``engine.execute`` — the observable the caching and
    coalescing contracts are asserted through."""
    calls = []
    engine.add_execute_hook(calls.append)
    yield calls
    engine.remove_execute_hook(calls.append)


# The differential contract, same style as test_oracle_equivalence.


def assert_ppa_equal(a, b):
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.area_breakdown == b.area_breakdown
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.tops_1b == b.tops_1b
    assert a.tops_per_w_1b == b.tops_per_w_1b
    assert a.tops_per_mm2_1b == b.tops_per_mm2_1b
    assert a.meets_timing == b.meets_timing


def assert_search_identical(got, oracle):
    assert got.spec == oracle.spec
    assert got.n_evaluated == oracle.n_evaluated
    assert [p.design.name() for p in got.explored] == \
           [p.design.name() for p in oracle.explored]
    assert len(got.frontier) == len(oracle.frontier)
    for x, y in zip(got.frontier, oracle.frontier):
        assert_ppa_equal(x, y)


# ---------------------------------------------------------------------------
# Spec canonicalization + content addresses
# ---------------------------------------------------------------------------


class TestKeys:
    def test_spec_key_deterministic_and_structural(self):
        a = MacroSpec(h=64, w=64, mcr=2, f_mac_hz=800e6)
        b = MacroSpec(h=64, w=64, mcr=2, f_mac_hz=8e8)   # same value
        assert spec_key(a) == spec_key(a)
        assert spec_key(a) == spec_key(b)

    def test_spec_key_separates_specs(self):
        specs = spec_variants(12, seed=4) + list(scenario_specs().values())
        keys = {spec_key(s) for s in specs}
        assert len(keys) == len(set(specs))

    def test_cache_key_covers_every_ingredient(self, tech):
        import dataclasses
        spec = scenario_specs()["vision"]
        mc = SynthesisService().memcells
        base = cache_key(spec, tech, mc, 4)
        assert base == cache_key(spec, tech, mc, 4)
        # a different spec, resolution, eps band or tech calibration must
        # re-address — a stale frontier can never be served for any of them
        assert cache_key(scenario_specs()["cloud"], tech, mc, 4) != base
        assert cache_key(spec, tech, mc, 5) != base
        assert cache_key(spec, tech, mc, 4, eps=1e-9) != base
        bumped = dataclasses.replace(tech, tau_ps=tech.tau_ps * 1.01)
        assert cache_key(spec, bumped, mc, 4) != base
        assert lattice_signature(tech, mc) != lattice_signature(bumped, mc)


# ---------------------------------------------------------------------------
# Cache hits are bit-identical to fresh engine runs
# ---------------------------------------------------------------------------


class TestCacheHitIdentity:
    def test_hit_bit_identical_to_fresh_run(self, tech):
        specs = spec_variants(3, seed=11)
        fresh = mso_search_many(specs, None, tech, resolution=3)
        svc = SynthesisService(tech=tech, resolution=3)
        first = svc.synthesize_many(specs)       # misses: the fused pass
        again = svc.synthesize_many(specs)       # pure cache hits
        for f, a, b in zip(fresh, first, again):
            assert_search_identical(a, f)
            assert_search_identical(b, f)

    def test_second_call_zero_engine_executions(self, tech, execute_counter):
        spec = spec_variants(1, seed=13)[0]
        svc = SynthesisService(tech=tech, resolution=3)
        svc.synthesize(spec)
        n_cold = len(execute_counter)
        assert n_cold == 1
        svc.synthesize(spec)
        assert len(execute_counter) == n_cold    # zero new executions
        assert svc.stats.cache_hits == 1

    def test_select_macros_memoized_through_cache(self, tech,
                                                  execute_counter):
        """The satellite contract: select_macros re-synthesized the scenario
        frontier on every invocation; through the service the second call
        performs zero engine executions and selects identically."""
        from repro.core.dse import gemm_inventory
        from repro.configs import smoke_config
        workloads = {"qwen3-4b": gemm_inventory(smoke_config("qwen3-4b"))}
        svc = SynthesisService(tech=tech)
        first = select_macros(workloads, tech=tech, service=svc)
        n_cold = len(execute_counter)
        assert n_cold >= 1
        second = select_macros(workloads, tech=tech, service=svc)
        assert len(execute_counter) == n_cold    # zero engine executions
        assert second.assignment == first.assignment
        assert second.pool_labels == first.pool_labels
        assert second.summary() == first.summary()


# ---------------------------------------------------------------------------
# Coalescing: N singleton requests cost one fused pass
# ---------------------------------------------------------------------------


class TestCoalescing:
    def test_n_singletons_one_fused_pass(self, tech, execute_counter):
        specs = spec_variants(5, seed=17)
        oracle = [mso_search_many([s], None, tech, resolution=3)[0]
                  for s in specs]
        n_naive = len(execute_counter)
        assert n_naive == len(specs)             # the naive cost: N passes
        svc = SynthesisService(tech=tech, resolution=3)
        got = svc.synthesize_many(specs)
        assert len(execute_counter) == n_naive + 1   # the service cost: ONE
        assert svc.stats.fused_passes == 1
        for g, r in zip(got, oracle):
            assert_search_identical(g, r)

    def test_duplicates_coalesce_onto_one_miss(self, tech, execute_counter):
        specs = spec_variants(3, seed=19)
        stream = [specs[0], specs[1], specs[0], specs[2], specs[1], specs[0]]
        svc = SynthesisService(tech=tech, resolution=3)
        got = svc.synthesize_many(stream)
        assert len(execute_counter) == 1
        assert svc.stats.misses == 3
        assert svc.stats.coalesced == 3
        # every duplicate fans out the very result object its miss produced
        assert got[2] is got[0] and got[5] is got[0] and got[4] is got[1]

    def test_mixed_geometry_batch_still_one_execute(self, tech,
                                                    execute_counter):
        """Specs with different lattice signatures land in different vmap
        groups (engine.group_key) but still one engine entry."""
        mixed = spec_variants(2, seed=23) + [
            MacroSpec(h=32, w=32, mcr=2, int_precisions=(4, 8),
                      fp_precisions=("FP8",), f_mac_hz=500e6,
                      f_wupdate_hz=500e6, vdd=0.9)]
        oracle = mso_search_many(mixed, None, tech, resolution=3)
        n0 = len(execute_counter)
        svc = SynthesisService(tech=tech, resolution=3)
        got = svc.synthesize_many(mixed)
        assert len(execute_counter) == n0 + 1
        assert len(execute_counter[-1].groups) == 2
        for g, r in zip(got, oracle):
            assert_search_identical(g, r)


# ---------------------------------------------------------------------------
# On-disk artifact store: round trip + corrupted-artifact rejection
# ---------------------------------------------------------------------------


class TestDiskStore:
    def test_round_trip_bit_identical(self, tech, tmp_path):
        specs = spec_variants(2, seed=29)
        warm = SynthesisService(tech=tech, resolution=3,
                                cache=FrontierCache(store_dir=tmp_path))
        first = warm.synthesize_many(specs)
        # a second service over the same store: disk hits only
        cold = SynthesisService(tech=tech, resolution=3,
                                cache=FrontierCache(store_dir=tmp_path))
        again = cold.synthesize_many(specs)
        assert cold.stats.misses == 0 and cold.stats.fused_passes == 0
        assert cold.cache.stats.disk_hits == len(specs)
        for a, b in zip(first, again):
            assert_search_identical(b, a)

    def test_payload_round_trip_is_lossless(self, tech):
        (res,) = mso_search_many(spec_variants(1, seed=31), None, tech,
                                 resolution=3)
        back = result_from_payload(
            json.loads(json.dumps(result_to_payload(res))))
        assert_search_identical(back, res)
        assert back.spec == res.spec

    @pytest.mark.parametrize("corruption", [
        "not json at all {",
        json.dumps({"schema": "something-else/v1", "key": "k",
                    "result": {}}),
        json.dumps({"schema": "syndcim-frontier-artifact/v1",
                    "key": "k", "result": {"spec": {}}}),
        json.dumps([1, 2, 3]),
    ])
    def test_corrupted_artifact_rejected(self, tech, tmp_path, corruption):
        spec = spec_variants(1, seed=37)[0]
        cache = FrontierCache(store_dir=tmp_path)
        svc = SynthesisService(tech=tech, resolution=3, cache=cache)
        ref = svc.synthesize(spec)
        path = cache.artifact_path(svc.request_key(spec))
        assert path.exists()
        path.write_text(corruption)
        with pytest.raises(CacheArtifactError):
            FrontierCache.load_artifact(path)
        # a fresh service over the corrupted store treats it as a miss,
        # re-synthesizes, and heals the artifact — never serves bad bytes
        svc2 = SynthesisService(tech=tech, resolution=3,
                                cache=FrontierCache(store_dir=tmp_path))
        got = svc2.synthesize(spec)
        assert svc2.cache.stats.corrupt == 1
        assert svc2.stats.fused_passes == 1
        assert_search_identical(got, ref)
        (_, healed) = FrontierCache.load_artifact(path)   # valid again
        assert_search_identical(healed, ref)

    def test_key_mismatch_is_rejected(self, tech, tmp_path):
        """An artifact stored under the wrong address must not be served."""
        specs = spec_variants(2, seed=41)
        cache = FrontierCache(store_dir=tmp_path)
        svc = SynthesisService(tech=tech, resolution=3, cache=cache)
        svc.synthesize_many(specs)
        k0, k1 = (svc.request_key(s) for s in specs)
        os.replace(cache.artifact_path(k0), cache.artifact_path(k1))
        fresh = FrontierCache(store_dir=tmp_path)
        assert fresh.get(k1) is None
        assert fresh.stats.corrupt == 1

    def test_lru_eviction_keeps_disk_copy(self, tech, tmp_path):
        specs = spec_variants(3, seed=43)
        cache = FrontierCache(capacity=1, store_dir=tmp_path)
        svc = SynthesisService(tech=tech, resolution=3, cache=cache)
        svc.synthesize_many(specs)
        assert len(cache) == 1
        assert cache.stats.evictions == 2
        # evicted entries still answer from disk, bit-identically
        ref = mso_search_many(specs[:1], None, tech, resolution=3)[0]
        got = svc.synthesize(specs[0])
        assert svc.stats.fused_passes == 1       # no re-synthesis
        assert_search_identical(got, ref)


# ---------------------------------------------------------------------------
# apply_profile: the shared read-then-update round trip
# ---------------------------------------------------------------------------


class TestApplyProfile:
    def test_round_trip_persists_applied_weights(self, tech, tmp_path):
        from repro.core.dse import gemm_inventory
        from repro.configs import smoke_config
        from repro.serve.select import load_preference_profile
        workloads = {"qwen3-4b": gemm_inventory(smoke_config("qwen3-4b"))}
        path = tmp_path / "profile.json"
        svc = SynthesisService(tech=tech)
        sel, updated = apply_profile(
            path, lambda profile: select_macros(
                workloads, tech=tech, preference=(0.2, 0.6, 0.2),
                profile=profile, service=svc))
        assert updated.workloads["qwen3-4b"] == (0.2, 0.6, 0.2)
        back = load_preference_profile(path)
        assert back.weights_for("qwen3-4b") == (0.2, 0.6, 0.2)
        # second round: the persisted profile now overrides no-preference
        sel2, _ = apply_profile(
            path, lambda profile: select_macros(
                workloads, tech=tech, profile=profile, service=svc))
        assert sel2.preferences_applied["qwen3-4b"] == (0.2, 0.6, 0.2)
        assert sel2.assignment == sel.assignment

    def test_none_path_runs_unprofiled(self, tech):
        from repro.core.dse import gemm_inventory
        from repro.configs import smoke_config
        workloads = {"qwen3-4b": gemm_inventory(smoke_config("qwen3-4b"))}
        svc = SynthesisService(tech=tech)
        sel, updated = apply_profile(
            None, lambda profile: select_macros(workloads, tech=tech,
                                                profile=profile,
                                                service=svc))
        assert updated is None
        assert sel.preferences_applied["qwen3-4b"] is None


# ---------------------------------------------------------------------------
# Multi-host strategy: registered on the engine, bit-identical on 1 + 8 dev
# ---------------------------------------------------------------------------


class TestMultiHostStrategy:
    def test_registered_with_fallback_semantics(self):
        assert "multihost" in engine.STRATEGIES
        s = engine.STRATEGIES["multihost"]
        assert s.sharded and callable(s.default_mesh)
        assert "multihost" in engine.SHARDED_MODES
        # resolution: multihost when available, the single-host pick if not
        resolved = engine.resolve_sharded_mode("multihost")
        if s.available():
            assert resolved == "multihost"
        else:
            assert resolved in ("jit", "pmap")

    def test_host_spec_mesh_shape(self):
        import jax
        from repro.parallel.sharding import host_spec_mesh
        mesh = host_spec_mesh()
        assert tuple(mesh.axis_names) == ("host", "spec")
        assert mesh.devices.size == len(jax.devices())
        assert mesh.devices.shape[0] == jax.process_count()

    def test_multihost_matches_unsharded(self, tech):
        if not engine.STRATEGIES["multihost"].available():
            pytest.skip("multihost strategy unavailable on this runtime")
        for n in (1, 3, 5):
            specs = spec_variants(n, seed=n + 50)
            ref = mso_search_many(specs, None, tech, resolution=3)
            from repro.core.shardspec import mso_search_many_sharded
            got = mso_search_many_sharded(specs, None, tech, resolution=3,
                                          mode="multihost")
            for g, r in zip(got, ref):
                assert_search_identical(g, r)

    def test_service_through_multihost_identical(self, tech):
        if not engine.STRATEGIES["multihost"].available():
            pytest.skip("multihost strategy unavailable on this runtime")
        specs = spec_variants(4, seed=59)
        ref = mso_search_many(specs, None, tech, resolution=3)
        svc = SynthesisService(tech=tech, resolution=3, mode="multihost")
        got = svc.synthesize_many(specs)
        for g, r in zip(got, ref):
            assert_search_identical(g, r)

    def test_eight_fake_devices_bit_identical(self):
        """Subprocess drill (device count is fixed at first jax init): the
        multihost strategy on 8 fake host devices, ragged 13-spec request,
        bit-identical to the unsharded multispec pass — and the service's
        fused pass through it serves the same bits."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import jax
            from repro.core import calibrated_tech_for_reference
            from repro.core.multispec import mso_search_many
            from repro.core.shardspec import (mso_search_many_sharded,
                                              spec_variants)
            from repro.service import SynthesisService

            tech = calibrated_tech_for_reference()
            specs = spec_variants(13, seed=5)       # ragged on 8 devices
            ref = mso_search_many(specs, None, tech, resolution=3)

            def identical(got):
                return all(
                    [p.design.name() for p in g.explored]
                    == [p.design.name() for p in r.explored]
                    and len(g.frontier) == len(r.frontier)
                    and all(x.paths == y.paths
                            and x.fmax_hz == y.fmax_hz
                            and x.area_um2 == y.area_um2
                            and x.area_breakdown == y.area_breakdown
                            and x.e_cycle_fj == y.e_cycle_fj
                            and x.tops_per_w_1b == y.tops_per_w_1b
                            and x.latency_cycles == y.latency_cycles
                            for x, y in zip(g.frontier, r.frontier))
                    for g, r in zip(got, ref))

            got = mso_search_many_sharded(specs, None, tech, resolution=3,
                                          mode="multihost")
            svc = SynthesisService(tech=tech, resolution=3,
                                   mode="multihost")
            served = svc.synthesize_many(specs)
            print(json.dumps({"devices": len(jax.devices()),
                              "multihost": identical(got),
                              "service": identical(served),
                              "fused_passes": svc.stats.fused_passes}))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"drill failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["multihost"] and out["service"]
        assert out["fused_passes"] == 1
