"""Axis-registry lattice properties and per-axis cache-key scoping.

Property-tests (hypothesis; deterministic fallback shim offline) pin the
mixed-radix contract of the registry-composed :class:`DesignLattice` —
``index_of`` / ``coords_of`` round-trip, stride/dim consistency, trailing
zero-padding, ``design_at`` / ``index_of_design`` inversion — over
randomized axis configurations.  The key tests pin the scoped-invalidation
semantics of :mod:`repro.service.keys`: a per-axis signature moves exactly
when that axis's payload moves, and slice keys of unchanged values survive
both a scoped tech recalibration and an axis growth.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import subcircuits as sc
from repro.core.axes import (SLICEABLE_AXES, LatticeConfig, precision_plans,
                             resolve_axes, seed_config)
from repro.core.batched import DesignLattice
from repro.core.macro import MacroSpec, calibrated_tech_for_reference
from repro.service.keys import (axis_signatures, lattice_signature, slice_key,
                                sweep_key)

SPEC = MacroSpec()
TECH = calibrated_tech_for_reference()

MEMCELL_SUBSETS = [
    (sc.MemCellKind.SRAM_6T,),
    (sc.MemCellKind.SRAM_6T, sc.MemCellKind.DLATCH_8T),
    (sc.MemCellKind.SRAM_6T, sc.MemCellKind.DLATCH_8T,
     sc.MemCellKind.OAI_12T),
]
RHO_SUBSETS = [(1.0,), (1.0, 0.5), (1.0, 0.75, 0.5, 0.25, 0.0),
               (1.0, 0.75, 0.5, 0.25, 0.0, 0.9)]
PIPE_SUBSETS = [(0,), (0, 1), (0, 1, 2, 3)]
APPROX_SUBSETS = [(), sc.APPROX_CELLS[:2], sc.APPROX_CELLS]


def random_config(mem_i, rho_i, pipe_i, prec, apx_i) -> LatticeConfig:
    return LatticeConfig(memcells=MEMCELL_SUBSETS[mem_i],
                         rho_steps=RHO_SUBSETS[rho_i],
                         pipe_steps=PIPE_SUBSETS[pipe_i],
                         precision_modes=prec,
                         approx_cells=APPROX_SUBSETS[apx_i])


config_strategy = st.tuples(
    st.integers(min_value=0, max_value=len(MEMCELL_SUBSETS) - 1),
    st.integers(min_value=0, max_value=len(RHO_SUBSETS) - 1),
    st.integers(min_value=0, max_value=len(PIPE_SUBSETS) - 1),
    st.integers(min_value=0, max_value=4),
    st.integers(min_value=0, max_value=len(APPROX_SUBSETS) - 1),
)


class TestLatticeProperties:
    @settings(max_examples=25, deadline=None)
    @given(cfg_i=config_strategy, seed=st.integers(min_value=0,
                                                   max_value=10**6))
    def test_index_roundtrip_and_strides(self, cfg_i, seed):
        lat = DesignLattice.enumerate(SPEC, config=random_config(*cfg_i))
        # dims/strides: mixed-radix consistency, computed once at build
        assert lat.dims == tuple(ax.size for ax in lat.axes)
        assert lat.strides[-1] == 1
        for k in range(len(lat.dims) - 1):
            assert lat.strides[k] == lat.strides[k + 1] * lat.dims[k + 1]
        assert len(lat) == int(np.prod(lat.dims))
        rng = np.random.default_rng(seed)
        for i in rng.integers(0, len(lat), size=16):
            i = int(i)
            coords = lat.coords_of(i)
            assert int(lat.index_of(*coords)) == i
            for k, c in enumerate(coords):
                assert int(lat.coords[k][i]) == c

    @settings(max_examples=25, deadline=None)
    @given(cfg_i=config_strategy, seed=st.integers(min_value=0,
                                                   max_value=10**6))
    def test_trailing_coordinates_zero_pad(self, cfg_i, seed):
        """Callers passing only leading coordinates address the trailing
        axes' defaults (index 0) — the seed-call-site compatibility rule."""
        lat = DesignLattice.enumerate(SPEC, config=random_config(*cfg_i))
        rng = np.random.default_rng(seed)
        for i in rng.integers(0, len(lat), size=8):
            coords = lat.coords_of(int(i))
            for cut in range(1, len(coords) + 1):
                expect = sum(c * s for c, s in
                             zip(coords[:cut], lat.strides[:cut]))
                assert int(lat.index_of(*coords[:cut])) == expect
        with pytest.raises(ValueError):
            lat.index_of(*([0] * (len(lat.dims) + 1)))

    @settings(max_examples=15, deadline=None)
    @given(cfg_i=config_strategy, seed=st.integers(min_value=0,
                                                   max_value=10**6))
    def test_design_index_inversion(self, cfg_i, seed):
        lat = DesignLattice.enumerate(SPEC, config=random_config(*cfg_i))
        rng = np.random.default_rng(seed)
        for i in rng.integers(0, len(lat), size=8):
            assert lat.index_of_design(lat.design_at(int(i))) == int(i)

    def test_seed_lattice_shape_unchanged(self):
        """The registry re-expression of the seed axes keeps the historical
        dims/strides byte-for-byte (flat indices are cache currency)."""
        lat = DesignLattice.enumerate(SPEC)
        assert lat.dims == (3, 3, 5, 2, 2, 3, 4, 2, 2, 2)
        assert lat.strides == (5760, 1920, 384, 192, 96, 32, 8, 4, 2, 1)
        assert [ax.name for ax in lat.axes] == [
            "memcell", "multmux", "rho", "reorder", "retimed", "split",
            "pipe", "ofu_retime", "fuse_tree_sa", "fuse_sa_ofu"]

    def test_optional_axes_append_after_seed(self):
        cfg = LatticeConfig(precision_modes=3, approx_cells=sc.APPROX_CELLS)
        lat = DesignLattice.enumerate(SPEC, config=cfg)
        assert [ax.name for ax in lat.axes[-2:]] == ["precision",
                                                     "approx_cell"]
        seed = DesignLattice.enumerate(SPEC)
        # seed point i maps to extended index i * (n_prec * n_apx)
        scale = lat.dims[-1] * lat.dims[-2]
        for i in (0, 17, 5759):
            assert int(lat.index_of(*seed.coords_of(i))) == i * scale

    def test_sublattice_parent_mapping(self):
        cfg = LatticeConfig(precision_modes=2)
        lat = DesignLattice.enumerate(SPEC, config=cfg)
        sub, parent = lat.sublattice("rho", (1, 3))
        assert len(sub) == len(lat) // lat.axis("rho").size * 2
        rng = np.random.default_rng(0)
        for j in rng.integers(0, len(sub), size=32):
            j = int(j)
            d_sub = sub.design_at(j)
            d_par = lat.design_at(int(parent[j]))
            assert dataclasses.asdict(d_sub) == dataclasses.asdict(d_par)

    def test_precision_plans_prefix(self):
        plans = precision_plans(SPEC, 4)
        assert plans[0].ints == tuple(SPEC.int_precisions)
        assert plans[0].fps == tuple(SPEC.fp_precisions)
        assert precision_plans(SPEC, 2) == plans[:2]
        with pytest.raises(ValueError):
            precision_plans(SPEC, 5)

    def test_resolved_axes_cover_config(self):
        cfg = LatticeConfig(precision_modes=1, approx_cells=sc.APPROX_CELLS)
        names = [a.name for a in resolve_axes(SPEC, cfg)]
        assert "precision" in names and "approx_cell" in names
        names0 = [a.name for a in resolve_axes(SPEC, seed_config())]
        assert "precision" not in names0 and "approx_cell" not in names0


class TestPerAxisSignatures:
    """A per-axis signature moves exactly when that axis's payload moves."""

    CFG = LatticeConfig()       # all memcells/multmuxes, seed steps

    def _sigs(self, tech, cfg=None):
        return axis_signatures(tech, cfg or self.CFG)

    def _changed(self, tech2, cfg2=None) -> set:
        base = self._sigs(TECH)
        new = self._sigs(tech2, cfg2)
        assert set(base) == set(new)
        return {k for k in base if base[k] != new[k]}

    def test_memcell_scoped_field_moves_only_memcell(self):
        tech2 = dataclasses.replace(TECH, a_sram8t=TECH.a_sram8t * 1.01)
        assert self._changed(tech2) == {"memcell"}

    def test_multmux_scoped_field_moves_only_multmux(self):
        tech2 = dataclasses.replace(TECH,
                                    d_mult_oai22=TECH.d_mult_oai22 * 1.01)
        assert self._changed(tech2) == {"multmux"}

    def test_global_field_moves_only_global(self):
        tech2 = dataclasses.replace(TECH, d_fa_sum=TECH.d_fa_sum * 1.01)
        assert self._changed(tech2) == {"__global__"}

    def test_shared_mux_field_is_global(self):
        """d_mux2 feeds the OFU/align models for every design, not just the
        TG_NOR multmux — it must invalidate globally."""
        tech2 = dataclasses.replace(TECH, d_mux2=TECH.d_mux2 * 1.01)
        assert self._changed(tech2) == {"__global__"}

    def test_axis_growth_moves_only_that_axis(self):
        cfg2 = dataclasses.replace(self.CFG,
                                   rho_steps=self.CFG.rho_steps + (0.9,))
        assert self._changed(TECH, cfg2) == {"rho"}

    def test_lattice_signature_tracks_every_axis(self):
        base = lattice_signature(TECH, config=self.CFG)
        for tech2 in (dataclasses.replace(TECH, a_sram6t=1.5),
                      dataclasses.replace(TECH, d_fa_sum=9.9)):
            assert lattice_signature(tech2, config=self.CFG) != base
        assert lattice_signature(TECH, config=self.CFG) == base

    def test_slice_keys_survive_scoped_change(self):
        """The incremental contract: a change scoped to one memcell leaves
        the OTHER memcell values' slice keys intact — and only those."""
        tech2 = dataclasses.replace(TECH, a_sram8t=TECH.a_sram8t * 1.03)
        kinds = list(self.CFG.memcells)
        changed_v = kinds.index(sc.MemCellKind.DLATCH_8T)
        for v in range(len(kinds)):
            k1 = slice_key(SPEC, TECH, "memcell", v, config=self.CFG)
            k2 = slice_key(SPEC, tech2, "memcell", v, config=self.CFG)
            assert (k1 != k2) == (v == changed_v)
        # every other axis's slices cover the memcell axis -> all invalidated
        for axis in SLICEABLE_AXES:
            if axis == "memcell":
                continue
            ax_values = {"multmux": self.CFG.multmuxes,
                         "rho": self.CFG.rho_steps,
                         "pipe": self.CFG.pipe_steps}.get(axis)
            if ax_values is None:
                continue        # precision/approx absent in seed config
            for v in range(len(ax_values)):
                assert (slice_key(SPEC, TECH, axis, v, config=self.CFG)
                        != slice_key(SPEC, tech2, axis, v, config=self.CFG))
        assert sweep_key(SPEC, TECH, self.CFG) != sweep_key(SPEC, tech2,
                                                            self.CFG)

    def test_slice_keys_survive_axis_growth(self):
        cfg2 = dataclasses.replace(self.CFG,
                                   rho_steps=self.CFG.rho_steps + (0.9,))
        for v in range(len(self.CFG.rho_steps)):
            assert (slice_key(SPEC, TECH, "rho", v, config=self.CFG)
                    == slice_key(SPEC, TECH, "rho", v, config=cfg2))
        for v in range(len(self.CFG.memcells)):
            assert (slice_key(SPEC, TECH, "memcell", v, config=self.CFG)
                    != slice_key(SPEC, TECH, "memcell", v, config=cfg2))

    def test_spec_is_part_of_slice_identity(self):
        spec2 = dataclasses.replace(SPEC, f_mac_hz=SPEC.f_mac_hz * 2)
        assert (slice_key(SPEC, TECH, "rho", 0, config=self.CFG)
                != slice_key(spec2, TECH, "rho", 0, config=self.CFG))
