"""Tests for the async/streaming synthesis front and the typed request API.

Same contract as the rest of the serving stack: the frontend adds
*scheduling* — admission queue, priority classes, batching window,
backpressure, streaming — and must add no arithmetic.  Every served answer
is pinned bit-identical to the blocking path (which the differential oracle
harness pins to the scalar oracle), the request lifecycle
(queued → batched → served / shedded) is observable and typed, overload
sheds explicitly with bounded queue depth, and the deprecated kwarg-tuple
shims return the very bits the typed API serves.
"""

import json
import os
import subprocess
import sys
import textwrap
import threading
import time
from argparse import Namespace
from pathlib import Path

import pytest

from repro.core import calibrated_tech_for_reference, engine
from repro.core.multispec import frontier_union, mso_search_many
from repro.core.shardspec import spec_variants
from repro.serve.config import (SERVE_CONFIG_SCHEMA, ServeConfig,
                                load_serve_config, parse_pref,
                                save_serve_config, serve_config_from_args)
from repro.service import (FRONTIER_EVENT, Priority, RequestState,
                           ServiceFrontend, SheddedResponse, SynthesisRequest,
                           SynthesisResponse, SynthesisService, get_service,
                           reset_service)
from repro.service.frontend import WINDOW_BOUNDS

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tech():
    return calibrated_tech_for_reference()


@pytest.fixture()
def execute_counter():
    calls = []
    engine.add_execute_hook(calls.append)
    yield calls
    engine.remove_execute_hook(calls.append)


def assert_ppa_equal(a, b):
    assert a.design.name() == b.design.name()
    assert a.paths == b.paths
    assert a.fmax_hz == b.fmax_hz
    assert a.area_um2 == b.area_um2
    assert a.e_cycle_fj == b.e_cycle_fj
    assert a.latency_cycles == b.latency_cycles
    assert a.meets_timing == b.meets_timing


def assert_search_identical(got, oracle):
    assert got.spec == oracle.spec
    assert got.n_evaluated == oracle.n_evaluated
    assert [p.design.name() for p in got.explored] == \
           [p.design.name() for p in oracle.explored]
    assert len(got.frontier) == len(oracle.frontier)
    for x, y in zip(got.frontier, oracle.frontier):
        assert_ppa_equal(x, y)


# ---------------------------------------------------------------------------
# The typed request API on the (blocking) service
# ---------------------------------------------------------------------------


class TestTypedRequestAPI:
    def test_serve_bit_identical_to_oracle(self, tech, execute_counter):
        specs = spec_variants(4, seed=61)
        oracle = mso_search_many(specs, None, tech, resolution=3)
        svc = SynthesisService(tech=tech, resolution=3)
        n0 = len(execute_counter)
        responses = svc.serve([SynthesisRequest(spec=s) for s in specs])
        assert len(execute_counter) == n0 + 1        # still ONE fused pass
        for r, o in zip(responses, oracle):
            assert isinstance(r, SynthesisResponse)
            assert r.state is RequestState.SERVED
            assert r.served_from == "engine"
            assert_search_identical(r.result, o)

    def test_served_from_tiers(self, tech):
        specs = spec_variants(2, seed=67)
        svc = SynthesisService(tech=tech, resolution=3)
        batch = [SynthesisRequest(spec=specs[0]),
                 SynthesisRequest(spec=specs[1]),
                 SynthesisRequest(spec=specs[0])]     # in-batch duplicate
        first = svc.serve(batch)
        assert [r.served_from for r in first] == \
            ["engine", "engine", "coalesced"]
        assert first[2].result is first[0].result
        again = svc.serve([SynthesisRequest(spec=specs[0])])
        assert again[0].served_from == "cache"
        assert again[0].result is first[0].result

    def test_per_request_resolution_and_tech(self, tech):
        spec = spec_variants(1, seed=71)[0]
        svc = SynthesisService(tech=tech, resolution=3)
        (r5,) = svc.serve([SynthesisRequest(spec=spec, resolution=5)])
        (oracle5,) = mso_search_many([spec], None, tech, resolution=5)
        assert_search_identical(r5.result, oracle5)
        # mixed resolutions in ONE batch each honor their own request
        r3, r5b = svc.serve([SynthesisRequest(spec=spec, resolution=3),
                             SynthesisRequest(spec=spec, resolution=5)])
        (oracle3,) = mso_search_many([spec], None, tech, resolution=3)
        assert_search_identical(r3.result, oracle3)
        assert_search_identical(r5b.result, oracle5)

    def test_mixed_tech_requests_fuse_into_one_pass(self, tech,
                                                    execute_counter):
        import dataclasses
        specs = spec_variants(2, seed=73)
        slow = dataclasses.replace(tech, tau_ps=tech.tau_ps * 1.25)
        oracle_a = mso_search_many(specs[:1], None, tech, resolution=3)[0]
        oracle_b = mso_search_many(specs[1:], None, slow, resolution=3)[0]
        svc = SynthesisService(tech=tech, resolution=3)
        n0 = len(execute_counter)
        ra, rb = svc.serve([SynthesisRequest(spec=specs[0]),
                            SynthesisRequest(spec=specs[1], tech=slow)])
        assert len(execute_counter) == n0 + 1
        assert_search_identical(ra.result, oracle_a)
        assert_search_identical(rb.result, oracle_b)

    def test_on_partial_streams_every_request(self, tech):
        specs = spec_variants(3, seed=79)
        stream = [specs[0], specs[1], specs[0], specs[2]]
        svc = SynthesisService(tech=tech, resolution=3)
        events = []
        responses = svc.serve([SynthesisRequest(spec=s) for s in stream],
                              on_partial=lambda i, r: events.append(i))
        assert sorted(events) == [0, 1, 2, 3]   # hits, dups and misses alike
        for i, r in zip(events, responses):
            assert responses[i].result is not None
        assert responses[2].result is responses[0].result

    def test_rejects_bare_specs_and_bad_requests(self, tech):
        spec = spec_variants(1, seed=83)[0]
        svc = SynthesisService(tech=tech, resolution=3)
        with pytest.raises(TypeError):
            svc.serve([spec])
        with pytest.raises(TypeError):
            SynthesisRequest(spec="not a spec")
        with pytest.raises(ValueError):
            SynthesisRequest(spec=spec, deadline_s=0.0)
        with pytest.raises(ValueError):
            svc.serve([SynthesisRequest(spec=spec, mode="warp-drive")])


class TestDeprecationShims:
    def test_shims_warn_and_match_typed_api(self, tech):
        specs = spec_variants(3, seed=89)
        typed = SynthesisService(tech=tech, resolution=3)
        ref = [r.result for r in
               typed.serve([SynthesisRequest(spec=s) for s in specs])]
        legacy = SynthesisService(tech=tech, resolution=3)
        with pytest.deprecated_call():
            one = legacy.synthesize(specs[0])
        with pytest.deprecated_call():
            many = legacy.synthesize_many(specs)
        assert_search_identical(one, ref[0])
        for g, r in zip(many, ref):
            assert_search_identical(g, r)

    def test_request_key_shim_matches_key_for(self, tech):
        spec = spec_variants(1, seed=97)[0]
        svc = SynthesisService(tech=tech, resolution=3)
        with pytest.deprecated_call():
            old = svc.request_key(spec, resolution=5)
        assert old == svc.key_for(SynthesisRequest(spec=spec, resolution=5))
        # the shim and the typed path address the same cache entry
        svc.serve([SynthesisRequest(spec=spec, resolution=5)])
        assert svc.cache.get(old) is not None


# ---------------------------------------------------------------------------
# Request lifecycle through the frontend (deterministic run_pending drive)
# ---------------------------------------------------------------------------


def make_front(tech, **kw):
    svc = SynthesisService(tech=tech, resolution=3)
    kw.setdefault("start", False)
    return ServiceFrontend(svc, **kw)


class TestLifecycle:
    def test_queued_batched_served_transitions(self, tech):
        front = make_front(tech)
        try:
            spec = spec_variants(1, seed=101)[0]
            events = []
            t = front.submit(SynthesisRequest(spec=spec),
                             on_event=lambda e: events.append(e.kind))
            assert t.state is RequestState.QUEUED and not t.done()
            assert front.depth == 1
            served = front.run_pending()
            assert served == 1 and front.depth == 0
            resp = t.result(timeout=0)
            assert t.state is RequestState.SERVED
            lifecycle = [k for k in events if k != FRONTIER_EVENT]
            assert lifecycle == ["queued", "batched", "served"]
            assert FRONTIER_EVENT in events
            assert resp.queued_at <= resp.batched_at <= resp.served_at
            assert resp.latency_s >= 0 and resp.queue_delay_s >= 0
            (oracle,) = mso_search_many([spec], None, tech, resolution=3)
            assert_search_identical(resp.result, oracle)
        finally:
            front.close()

    def test_deadline_expired_request_is_shedded(self, tech):
        front = make_front(tech)
        try:
            spec = spec_variants(1, seed=103)[0]
            t = front.submit(SynthesisRequest(spec=spec, deadline_s=1e-6))
            time.sleep(0.01)
            front.run_pending()
            resp = t.result(timeout=0)
            assert isinstance(resp, SheddedResponse)
            assert resp.reason == "deadline"
            assert resp.state is RequestState.SHEDDED
            assert front.stats.shedded == 1 and front.stats.served == 0
        finally:
            front.close()

    def test_result_timeout_raises(self, tech):
        front = make_front(tech)
        try:
            t = front.submit(SynthesisRequest(
                spec=spec_variants(1, seed=107)[0]))
            with pytest.raises(TimeoutError):
                t.result(timeout=0.01)
        finally:
            front.close()


class TestPriorityOrdering:
    def test_interactive_ahead_of_bulk_fifo_within_class(self, tech):
        front = make_front(tech, max_batch=16)
        try:
            specs = spec_variants(5, seed=109)
            order = []

            def watch(tag):
                return lambda e: (order.append(tag)
                                  if e.kind == "batched" else None)

            front.submit(SynthesisRequest(spec=specs[0], tag="b0",
                                          priority=Priority.BULK),
                         on_event=watch("b0"))
            front.submit(SynthesisRequest(spec=specs[1], tag="b1",
                                          priority=Priority.BULK),
                         on_event=watch("b1"))
            front.submit(SynthesisRequest(spec=specs[2], tag="i0",
                                          priority=Priority.INTERACTIVE),
                         on_event=watch("i0"))
            front.submit(SynthesisRequest(spec=specs[3], tag="i1",
                                          priority=Priority.INTERACTIVE),
                         on_event=watch("i1"))
            front.submit(SynthesisRequest(spec=specs[4], tag="b2",
                                          priority=Priority.BULK),
                         on_event=watch("b2"))
            front.run_pending()
            assert order == ["i0", "i1", "b0", "b1", "b2"]
        finally:
            front.close()


class TestBackpressure:
    def test_bounded_depth_sheds_typed_never_silent(self, tech):
        front = make_front(tech, max_depth=3)
        try:
            specs = spec_variants(5, seed=113)
            tickets = [front.submit(SynthesisRequest(spec=s)) for s in specs]
            # the queue never exceeded its bound
            assert front.stats.depth_hwm == 3
            shed = [t for t in tickets if t.done()]
            assert len(shed) == 2                    # overload -> typed shed
            for t in shed:
                resp = t.result(timeout=0)
                assert isinstance(resp, SheddedResponse)
                assert resp.reason == "queue_full"
                assert resp.queue_depth == 3
            assert front.stats.shedded == 2
            front.run_pending()
            oracle = mso_search_many(specs[:3], None, tech, resolution=3)
            for t, o in zip(tickets[:3], oracle):
                assert_search_identical(t.result(timeout=0).result, o)
        finally:
            front.close()

    def test_close_without_drain_sheds_shutdown(self, tech):
        front = make_front(tech)
        t = front.submit(SynthesisRequest(spec=spec_variants(1, seed=127)[0]))
        front.close(drain=False)
        resp = t.result(timeout=0)
        assert isinstance(resp, SheddedResponse)
        assert resp.reason == "shutdown"
        # submits after shutdown shed immediately too
        t2 = front.submit(SynthesisRequest(
            spec=spec_variants(1, seed=127)[0]))
        assert t2.result(timeout=0).reason == "shutdown"


class TestStreaming:
    def test_sweep_streams_frontier_so_far(self, tech):
        front = make_front(tech, max_batch=2)
        try:
            specs = spec_variants(5, seed=131)
            seen = []
            handle = front.submit_sweep(
                specs, on_frontier=lambda done, total, pool:
                seen.append((done, total, len(pool))))
            while front.run_pending():
                pass
            responses = handle.results(timeout=0)
            assert [r.state for r in responses] == \
                [RequestState.SERVED] * len(specs)
            # one partial per finished lane, progress monotonic, total right
            assert [d for d, _, _ in seen] == list(range(1, len(specs) + 1))
            assert all(t == len(specs) for _, t, _ in seen)
            # the final pooled frontier matches the blocking sweep's union
            ref = mso_search_many(specs, None, tech, resolution=3)
            ref_pool, _ = frontier_union(
                ref, [f"sweep[{i}]" for i in range(len(specs))])
            assert seen[-1][2] == len(ref_pool)
        finally:
            front.close()


# ---------------------------------------------------------------------------
# The threaded scheduler end to end
# ---------------------------------------------------------------------------


class TestThreadedFrontend:
    def test_burst_served_bit_identical(self, tech):
        specs = spec_variants(4, seed=137)
        stream = [specs[i % 4] for i in range(10)]
        oracle = mso_search_many(specs, None, tech, resolution=3)
        svc = SynthesisService(tech=tech, resolution=3)
        with ServiceFrontend(svc, window=0.02, max_batch=16) as front:
            tickets = [front.submit(SynthesisRequest(spec=s))
                       for s in stream]
            responses = [t.result(timeout=600) for t in tickets]
        assert front.stats.served == len(stream)
        assert front.stats.shedded == 0
        assert svc.stats.fused_passes >= 1
        for resp, spec in zip(responses, stream):
            assert_search_identical(resp.result, oracle[specs.index(spec)])

    def test_frontend_serve_helper_and_select_macros(self, tech):
        """select_macros runs unchanged through a frontend (duck-typed
        ``serve``), proving the caller-facing API is one surface."""
        from repro.configs import smoke_config
        from repro.core.dse import gemm_inventory
        from repro.serve.select import select_macros
        workloads = {"qwen3-4b": gemm_inventory(smoke_config("qwen3-4b"))}
        svc = SynthesisService(tech=tech)
        direct = select_macros(workloads, tech=tech, service=svc)
        with ServiceFrontend(SynthesisService(tech=tech)) as front:
            routed = select_macros(workloads, tech=tech, service=front)
        assert routed.assignment == direct.assignment
        assert routed.pool_labels == direct.pool_labels
        assert routed.summary() == direct.summary()

    def test_adaptive_window_tracks_engine_latency(self, tech):
        front = make_front(tech, window=0.005)
        try:
            assert front.effective_window() == 0.005
            front._observe_pass(None, 1.0)
            w1 = front.effective_window()
            assert WINDOW_BOUNDS[0] <= w1 <= WINDOW_BOUNDS[1]
            assert w1 > 0.005                    # grew toward 10% of 1s
            front._observe_pass(None, 100.0)
            assert front.effective_window() == WINDOW_BOUNDS[1]  # clamped
        finally:
            front.close()

    def test_engine_latency_hook_fires_with_elapsed(self, tech):
        seen = []
        hook = lambda plan, s: seen.append((plan, s))
        engine.add_latency_hook(hook)
        try:
            svc = SynthesisService(tech=tech, resolution=3)
            svc.serve([SynthesisRequest(spec=spec_variants(1, seed=139)[0])])
        finally:
            engine.remove_latency_hook(hook)
        assert len(seen) == 1
        plan, elapsed = seen[0]
        assert elapsed > 0 and len(plan) == 1


# ---------------------------------------------------------------------------
# ServeConfig: the consolidated --dcim-* posture
# ---------------------------------------------------------------------------


def _args(**kw):
    base = dict(dcim_config=None, dcim_select=False, dcim_pref=None,
                dcim_profile=None, dcim_cache=None, dcim_macros=None)
    base.update(kw)
    return Namespace(**base)


class TestServeConfig:
    def test_round_trip(self, tmp_path):
        cfg = ServeConfig(select=True, pref=(0.2, 0.6, 0.2),
                          profile="p.json", cache="frontiers", macros=128)
        path = tmp_path / "serve.json"
        save_serve_config(path, cfg)
        assert json.loads(path.read_text())["schema"] == SERVE_CONFIG_SCHEMA
        assert load_serve_config(path) == cfg

    def test_unknown_key_and_bad_schema_rejected(self, tmp_path):
        path = tmp_path / "serve.json"
        path.write_text(json.dumps({"schema": SERVE_CONFIG_SCHEMA,
                                    "selekt": True}))
        with pytest.raises(ValueError, match="unknown serve-config keys"):
            load_serve_config(path)
        path.write_text(json.dumps({"schema": "other/v1"}))
        with pytest.raises(ValueError, match="not a serve config"):
            load_serve_config(path)

    def test_defaults_without_config(self):
        assert serve_config_from_args(_args()) == ServeConfig()
        got = serve_config_from_args(_args(dcim_select=True,
                                           dcim_pref="1,0,0"))
        assert got.select and got.pref == (1.0, 0.0, 0.0)
        assert got.macros == 256

    def test_cli_flags_override_file(self, tmp_path):
        path = tmp_path / "serve.json"
        save_serve_config(path, ServeConfig(select=True, pref=(0.2, 0.6, 0.2),
                                            cache="from-file", macros=64))
        # no explicit flags: the file wins wholesale
        got = serve_config_from_args(_args(dcim_config=str(path)))
        assert got == ServeConfig(select=True, pref=(0.2, 0.6, 0.2),
                                  cache="from-file", macros=64)
        # explicit flags override their fields, the rest stays from the file
        got = serve_config_from_args(_args(dcim_config=str(path),
                                           dcim_pref="1,0,0",
                                           dcim_macros=512))
        assert got.pref == (1.0, 0.0, 0.0) and got.macros == 512
        assert got.select and got.cache == "from-file"

    def test_parse_pref_validates(self):
        with pytest.raises(ValueError):
            parse_pref("0.5,0.5")
        with pytest.raises(ValueError):
            ServeConfig(pref=(1.0, 2.0))
        with pytest.raises(ValueError):
            ServeConfig(macros=0)


# ---------------------------------------------------------------------------
# The process-wide singleton under concurrency (async-front regression)
# ---------------------------------------------------------------------------


class TestSingletonThreadSafety:
    def test_concurrent_get_service_one_instance(self):
        reset_service()
        n = 16
        barrier = threading.Barrier(n)
        seen = []
        lock = threading.Lock()

        def grab():
            barrier.wait()
            svc = get_service()
            with lock:
                seen.append(id(svc))

        threads = [threading.Thread(target=grab) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(seen) == n
        assert len(set(seen)) == 1      # every thread saw the SAME service
        reset_service()


# ---------------------------------------------------------------------------
# 8-fake-device drill through the async path
# ---------------------------------------------------------------------------


class TestAsyncEightDevices:
    def test_eight_fake_devices_bit_identical(self):
        """Subprocess drill (device count is fixed at first jax init): a
        13-spec ragged request stream submitted through the async frontend
        over a multihost-mode service on 8 fake host devices — every
        response bit-identical to the unsharded blocking pass, nothing
        shedded."""
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": str(REPO / "src"),
               "JAX_PLATFORMS": "cpu"}
        code = textwrap.dedent("""
            import json
            import jax
            from repro.core import calibrated_tech_for_reference
            from repro.core.multispec import mso_search_many
            from repro.core.shardspec import spec_variants
            from repro.service import (ServiceFrontend, SynthesisRequest,
                                       SynthesisService)

            tech = calibrated_tech_for_reference()
            specs = spec_variants(13, seed=5)       # ragged on 8 devices
            ref = mso_search_many(specs, None, tech, resolution=3)

            svc = SynthesisService(tech=tech, resolution=3,
                                   mode="multihost")
            with ServiceFrontend(svc, window=0.05, max_batch=16) as front:
                tickets = [front.submit(SynthesisRequest(spec=s))
                           for s in specs]
                responses = [t.result(timeout=600) for t in tickets]

            identical = all(
                [p.design.name() for p in resp.result.explored]
                == [p.design.name() for p in r.explored]
                and len(resp.result.frontier) == len(r.frontier)
                and all(x.paths == y.paths
                        and x.fmax_hz == y.fmax_hz
                        and x.area_um2 == y.area_um2
                        and x.e_cycle_fj == y.e_cycle_fj
                        and x.latency_cycles == y.latency_cycles
                        for x, y in zip(resp.result.frontier, r.frontier))
                for resp, r in zip(responses, ref))
            print(json.dumps({"devices": len(jax.devices()),
                              "identical": identical,
                              "served": front.stats.served,
                              "shedded": front.stats.shedded,
                              "fused_passes": svc.stats.fused_passes}))
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env,
                           timeout=600, cwd=REPO)
        assert r.returncode == 0, f"drill failed:\n{r.stderr[-3000:]}"
        last = [ln for ln in r.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        out = json.loads(last)
        assert out["devices"] == 8
        assert out["identical"]
        assert out["served"] == 13 and out["shedded"] == 0
        assert out["fused_passes"] >= 1
