"""Tile autotuning + profiling harness + generic registry payloads.

Determinism matters more than timing here: the one thing interpret-mode CPU
timing can assert honestly is the *feasibility-pruned* behaviour — on shapes
smaller than the default block the default config is excluded from the
lattice, so the winner is non-default regardless of noise.  Everything
timing-flavoured (profile splits, candidate ranking) is smoke-tested for
plumbing, not for magnitudes.
"""

import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.autotune import TILE_SCHEMA, tile_key
from repro.kernels.profile import (KernelProfile, fraction_from_profiles,
                                   profile_kernel)
from repro.kernels.tiles import (DEFAULT_TILES, KERNELS, TileConfig,
                                 VMEM_BUDGET_BYTES, resolve_tile, shape_class,
                                 tile_space)
from repro.roofline.dcim import dcim_serving_bound
from repro.service.registry import ArtifactRegistry


@pytest.fixture(autouse=True)
def _fresh_memo():
    autotune.clear_memo()
    autotune.set_registry(None)
    yield
    autotune.clear_memo()
    autotune.set_registry(None)


class TestTileSpace:
    @pytest.mark.parametrize("kernel", KERNELS)
    def test_default_first_on_big_shapes(self, kernel):
        shape = {"dcim_mac": (512, 512, 512), "ssm_scan": (1024, 256),
                 "csa_tree": (256, 512)}[kernel]
        space = tile_space(kernel, shape)
        assert space[0] == DEFAULT_TILES[kernel]
        assert len(space) == len(set(space)) > 1

    def test_small_m_prunes_default_block(self):
        """m=64 < bm=128: no candidate streams pure padding, so the default
        is infeasible and every winner is non-default by construction."""
        space = tile_space("dcim_mac", (64, 256, 256))
        assert DEFAULT_TILES["dcim_mac"] not in space
        assert all(tc.bm <= 64 for tc in space)

    def test_vmem_budget_respected(self):
        for tc in tile_space("dcim_mac", (4096, 4096, 4096)):
            work = tc.depth * (tc.bm * tc.bk + tc.bk * tc.bn) + 4 * tc.bm * tc.bn
            assert work <= VMEM_BUDGET_BYTES

    def test_unknown_kernel_raises(self):
        with pytest.raises(ValueError, match="unknown kernel"):
            tile_space("nope", (8, 8))

    def test_shape_class_buckets_pow2(self):
        assert shape_class("dcim_mac", (100, 500, 512)) == \
            "dcim_mac:128x512x512"
        assert shape_class("ssm_scan", (1, 32)) == "ssm_scan:1x32"

    def test_tile_config_dict_roundtrip(self):
        tc = TileConfig(bm=64, bn=256, bk=128, depth=4)
        assert TileConfig.from_dict(tc.as_dict()) == tc

    def test_resolve_fills_from_default(self):
        tc = resolve_tile("dcim_mac", TileConfig(bm=64))
        assert (tc.bm, tc.bn, tc.bk) == (64, 128, 128)


class TestAutotune:
    def test_nondefault_winner_and_registry_roundtrip(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        res = autotune.autotune("dcim_mac", (64, 128, 128), iters=1,
                                registry=reg)
        assert res.picked_nondefault
        assert res.winner.bm <= 64
        assert res.frontier and all(res.candidates[i].ok
                                    for i in res.frontier)
        # Round-trip: a fresh process (cleared memo) resolves "auto" to the
        # persisted winner.
        autotune.clear_memo()
        assert autotune.lookup("dcim_mac", (64, 128, 128),
                               registry=reg) == res.winner
        # Same shape *class* shares the tuning (33 buckets up to 64).
        autotune.clear_memo()
        assert autotune.lookup("dcim_mac", (33, 128, 128),
                               registry=reg) == res.winner

    def test_lookup_cold_falls_back_to_default(self):
        assert autotune.lookup("ssm_scan", (10_000, 256)) == \
            DEFAULT_TILES["ssm_scan"]

    def test_auto_dispatch_through_entry_point(self, tmp_path):
        """tile_config='auto' end-to-end: tune, install the registry, run."""
        from repro.kernels import dcim_matmul_int
        from repro.kernels.dcim_mac.ref import dcim_matmul_int_ref
        reg = ArtifactRegistry(tmp_path)
        autotune.autotune("dcim_mac", (48, 128, 128), iters=1, registry=reg)
        autotune.clear_memo()
        autotune.set_registry(reg)
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.integers(-8, 8, (48, 128)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (128, 128)), jnp.int8)
        out = dcim_matmul_int(a, w, use_pallas=True, interpret=True,
                              tile_config="auto")
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(dcim_matmul_int_ref(a, w)))
        assert reg.stats.hits == 1

    @pytest.mark.parametrize("kernel,shape", [("ssm_scan", (96, 128)),
                                              ("csa_tree", (48, 256))])
    def test_other_kernels_tune(self, kernel, shape):
        res = autotune.autotune(kernel, shape, iters=1)
        assert res.candidates and all(c.ok for c in res.candidates)
        assert res.payload()["tile"] == res.winner.as_dict()

    def test_key_moves_with_backend(self, monkeypatch):
        k1 = tile_key("dcim_mac", (64, 128, 128))
        import jax
        monkeypatch.setattr(jax, "__version__", "999.0.0")
        assert tile_key("dcim_mac", (64, 128, 128)) != k1


class TestRegistryPayloads:
    def test_publish_fetch_roundtrip(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        reg.publish_payload("k1", {"tile": {"bm": 64}}, schema=TILE_SCHEMA)
        assert reg.fetch_payload("k1", schema=TILE_SCHEMA) == \
            {"tile": {"bm": 64}}
        # No-op republish (content addressing).
        reg.publish_payload("k1", {"tile": {"bm": 64}}, schema=TILE_SCHEMA)
        assert reg.stats.fill_noops == 1

    def test_wrong_schema_quarantined(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        reg.publish_payload("k1", {"x": 1}, schema="other-schema/v1")
        assert reg.fetch_payload("k1", schema=TILE_SCHEMA) is None
        assert reg.stats.corrupt == 1
        assert not reg.has("k1")          # slot clean for the next publish

    def test_corrupt_bytes_quarantined(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        reg.object_path("k2").write_text("{not json")
        assert reg.fetch_payload("k2", schema=TILE_SCHEMA) is None
        assert reg.stats.corrupt == 1

    def test_key_mismatch_rejected(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        reg.publish_payload("k3", {"x": 1}, schema=TILE_SCHEMA)
        blob = json.loads(reg.object_path("k3").read_text())
        blob["key"] = "other"
        reg.object_path("k3").write_text(json.dumps(blob))
        assert reg.fetch_payload("k3", schema=TILE_SCHEMA) is None

    def test_scope_record_enables_invalidation(self, tmp_path):
        reg = ArtifactRegistry(tmp_path)
        reg.publish_payload("k4", {"x": 1}, schema=TILE_SCHEMA,
                            scope={"backend": "digest-a"})
        assert reg.invalidate_digests({"digest-a"}) == ["k4"]
        assert reg.fetch_payload("k4", schema=TILE_SCHEMA) is None


class TestProfile:
    @pytest.mark.parametrize("kernel,shape", [("dcim_mac", (32, 128, 128)),
                                              ("ssm_scan", (64, 128)),
                                              ("csa_tree", (600, 256))])
    def test_profile_smoke(self, kernel, shape):
        p = profile_kernel(kernel, shape, iters=1)
        assert p.t_fused_us > 0 and p.t_copy_us >= 0
        assert p.bound in ("bandwidth", "compute")
        assert 0.0 <= p.roofline_fraction <= 1.0
        assert p.bytes_moved > 0 and p.flops > 0
        assert p.compute_measured == (kernel != "csa_tree")
        d = p.as_dict()
        assert d["kernel"] == kernel and d["tile"]["depth"] >= 1

    def test_fraction_aggregation(self):
        mk = lambda f: KernelProfile("dcim_mac", (1, 1, 1), TileConfig(),
                                     f, 0.0, 1.0, 1, 1, True)
        assert fraction_from_profiles([]) == 1.0
        assert abs(fraction_from_profiles([mk(0.25), mk(1.0)])
                   - 0.5) < 1e-9

    def test_roofline_feed_in(self):
        class G:
            m, k, n, count = 64, 256, 256, 1
        ideal = dcim_serving_bound([G()], 1e-3)
        derated = dcim_serving_bound([G()], 1e-3, kernel_fraction=0.5)
        assert ideal.kernel_fraction == 1.0
        assert derated.t_macro_s == pytest.approx(2 * ideal.t_macro_s)
        assert "kernel_fraction" in derated.summary()
        assert "kernel_fraction" not in ideal.summary()
        with pytest.raises(ValueError, match="kernel_fraction"):
            dcim_serving_bound([G()], 1e-3, kernel_fraction=0.0)
