"""Request-scoped spans: trace_id/span_id context from frontend to kernels.

A trace is born when a request is admitted (or when a blocking
``SynthesisService.serve`` call starts); every layer underneath — cache
tier probes, the fused engine pass, kernel dispatch — opens child spans
that inherit the trace through a :mod:`contextvars` variable, so the
frontend's scheduler thread and the caller thread each see their own
current span without locks.  Cross-thread handoff is explicit: the
frontend captures each ticket's :class:`SpanContext` at submit time and
re-activates it around the work done on the scheduler thread
(``Tracer.activate``), the same way the response timestamps already
travel on the ``_Entry``.

Tracing is OFF by default.  Disabled (or unsampled) traces take the
:data:`NOOP_SPAN` fast path — one contextvar read and an ``is None``
check, no allocation — which is what keeps the tracing-off overhead on
``service/p50_latency_ms`` under 1% (asserted in CI via
``obs/trace_overhead_pct``).

Span timestamps default to the tracer clock (``time.monotonic``, the
same clock ``SynthesisResponse`` stamps use) but can be passed
explicitly — the frontend does this so the ``request.queued`` /
``request.batched`` span boundaries *equal* the response's
``queued_at``/``batched_at``/``served_at`` rather than approximating
them.

    from repro.obs import tracer
    tracer.configure(enabled=True)
    with tracer.start_trace("request", tags={"key": k}) as root:
        with tracer.span("cache.mem"):
            ...
    spans = tracer.drain()
"""

from __future__ import annotations

import contextlib
import contextvars
import random
import threading
import time
from dataclasses import dataclass, field

from .metrics import get_registry


@dataclass(frozen=True)
class SpanContext:
    """The minimal cross-thread handle: which trace, which parent span."""

    trace_id: str
    span_id: str
    sampled: bool = True


@dataclass
class Span:
    """One finished (or in-flight) span; plain data, exporter-friendly."""

    name: str
    trace_id: str
    span_id: str
    parent_id: str | None
    start_s: float
    end_s: float | None = None
    tags: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.end_s is None else self.end_s - self.start_s

    @property
    def context(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    def as_dict(self) -> dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "start_s": self.start_s, "end_s": self.end_s,
                "duration_s": self.duration_s,
                "tags": dict(self.tags)}


class _NoopSpan:
    """The disabled-tracing fast path: every operation is a no-op, and it
    nests as a context manager so instrumented code never branches."""

    __slots__ = ()
    context = None
    trace_id = ""
    span_id = ""

    def set_tag(self, key, value):
        return self

    def finish(self, end_s=None):
        return None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __bool__(self):
        return False

    def __repr__(self):
        return "NOOP_SPAN"


NOOP_SPAN = _NoopSpan()

_current: contextvars.ContextVar[SpanContext | None] = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class SpanHandle:
    """A live span: tag it, finish it, or use it as a context manager
    (which also makes it the current span for code underneath)."""

    __slots__ = ("_tracer", "span", "_token")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span
        self._token = None

    @property
    def context(self) -> SpanContext:
        return self.span.context

    @property
    def trace_id(self) -> str:
        return self.span.trace_id

    @property
    def span_id(self) -> str:
        return self.span.span_id

    def set_tag(self, key: str, value) -> "SpanHandle":
        self.span.tags[key] = value
        return self

    def finish(self, end_s: float | None = None) -> Span:
        if self.span.end_s is None:
            self.span.end_s = (self._tracer.clock()
                               if end_s is None else end_s)
            self._tracer._record(self.span)
        return self.span

    def __enter__(self) -> "SpanHandle":
        self._token = _current.set(self.span.context)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        if exc_type is not None and "error" not in self.span.tags:
            self.set_tag("error", exc_type.__name__)
        self.finish()
        return False

    def __bool__(self) -> bool:
        return True

    def __repr__(self) -> str:
        state = "open" if self.span.end_s is None else "finished"
        return f"SpanHandle({self.span.name}, {state})"


class Tracer:
    """Collects spans into a bounded in-memory buffer.

    ``enabled=False`` (the default) short-circuits every entry point to
    :data:`NOOP_SPAN`.  ``sample`` in (0, 1] applies at *trace-root*
    creation only — a trace is either fully recorded or fully noop, so
    exported timelines never have orphan children."""

    MAX_SPANS = 100_000   # drop (and count) beyond this, never grow unbounded

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self._enabled = False
        self._sample = 1.0
        self._rng = random.Random(0xD01)
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._counter = 0

    # -- configuration ----------------------------------------------------

    def configure(self, enabled: bool | None = None,
                  sample: float | None = None,
                  clock=None) -> "Tracer":
        if enabled is not None:
            self._enabled = bool(enabled)
        if sample is not None:
            if not 0.0 < sample <= 1.0:
                raise ValueError(f"sample rate must be in (0, 1], got "
                                 f"{sample}")
            self._sample = float(sample)
        if clock is not None:
            self.clock = clock
        return self

    @property
    def enabled(self) -> bool:
        return self._enabled

    @property
    def sample_rate(self) -> float:
        return self._sample

    # -- ids ---------------------------------------------------------------

    def _new_id(self) -> str:
        with self._lock:
            self._counter += 1
            n = self._counter
        return f"{n:08x}{self._rng.getrandbits(32):08x}"

    # -- span creation -----------------------------------------------------

    def current(self) -> SpanContext | None:
        """The context-local current span, if any."""
        return _current.get()

    def start_trace(self, name: str, tags: dict | None = None,
                    start_s: float | None = None):
        """Open a trace root.  Applies sampling; returns NOOP_SPAN when
        disabled or the trace is not sampled."""
        if not self._enabled:
            return NOOP_SPAN
        if self._sample < 1.0 and self._rng.random() >= self._sample:
            get_registry().counter("obs/traces_unsampled").inc()
            return NOOP_SPAN
        get_registry().counter("obs/traces_started").inc()
        tid = self._new_id()
        span = Span(name=name, trace_id=tid, span_id=self._new_id(),
                    parent_id=None,
                    start_s=self.clock() if start_s is None else start_s,
                    tags=dict(tags or {}))
        return SpanHandle(self, span)

    def start(self, name: str, parent: SpanContext | None = None,
              tags: dict | None = None, start_s: float | None = None):
        """Open a child span under ``parent`` (default: the context-local
        current span).  NOOP when disabled or there is no live parent —
        children never start orphan traces of their own."""
        if not self._enabled:
            return NOOP_SPAN
        if parent is None:
            parent = _current.get()
        if parent is None:
            return NOOP_SPAN
        span = Span(name=name, trace_id=parent.trace_id,
                    span_id=self._new_id(), parent_id=parent.span_id,
                    start_s=self.clock() if start_s is None else start_s,
                    tags=dict(tags or {}))
        return SpanHandle(self, span)

    def span(self, name: str, parent: SpanContext | None = None,
             tags: dict | None = None):
        """Alias for :meth:`start` — reads as a context manager."""
        return self.start(name, parent=parent, tags=tags)

    @contextlib.contextmanager
    def activate(self, ctx: SpanContext | None):
        """Make ``ctx`` the context-local current span for a block — the
        cross-thread handoff primitive (scheduler thread re-activating a
        ticket's context).  ``None`` deactivates (no current span)."""
        token = _current.set(ctx)
        try:
            yield ctx
        finally:
            _current.reset(token)

    # -- collection --------------------------------------------------------

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) >= self.MAX_SPANS:
                get_registry().counter("obs/spans_dropped").inc()
                return
            self._spans.append(span)
        get_registry().counter("obs/spans_finished").inc()

    def spans(self) -> list[Span]:
        """Snapshot of finished spans (oldest first)."""
        with self._lock:
            return list(self._spans)

    def drain(self) -> list[Span]:
        """Return finished spans and clear the buffer."""
        with self._lock:
            out = self._spans
            self._spans = []
        return out

    def clear(self) -> None:
        with self._lock:
            self._spans = []


#: The process-global tracer every instrumented layer talks to.
tracer = Tracer()
