"""`repro.obs` — dependency-free tracing + metrics for the serving stack.

One subsystem answers "why was this response slow?" end to end:

- :mod:`repro.obs.tracing` — request-scoped spans (trace_id/span_id via
  contextvars, thread-safe across the frontend's scheduler thread) opened
  at frontend admission, per cache tier probed, around the fused engine
  pass (via the engine's own execute/latency hooks — strategies stay
  untouched), and at kernel dispatch.
- :mod:`repro.obs.metrics` — typed counters/gauges/histograms with
  fixed-bucket p50/p99; the components' ``telemetry()`` dicts are thin
  views over it (:class:`StatsView`), byte-identical key sets.
- :mod:`repro.obs.export` — JSON-lines span log + Chrome ``trace_event``
  timelines (``launch.serve --dcim-trace PATH``, Perfetto-loadable) and
  :func:`metrics_snapshot` text exposition.

Tracing is off by default; :func:`configure` turns it on (optionally with
a sampling rate) and the disabled path is a single contextvar read —
≤1% overhead on ``service/p50_latency_ms``, asserted in CI.
"""

from __future__ import annotations

from .export import (chrome_trace_events, span_dicts, write_chrome_trace,
                     write_spans_jsonl)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, StatsView,
                      get_registry, metrics_snapshot)
from .tracing import NOOP_SPAN, Span, SpanContext, SpanHandle, Tracer, tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "get_registry", "metrics_snapshot",
    "NOOP_SPAN", "Span", "SpanContext", "SpanHandle", "Tracer", "tracer",
    "span_dicts", "write_spans_jsonl", "chrome_trace_events",
    "write_chrome_trace",
    "configure", "install_engine_hooks", "uninstall_engine_hooks",
]


def configure(enabled: bool | None = None, sample: float | None = None,
              clock=None) -> Tracer:
    """Configure the global tracer; enabling also installs the engine
    execute/latency span hooks (idempotent)."""
    tracer.configure(enabled=enabled, sample=sample, clock=clock)
    if tracer.enabled:
        install_engine_hooks()
    return tracer


# -- engine phase spans via the engine's own observation hooks --------------
#
# The execute hook opens an "engine.execute" span as the pass starts; the
# latency hook closes it with the engine's own measured elapsed time.  Spans
# attach to whatever context is current on the executing thread (the
# service activates the engine-pass span around E.execute), so strategies
# and the engine's pipeline stay untouched.

_pending: dict[int, object] = {}
_installed = False


def _on_execute(plan) -> None:
    get_registry().counter("engine/executions").inc()
    if not tracer.enabled:
        return
    span = tracer.start("engine.execute",
                        tags={"mode": plan.placement.mode,
                              "n_specs": len(plan),
                              "n_groups": len(plan.groups),
                              "n_dev": plan.placement.n_dev})
    if span:
        _pending[id(plan)] = span


def _on_latency(plan, elapsed_s: float) -> None:
    get_registry().histogram("engine/pass_latency_s").observe(elapsed_s)
    span = _pending.pop(id(plan), None)
    if span is not None:
        span.finish(end_s=span.span.start_s + elapsed_s)


def install_engine_hooks() -> None:
    """Register the engine execute/latency span hooks (idempotent).
    Imports the engine lazily so ``repro.obs`` itself stays importable
    without jax."""
    global _installed
    if _installed:
        return
    from ..core import engine
    engine.add_execute_hook(_on_execute)
    engine.add_latency_hook(_on_latency)
    _installed = True


def uninstall_engine_hooks() -> None:
    global _installed
    if not _installed:
        return
    from ..core import engine
    try:
        engine.remove_execute_hook(_on_execute)
    except ValueError:
        pass
    try:
        engine.remove_latency_hook(_on_latency)
    except ValueError:
        pass
    _pending.clear()
    _installed = False
