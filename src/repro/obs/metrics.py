"""Typed metrics for the serving stack: counters, gauges, histograms.

One :class:`MetricsRegistry` is the single source of truth for every
counter the stack reports.  The per-component stats objects the earlier
PRs grew (``ServiceStats``, ``CacheStats``, ``RegistryStats``,
``FrontendStats``) are now :class:`StatsView` subclasses — their integer
attributes are *views over registry counters*, so ``stats.requests += 1``
keeps working at every historical call site while the value itself lives
in a registry that exporters and dashboards can walk.  The components'
``telemetry()`` dicts therefore keep byte-identical key sets (snapshot
tested) while delegating to the registry.

Histograms use fixed log-spaced buckets so p50/p99 latency quantiles come
out of pure-python bucket interpolation — no numpy on the hot path, and a
bounded memory footprint regardless of sample count.

Everything here is stdlib-only and thread-safe (one lock per metric; the
increments themselves are as racy as the plain-int fields they replace,
which is to say: not, under the GIL's read-modify-write granularity for
the purposes these counters serve).

    from repro.obs import get_registry, metrics_snapshot
    get_registry().counter("kernel/dcim_mac/dispatch").inc()
    print(metrics_snapshot())
"""

from __future__ import annotations

import threading
import weakref

#: Default histogram bucket upper bounds (seconds): log-spaced 1-2-5 decades
#: from 1 µs to 60 s — wide enough for span durations from a cache probe to
#: a cold exhaustive sweep, and fixed so quantiles never allocate.
DEFAULT_BUCKETS = (
    1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4,
    1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2, 0.1, 0.2, 0.5,
    1.0, 2.0, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """A monotonic integer counter."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, value: int) -> None:
        """Direct assignment — the escape hatch :class:`StatsView` field
        writes (``stats.x += 1`` desugars to get-then-set) resolve to."""
        with self._lock:
            self._value = int(value)

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Gauge:
    """A point-in-time float value (queue depth, window size, fraction)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self._value})"


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    ``observe(v)`` is O(len(buckets)) worst case (a linear scan over ~24
    bounds — no allocation, no numpy); quantiles linearly interpolate
    inside the bucket where the requested rank falls, which is exact
    enough for p50/p99 latency tracking and never touches the samples
    themselves (none are kept)."""

    __slots__ = ("name", "bounds", "_counts", "_count", "_sum", "_min",
                 "_max", "_lock")

    def __init__(self, name: str, bounds: tuple[float, ...] | None = None):
        self.name = name
        self.bounds = tuple(float(b) for b in (bounds or DEFAULT_BUCKETS))
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram bucket bounds must be sorted")
        self._counts = [0] * (len(self.bounds) + 1)   # +1: overflow bucket
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            i = 0
            for i, bound in enumerate(self.bounds):
                if value <= bound:
                    break
            else:
                i = len(self.bounds)
            self._counts[i] += 1
            self._count += 1
            self._sum += value
            self._min = min(self._min, value)
            self._max = max(self._max, value)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """Interpolated quantile estimate (0 <= q <= 1); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self._count == 0:
                return 0.0
            rank = q * self._count
            seen = 0
            for i, n in enumerate(self._counts):
                if n == 0:
                    continue
                if seen + n >= rank:
                    lo = 0.0 if i == 0 else self.bounds[i - 1]
                    hi = (self._max if i == len(self.bounds)
                          else self.bounds[i])
                    lo = max(lo, self._min) if i == 0 else lo
                    frac = (rank - seen) / n
                    return min(lo + (hi - lo) * frac, self._max)
                seen += n
            return self._max

    def summary(self) -> dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0}
        return {"count": self._count, "sum": self._sum,
                "min": self._min, "max": self._max,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}

    def __repr__(self) -> str:
        return f"Histogram({self.name}, n={self._count})"


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.

    A registry is cheap; every component stats object owns one (so two
    services in one process never share counters — the per-instance
    semantics the existing tests pin), and all registries are enumerable
    through :func:`metrics_snapshot` for the process-wide exposition."""

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}
        _COMPONENTS.add(self)

    def _get_or_create(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None) -> Histogram:
        return self._get_or_create(name, Histogram, bounds)

    def get(self, name: str):
        return self._metrics.get(name)

    def value(self, name: str):
        m = self._metrics.get(name)
        return None if m is None else m.value

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def as_dict(self) -> dict:
        """{name: value | histogram summary} snapshot of this registry."""
        out = {}
        for name in self.names():
            m = self._metrics[name]
            out[name] = (m.summary() if isinstance(m, Histogram)
                         else m.value)
        return out

    def expose(self) -> str:
        """Plain-text exposition, one ``name value`` line per metric
        (histograms expand to ``name{count|sum|p50|p99}`` lines)."""
        lines = []
        for name, val in self.as_dict().items():
            if isinstance(val, dict):
                for k, v in val.items():
                    lines.append(f"{name}{{{k}}} {v}")
            else:
                lines.append(f"{name} {val}")
        return "\n".join(lines)


#: Every live registry, weakly held — what :func:`metrics_snapshot` walks.
#: Weak so short-lived test services don't accumulate forever.
_COMPONENTS: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()

#: The process-global registry: kernel dispatch counters, engine pass
#: latency, span accounting — everything not scoped to one component
#: instance.
_GLOBAL = MetricsRegistry("process")


def get_registry() -> MetricsRegistry:
    """The process-global registry."""
    return _GLOBAL


def metrics_snapshot() -> str:
    """Text exposition across every live registry in the process — the
    one dump a fleet agent scrapes.  Component registries (per-service
    stats and friends) are prefixed with their namespace; the global
    registry comes first, unprefixed."""
    chunks = [_GLOBAL.expose()]
    others = sorted((r for r in _COMPONENTS if r is not _GLOBAL),
                    key=lambda r: (r.namespace, id(r)))
    seen: dict[str, int] = {}
    for reg in others:
        if not reg.names():
            continue
        n = seen[reg.namespace] = seen.get(reg.namespace, 0) + 1
        prefix = f"{reg.namespace or 'component'}[{n - 1}]"
        body = reg.expose()
        chunks.append("\n".join(f"{prefix}/{line}"
                                for line in body.splitlines()))
    return "\n".join(c for c in chunks if c)


class StatsView:
    """Base for component stats: integer attributes backed by registry
    counters.

    Subclasses declare ``_FIELDS`` (the attribute names, in the order the
    historical ``as_dict()`` emitted them) and ``_NAMESPACE``.  Attribute
    reads return plain ints and ``stats.x += 1`` / ``stats.x = v`` write
    through to the counter, so every existing call site and test works
    unchanged — but the numbers live in a :class:`MetricsRegistry` the
    observability layer can export."""

    _FIELDS: tuple[str, ...] = ()
    _NAMESPACE = "stats"

    def __init__(self, metrics: MetricsRegistry | None = None):
        if metrics is None:
            metrics = MetricsRegistry(self._NAMESPACE)
        object.__setattr__(self, "metrics", metrics)
        for f in self._FIELDS:
            metrics.counter(f"{self._NAMESPACE}/{f}")

    def _counter(self, field: str) -> Counter:
        return self.metrics.counter(f"{self._NAMESPACE}/{field}")

    def __getattr__(self, name: str):
        if name in type(self)._FIELDS:
            return self._counter(name).value
        raise AttributeError(f"{type(self).__name__} has no attribute "
                             f"{name!r}")

    def __setattr__(self, name: str, value) -> None:
        if name in type(self)._FIELDS:
            self._counter(name).set(value)
        else:
            object.__setattr__(self, name, value)

    def as_dict(self) -> dict:
        """The historical telemetry dict — identical key set and order,
        now a view over the metrics registry."""
        return {f: self._counter(f).value for f in self._FIELDS}

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsView):
            return (type(self) is type(other)
                    and self.as_dict() == other.as_dict())
        return NotImplemented

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"{type(self).__name__}({body})"
