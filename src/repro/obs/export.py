"""Span exporters: JSON-lines log and Chrome ``trace_event`` timelines.

Both formats are plain stdlib-json over :meth:`Span.as_dict`.  The Chrome
format (``{"traceEvents": [...]}`` with complete ``"ph": "X"`` events) is
what ``launch.serve --dcim-trace PATH`` writes; load it at
https://ui.perfetto.dev (or chrome://tracing) to see each request's
queued→batched→served lane with cache-tier and engine-pass child spans.

Chrome events use microsecond timestamps relative to the earliest span in
the export (the tracer clock is ``time.monotonic``, whose epoch is
arbitrary).  Each trace gets its own ``tid`` lane named after the trace
root, so concurrent requests render as parallel rows instead of one
interleaved smear.
"""

from __future__ import annotations

import json

from .tracing import Span


def span_dicts(spans: list[Span]) -> list[dict]:
    return [s.as_dict() for s in spans]


def write_spans_jsonl(spans: list[Span], path) -> int:
    """One span per line; returns the number written."""
    with open(path, "w") as fh:
        for s in spans:
            fh.write(json.dumps(s.as_dict(), sort_keys=True) + "\n")
    return len(spans)


def chrome_trace_events(spans: list[Span]) -> list[dict]:
    """Convert spans to Chrome ``trace_event`` dicts (complete events).

    One ``tid`` per trace, with ``thread_name`` metadata naming the lane
    after the trace's root span (e.g. ``request[ab12cd34]``)."""
    if not spans:
        return []
    t0 = min(s.start_s for s in spans)
    roots = {s.trace_id: s for s in spans if s.parent_id is None}
    tids: dict[str, int] = {}
    events: list[dict] = []
    for s in spans:
        tid = tids.get(s.trace_id)
        if tid is None:
            tid = tids[s.trace_id] = len(tids) + 1
            root = roots.get(s.trace_id)
            label = (f"{root.name}[{s.trace_id[:8]}]" if root is not None
                     else f"trace[{s.trace_id[:8]}]")
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": label}})
        end_s = s.end_s if s.end_s is not None else s.start_s
        args = {"trace_id": s.trace_id, "span_id": s.span_id}
        if s.parent_id:
            args["parent_id"] = s.parent_id
        args.update(s.tags)
        events.append({
            "ph": "X", "name": s.name, "pid": 1, "tid": tid,
            "ts": (s.start_s - t0) * 1e6,
            "dur": max(end_s - s.start_s, 0.0) * 1e6,
            "cat": s.name.split(".", 1)[0],
            "args": args,
        })
    return events


def write_chrome_trace(spans: list[Span], path) -> int:
    """Write a Perfetto/chrome-tracing loadable JSON; returns the number
    of span events written (metadata events excluded)."""
    events = chrome_trace_events(spans)
    with open(path, "w") as fh:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, fh)
    return sum(1 for e in events if e["ph"] == "X")
