"""DMA-vs-compute profiling for the DCIM-path kernels.

Multi-buffered pipelines only pay off when operand streaming of chunk t+1
genuinely overlaps compute on chunk t — and tuning buffer depth only makes
sense once you know which side of the pipeline is the bottleneck.  This
harness answers that per ``(kernel, shape, tile)`` by timing three
skeletons of the *same* kernel body (the ``_mode`` static argument of the
pipelined kernels):

  copy     DMA rotation runs, math is skipped (a one-element data
           dependency into the output defeats DCE) → streaming time;
  compute  DMA is skipped, the math runs on resident slot-0 buffers
           → arithmetic time;
  fused    the real kernel → what actually ships.

Classification: a kernel is **bandwidth-bound** when the copy skeleton
dominates (``t_copy >= t_compute``), compute-bound otherwise.  The
``roofline_fraction`` is ``max(t_copy, t_compute) / t_fused`` — how close
the fused pipeline comes to fully hiding the cheaper side under the more
expensive one (1.0 = perfect overlap; 0.5 = no overlap at all for balanced
sides).  ``repro.roofline.dcim`` accepts this fraction to derate its
analytic serving bound with a measured pipeline efficiency.

``csa_tree`` has no manual pipeline (BlockSpec streaming cannot be turned
off), so its compute time is *derived* as ``max(fused - copy, 0)`` and
flagged ``compute_measured=False``.

Off-TPU the skeletons run in Pallas interpret mode: absolute times are
meaningless there, but the plumbing (modes, shapes, report format) is
identical, which is what CI exercises.
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .tiles import TileConfig, resolve_tile

#: Mode tags understood by the pipelined kernels' ``_mode`` argument.
MODES = ("copy", "compute", "fused")

#: Schema tag of the persisted kernel-profile artifact
#: (``scripts/profile_kernels.py --json`` output, consumed by
#: ``repro.launch.serve --dcim-kernel-profile``).
PROFILE_SCHEMA = "syndcim-kernel-profile/v1"


@dataclass
class KernelProfile:
    """Timing split of one (kernel, shape, tile) point."""

    kernel: str
    shape: tuple[int, ...]
    tile: TileConfig
    t_copy_us: float
    t_compute_us: float
    t_fused_us: float
    bytes_moved: int          # analytic HBM traffic of one fused launch
    flops: int                # analytic arithmetic of one fused launch
    compute_measured: bool    # False when compute was derived (csa_tree)

    @property
    def bound(self) -> str:
        return "bandwidth" if self.t_copy_us >= self.t_compute_us else "compute"

    @property
    def roofline_fraction(self) -> float:
        """Pipeline efficiency: the slower skeleton over the fused time
        (1.0 = the cheap side is perfectly hidden)."""
        if self.t_fused_us <= 0.0:
            return 0.0
        return min(1.0, max(self.t_copy_us, self.t_compute_us)
                   / self.t_fused_us)

    def as_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "shape": list(self.shape),
            "tile": self.tile.as_dict(),
            "t_copy_us": self.t_copy_us,
            "t_compute_us": self.t_compute_us,
            "t_fused_us": self.t_fused_us,
            "bytes_moved": self.bytes_moved,
            "flops": self.flops,
            "bound": self.bound,
            "roofline_fraction": self.roofline_fraction,
            "compute_measured": self.compute_measured,
        }


def _traffic(kernel: str, shape: tuple[int, ...]) -> tuple[int, int]:
    """(bytes_moved, flops) of one fused launch, analytic."""
    if kernel == "dcim_mac":
        m, k, n = shape
        return m * k + k * n + 4 * m * n, 2 * m * k * n
    if kernel == "ssm_scan":
        t, d = shape
        # in: a, b; out: states (+ final).  Doubling scan: ~3 vector ops per
        # level, log2(bt)~7 levels at the default chunk, plus the carry fix.
        levels = 7
        return 4 * (3 * t * d + d), t * d * (3 * levels + 2)
    if kernel == "csa_tree":
        h, n = shape
        # ~5 bitwise ops per lane per reduced row (FA: 3 xor/and + or + shift)
        return 4 * (h * n + n), 5 * h * n
    raise ValueError(f"unknown kernel {kernel!r}")


def _runner(kernel: str, shape: tuple[int, ...], tc: TileConfig,
            interpret: bool):
    """mode -> zero-arg callable running one launch."""
    rng = np.random.default_rng(1)
    if kernel == "dcim_mac":
        from .dcim_mac.kernel import dcim_matmul_int_pipelined_pallas
        m, k, n = shape
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
        depth = max(2, tc.depth)

        def run(mode: str):
            return dcim_matmul_int_pipelined_pallas(
                a, w, bm=tc.bm, bn=tc.bn, bk=tc.bk, depth=depth,
                interpret=interpret, _mode=mode)
    elif kernel == "ssm_scan":
        from .ssm_scan.kernel import ssm_scan_pipelined_pallas
        t, d = shape
        a = jnp.asarray(0.9 + 0.05 * rng.standard_normal((t, d)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        h0 = jnp.zeros((d,), jnp.float32)
        depth = max(2, tc.depth)

        def run(mode: str):
            return ssm_scan_pipelined_pallas(
                a, b, h0, bt=tc.bt, bd=tc.bd, depth=depth,
                interpret=interpret, _mode=mode)
    elif kernel == "csa_tree":
        from .csa_tree.kernel import csa_tree_tiled_pallas
        h, n = shape
        x = jnp.asarray(rng.integers(-1000, 1000, (h, n)), jnp.int32)

        def run(mode: str):
            return csa_tree_tiled_pallas(x, bh=tc.bh, bn=tc.bn,
                                         interpret=interpret, _mode=mode)
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return run


def _time_us(fn, iters: int) -> float:
    jax.block_until_ready(fn())          # compile + warm outside the clock
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def profile_kernel(kernel: str, shape: tuple[int, ...], *,
                   tile_config: TileConfig | None = None, iters: int = 3,
                   interpret: bool | None = None) -> KernelProfile:
    """Time the copy / compute / fused skeletons of one kernel launch."""
    shape = tuple(int(d) for d in shape)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tc = resolve_tile(kernel, tile_config)
    run = _runner(kernel, shape, tc, interpret)

    t_fused = _time_us(lambda: run("fused"), iters)
    t_copy = _time_us(lambda: run("copy"), iters)
    if kernel == "csa_tree":
        t_compute, measured = max(t_fused - t_copy, 0.0), False
    else:
        t_compute, measured = _time_us(lambda: run("compute"), iters), True

    nbytes, flops = _traffic(kernel, shape)
    return KernelProfile(kernel=kernel, shape=shape, tile=tc,
                         t_copy_us=t_copy, t_compute_us=t_compute,
                         t_fused_us=t_fused, bytes_moved=nbytes,
                         flops=flops, compute_measured=measured)


def fraction_from_profiles(profiles) -> float:
    """Aggregate roofline fraction for the serving-bound derate: the
    geometric mean of per-kernel fractions (each in (0, 1]) — duck-typed so
    :mod:`repro.roofline.dcim` need not import this module."""
    fracs = [max(1e-6, float(p.roofline_fraction)) for p in profiles]
    if not fracs:
        return 1.0
    return float(math.exp(sum(math.log(f) for f in fracs) / len(fracs)))


def profiles_payload(profiles) -> dict:
    """The machine-readable artifact of one profiling run: schema tag, the
    per-point timing splits, and the pre-aggregated serving derate (so the
    consumer need not recompute the geomean)."""
    profiles = list(profiles)
    return {
        "schema": PROFILE_SCHEMA,
        "backend": jax.default_backend(),
        "fraction": fraction_from_profiles(profiles),
        "profiles": [p.as_dict() for p in profiles],
    }


def load_profile_artifact(path) -> dict:
    """Read a kernel-profile artifact; a missing file is an error (the
    launch was pointed at a measurement that must exist).  A legacy bare
    list of profile dicts (pre-schema ``--json`` output) is upgraded in
    memory."""
    p = Path(path)
    data = json.loads(p.read_text())
    if isinstance(data, list):                      # legacy bare list
        fracs = [max(1e-6, float(d["roofline_fraction"])) for d in data]
        frac = (float(math.exp(sum(math.log(f) for f in fracs)
                               / len(fracs))) if fracs else 1.0)
        return {"schema": PROFILE_SCHEMA, "backend": None,
                "fraction": frac, "profiles": data}
    if not isinstance(data, dict) or data.get("schema") != PROFILE_SCHEMA:
        raise ValueError(f"{p}: not a kernel profile "
                         f"(schema={data.get('schema') if isinstance(data, dict) else type(data).__name__!r}, "
                         f"expected {PROFILE_SCHEMA!r})")
    return data


def fraction_from_profile_artifact(path) -> float:
    """The serving-roofline derate recorded in (or derivable from) a
    profile artifact, clamped to (0, 1]."""
    data = load_profile_artifact(path)
    frac = float(data.get("fraction", 1.0))
    if not (0.0 < frac <= 1.0):
        raise ValueError(f"{path}: fraction must be in (0, 1], got {frac}")
    return frac
