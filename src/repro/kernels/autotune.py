"""Tile autotuning for the DCIM-path kernels via the repo's DSE machinery.

The synthesis side of this repo picks subcircuits by sweeping a candidate
lattice, scoring each candidate on multiple objectives, and keeping the
Pareto frontier (:mod:`repro.core.dse`).  The kernel layer reuses exactly
that idiom one level down: for one ``(kernel, shape)`` the tuner

  1. enumerates the feasibility-pruned (block-shape, buffer-depth) lattice
     from :func:`repro.kernels.tiles.tile_space`;
  2. runs every candidate against the kernel's oracle (a mis-computing
     candidate is disqualified, never timed);
  3. times each survivor and scores it on ``(time_us, vmem_bytes)``;
  4. extracts the frontier with :func:`repro.core.pareto.pareto_indices`
     (the same single source of truth the synthesis sweeps use) and picks
     the fastest frontier member as the winner.

Winners persist through the shared :class:`repro.service.registry.
ArtifactRegistry` as generic JSON payloads (schema
:data:`TILE_SCHEMA`), content-addressed by ``(kernel, shape-class,
backend digest)`` — a tuning done once on one host warms the fleet, and a
jax upgrade or backend change silently re-tunes because the address moves.
``lookup`` is the read path the kernels' ``tile_config="auto"`` mode calls:
process memo, then registry, then the static default.
"""

from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pareto import pareto_indices
from .tiles import DEFAULT_TILES, TileConfig, shape_class, tile_space

#: Schema tag of one persisted tile-winner payload.
TILE_SCHEMA = "syndcim-kernel-tile/v1"

#: Exactness contract per kernel: int paths must match the oracle bit-for-
#: bit; the f32 scan reorders the reduction per chunk shape, so it gets a
#: tolerance.
_MAX_ERR = {"dcim_mac": 0.0, "csa_tree": 0.0, "ssm_scan": 1e-3}


def _digest(obj) -> str:
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def backend_digest() -> str:
    """Content digest of the execution substrate a tuning is valid for."""
    return _digest({"jax": jax.__version__,
                    "backend": jax.default_backend()})


def tile_key(kernel: str, shape: tuple[int, ...]) -> str:
    """Registry address of one tuning: (kernel, shape-class, backend)."""
    return _digest({"kind": "kernel-tile", "kernel": kernel,
                    "shape_class": shape_class(kernel, tuple(shape)),
                    "backend": backend_digest()})


def _vmem_bytes(kernel: str, cfg: TileConfig) -> int:
    """Planned VMEM working set of one candidate — the second objective
    (same formulas :func:`repro.kernels.tiles.tile_space` prunes with)."""
    if kernel == "dcim_mac":
        return cfg.depth * (cfg.bm * cfg.bk + cfg.bk * cfg.bn) + 4 * cfg.bm * cfg.bn
    if kernel == "ssm_scan":
        return 4 * (3 * cfg.depth * cfg.bt * cfg.bd + cfg.bd)
    return 4 * (cfg.bh * cfg.bn + cfg.bn)


@dataclass
class CandidateScore:
    """One evaluated lattice point."""

    config: TileConfig
    time_us: float
    vmem_bytes: int
    max_err: float
    ok: bool


@dataclass
class TuneResult:
    """Outcome of one autotune sweep."""

    kernel: str
    shape: tuple[int, ...]
    shape_class: str
    winner: TileConfig
    time_us: float
    picked_nondefault: bool
    candidates: list[CandidateScore] = field(default_factory=list)
    frontier: list[int] = field(default_factory=list)
    key: str = ""

    def payload(self) -> dict:
        """The registry artifact body (JSON-safe)."""
        return {
            "kernel": self.kernel,
            "shape_class": self.shape_class,
            "backend": backend_digest(),
            "tile": self.winner.as_dict(),
            "time_us": self.time_us,
            "picked_nondefault": self.picked_nondefault,
            "n_candidates": len(self.candidates),
            "n_frontier": len(self.frontier),
        }


def _make_case(kernel: str, shape: tuple[int, ...], interpret: bool):
    """Deterministic inputs, oracle output, and a per-config runner."""
    rng = np.random.default_rng(0)
    if kernel == "dcim_mac":
        from .dcim_mac import dcim_matmul_int, ref
        m, k, n = shape
        a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
        want = np.asarray(ref.dcim_matmul_int_ref(a, w), np.int64)

        def run(cfg: TileConfig):
            return dcim_matmul_int(a, w, use_pallas=True,
                                   interpret=interpret, tile_config=cfg)
    elif kernel == "ssm_scan":
        from .ssm_scan import ssm_scan
        from .ssm_scan.ref import ssm_scan_ref
        t, d = shape
        a = jnp.asarray(0.9 + 0.05 * rng.standard_normal((t, d)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
        h0 = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
        want = np.asarray(ssm_scan_ref(a, b, h0)[0], np.float64)

        def run(cfg: TileConfig):
            return ssm_scan(a, b, h0, use_pallas=True, interpret=interpret,
                            tile_config=cfg)[0]
    elif kernel == "csa_tree":
        from .csa_tree import csa_tree_sum
        from .csa_tree.ref import csa_tree_ref
        h, n = shape
        x = jnp.asarray(rng.integers(-1000, 1000, (h, n)), jnp.int32)
        want = np.asarray(csa_tree_ref(x), np.int64)

        def run(cfg: TileConfig):
            return csa_tree_sum(x, use_pallas=True, interpret=interpret,
                                tile_config=cfg)
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return run, want


def _time_us(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def autotune(kernel: str, shape: tuple[int, ...], *, iters: int = 3,
             interpret: bool | None = None, registry=None,
             memoize: bool = True) -> TuneResult:
    """Sweep the tile lattice for ``(kernel, shape)`` and pick a winner.

    ``interpret`` defaults to True off-TPU (where compiled Pallas is
    unavailable; interpret-mode timings still rank launch-count and
    working-set effects deterministically via the feasibility-pruned
    lattice).  When ``registry`` is an :class:`~repro.service.registry.
    ArtifactRegistry`, the winner is published under :func:`tile_key`."""
    shape = tuple(int(d) for d in shape)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    run, want = _make_case(kernel, shape, interpret)
    tol = _MAX_ERR[kernel]

    scores: list[CandidateScore] = []
    for cfg in tile_space(kernel, shape):
        out = np.asarray(jax.block_until_ready(run(cfg)), np.float64)
        err = float(np.max(np.abs(out - want))) if out.size else 0.0
        ok = err <= tol
        t_us = _time_us(lambda: run(cfg), iters) if ok else float("inf")
        scores.append(CandidateScore(cfg, t_us, _vmem_bytes(kernel, cfg),
                                     err, ok))
    live = [i for i, s in enumerate(scores) if s.ok]
    if not live:
        raise RuntimeError(
            f"autotune({kernel}, {shape}): every candidate failed the "
            f"oracle check — kernel bug, not a tuning problem")

    objs = [(scores[i].time_us, float(scores[i].vmem_bytes)) for i in live]
    frontier = [live[j] for j in pareto_indices(objs)]
    win_idx = min(frontier, key=lambda i: scores[i].time_us)
    winner = scores[win_idx].config

    default = DEFAULT_TILES[kernel]
    result = TuneResult(
        kernel=kernel, shape=shape,
        shape_class=shape_class(kernel, shape),
        winner=winner, time_us=scores[win_idx].time_us,
        picked_nondefault=(winner != default),
        candidates=scores, frontier=frontier,
        key=tile_key(kernel, shape))
    if registry is not None:
        registry.publish_payload(result.key, result.payload(),
                                 schema=TILE_SCHEMA)
    if memoize:
        _MEMO[result.key] = winner
    return result


# -- the read path ("auto" tile_config) --------------------------------------

#: Process-wide memo: tile_key -> winning TileConfig.  Misses fall through
#: to the configured registry, then to the static default.
_MEMO: dict[str, TileConfig] = {}

_REGISTRY = None


def set_registry(registry) -> None:
    """Install the process-default registry the ``"auto"`` path consults
    (e.g. the warm-cache script's shared store).  None disables it."""
    global _REGISTRY
    _REGISTRY = registry


def clear_memo() -> None:
    _MEMO.clear()


def lookup_with_source(kernel: str, shape: tuple[int, ...], registry=None
                       ) -> tuple[TileConfig, str]:
    """:func:`lookup` plus where the config came from: ``"memo"`` (process
    memo), ``"registry"`` (shared payload, memoized on the way out), or
    ``"default"`` (the static per-kernel posture) — the attribution the
    kernel-dispatch spans and counters record."""
    shape = tuple(int(d) for d in shape)
    key = tile_key(kernel, shape)
    hit = _MEMO.get(key)
    if hit is not None:
        return hit, "memo"
    reg = registry if registry is not None else _REGISTRY
    if reg is not None:
        payload = reg.fetch_payload(key, schema=TILE_SCHEMA)
        if payload is not None and isinstance(payload.get("tile"), dict):
            cfg = TileConfig.from_dict(payload["tile"])
            _MEMO[key] = cfg
            return cfg, "registry"
    return DEFAULT_TILES[kernel], "default"


def lookup(kernel: str, shape: tuple[int, ...],
           registry=None) -> TileConfig:
    """The tile config ``tile_config="auto"`` resolves to: process memo →
    registry payload → per-kernel default.  Never raises on a cold cache —
    an untuned shape just runs the default posture."""
    return lookup_with_source(kernel, shape, registry=registry)[0]
