"""Public entry points for the DCIM MAC.

``dcim_matmul`` dispatches between the Pallas TPU kernel and an XLA path:

  * On TPU the Pallas kernel runs compiled (interpret=False).
  * On CPU (this container) the *framework* uses the XLA path for speed, and
    tests exercise the Pallas kernel in interpret mode against the oracles.

Both paths compute identical integers (asserted by tests), so the dispatch is
purely a performance decision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import dcim_matmul_int_pallas, dcim_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_pallas",
                                             "interpret"))
def dcim_matmul(a_q: jnp.ndarray, w_q: jnp.ndarray,
                a_scale: jnp.ndarray | float = 1.0,
                w_scale: jnp.ndarray | float = 1.0,
                *, out_dtype=jnp.float32, use_pallas: bool | None = None,
                interpret: bool = False) -> jnp.ndarray:
    """Quantized (M,K)x(K,N) matmul with fused dequant epilogue."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        m, n = a_q.shape[0], w_q.shape[1]
        asc = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m,))
        wsc = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))
        return dcim_matmul_pallas(a_q, w_q, asc, wsc, out_dtype=out_dtype,
                                  interpret=interpret)
    return ref.dcim_matmul_ref(a_q, w_q, a_scale, w_scale, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def dcim_matmul_int(a_q: jnp.ndarray, w_q: jnp.ndarray,
                    *, use_pallas: bool | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Integer-accumulator variant: returns int32 (M,N)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return dcim_matmul_int_pallas(a_q, w_q, interpret=interpret)
    return ref.dcim_matmul_int_ref(a_q, w_q)
