"""Public entry points for the DCIM MAC.

``dcim_matmul`` dispatches between the Pallas TPU kernels and an XLA path:

  * On TPU the Pallas kernel runs compiled (interpret=False).
  * On CPU (this container) the *framework* uses the XLA path for speed, and
    tests exercise the Pallas kernels in interpret mode against the oracles.

Both paths compute identical integers (asserted by tests), so the dispatch is
purely a performance decision.  Within the Pallas path ``tile_config``
selects the launch posture:

  * ``None`` — the per-kernel default (:data:`repro.kernels.tiles.
    DEFAULT_TILES`): 128-blocks, depth-2 manual DMA pipeline;
  * a :class:`repro.kernels.tiles.TileConfig` — explicit blocks/depth
    (``depth == 1`` selects the classic BlockSpec grid kernel, ``>= 2`` the
    multi-buffered pipeline);
  * ``"auto"`` — the autotuner's persisted winner for this shape class
    (:func:`repro.kernels.autotune.lookup`), falling back to the default
    when nothing has been tuned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from ..instrument import dispatch_span
from ..tiles import TileConfig, resolve_tile
from .kernel import (dcim_matmul_int_pallas, dcim_matmul_int_pipelined_pallas,
                     dcim_matmul_pallas, dcim_matmul_pipelined_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(shape: tuple[int, ...],
             tile_config: TileConfig | str | None
             ) -> tuple[TileConfig, str]:
    """The tile config to launch with, plus its attribution: the autotune
    resolution chain for ``"auto"``, ``"explicit"`` for a caller-provided
    config, ``"default"`` for the stock posture."""
    if tile_config == "auto":
        from .. import autotune
        return autotune.lookup_with_source("dcim_mac", shape)
    return (resolve_tile("dcim_mac", tile_config),
            "default" if tile_config is None else "explicit")


def dcim_matmul(a_q: jnp.ndarray, w_q: jnp.ndarray,
                a_scale: jnp.ndarray | float = 1.0,
                w_scale: jnp.ndarray | float = 1.0,
                *, out_dtype=jnp.float32, use_pallas: bool | None = None,
                interpret: bool = False,
                tile_config: TileConfig | str | None = None) -> jnp.ndarray:
    """Quantized (M,K)x(K,N) matmul with fused dequant epilogue."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        m, n = a_q.shape[0], w_q.shape[1]
        asc = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m,))
        wsc = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))
        shape = (m, a_q.shape[1], n)
        tc, source = _resolve(shape, tile_config)
        route = "pipelined" if tc.depth >= 2 else "grid"
        with dispatch_span("dcim_mac", shape, tc, source, route):
            if tc.depth >= 2:
                return dcim_matmul_pipelined_pallas(
                    a_q, w_q, asc, wsc, bm=tc.bm, bn=tc.bn, bk=tc.bk,
                    depth=tc.depth, out_dtype=out_dtype,
                    interpret=interpret)
            return dcim_matmul_pallas(a_q, w_q, asc, wsc, bm=tc.bm,
                                      bn=tc.bn, bk=tc.bk,
                                      out_dtype=out_dtype,
                                      interpret=interpret)
    shape = (a_q.shape[0], a_q.shape[1], w_q.shape[1])
    with dispatch_span("dcim_mac", shape, None, "none", "xla"):
        return _ref_matmul(a_q, w_q, jnp.asarray(a_scale, jnp.float32),
                           jnp.asarray(w_scale, jnp.float32),
                           out_dtype=out_dtype)


def dcim_matmul_int(a_q: jnp.ndarray, w_q: jnp.ndarray,
                    *, use_pallas: bool | None = None,
                    interpret: bool = False,
                    tile_config: TileConfig | str | None = None
                    ) -> jnp.ndarray:
    """Integer-accumulator variant: returns int32 (M,N)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    shape = (a_q.shape[0], a_q.shape[1], w_q.shape[1])
    if use_pallas:
        tc, source = _resolve(shape, tile_config)
        route = "pipelined" if tc.depth >= 2 else "grid"
        with dispatch_span("dcim_mac", shape, tc, source, route):
            if tc.depth >= 2:
                return dcim_matmul_int_pipelined_pallas(
                    a_q, w_q, bm=tc.bm, bn=tc.bn, bk=tc.bk, depth=tc.depth,
                    interpret=interpret)
            return dcim_matmul_int_pallas(a_q, w_q, bm=tc.bm, bn=tc.bn,
                                          bk=tc.bk, interpret=interpret)
    with dispatch_span("dcim_mac", shape, None, "none", "xla"):
        return _ref_matmul_int(a_q, w_q)


_ref_matmul = jax.jit(ref.dcim_matmul_ref, static_argnames=("out_dtype",))
_ref_matmul_int = jax.jit(ref.dcim_matmul_int_ref)
