"""Pallas TPU kernel for the DCIM MAC (the paper's compute hot-spot, adapted
to the MXU — see DESIGN.md §4 "Hardware adaptation").

The circuit mechanism (bit-serial WL streaming + per-column adder trees) has
no MXU analogue; the TPU-native mapping keeps the *roles*:

  weight-stationary SRAM tile   ->  W block resident in VMEM (BlockSpec)
  bitwise multiplier + CSA tree ->  MXU systolic int multiply-accumulate
  S&A (full-width accumulation) ->  int32 accumulator scratch in VMEM
  OFU / alignment epilogue      ->  fused per-channel dequant on final k step

Blocked matmul with grid (M/bm, N/bn, K/bk), k innermost (sequential on TPU)
so the int32 accumulator lives in a VMEM scratch across k steps.  Block shapes
default to MXU-aligned 128 multiples; int8 operands, int32 accumulate
(``preferred_element_type``), bf16/f32 output after the epilogue.

Two execution styles share the wrappers:

  * the classic BlockSpec grid kernel (``depth == 1``): operand staging is
    left to the pipeline the Mosaic compiler builds for the declared blocks;
  * the **multi-buffered manual pipeline** (``depth >= 2``,
    ``dcim_matmul_pipelined_pallas``): A/W live in HBM (``memory_space=ANY``)
    and the kernel streams (bm, bk)/(bk, bn) chunks itself through
    ``pltpu.make_async_copy`` into a ``depth``-slot VMEM scratch rotation, so
    the fetch of K-chunk t+1..t+depth-1 overlaps the MXU pass on chunk t.
    Buffer depth is a tunable the autotuner sweeps.

Both compute identical int32 bits (the adder tree is exact either way);
``_mode`` exposes copy-only / compute-only skeletons of the same pipeline to
the DMA-vs-compute profiling harness (:mod:`repro.kernels.profile`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mac_kernel(a_ref, w_ref, asc_ref, wsc_ref, o_ref, acc_ref, *, k_steps: int,
                out_dtype):
    """One (bm, bn) output tile; accumulates over the k grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: int8 x int8 -> int32 (the adder tree + S&A, full width, one shot).
    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # OFU-equivalent: per-row activation scale x per-column weight scale.
        scale = asc_ref[...].reshape(-1, 1) * wsc_ref[...].reshape(1, -1)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(out_dtype)


def _int_kernel(a_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _out():
        o_ref[...] = acc_ref[...]


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = []
    for dim, m in zip(x.shape, mults):
        rem = (-dim) % m
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def dcim_matmul_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                       a_scale: jnp.ndarray, w_scale: jnp.ndarray,
                       *, bm: int = 128, bn: int = 128, bk: int = 128,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jnp.ndarray:
    """Quantized matmul with fused dequant: (M,K)i8 @ (K,N)i8 -> (M,N).

    ``a_scale``: per-row (M,) f32; ``w_scale``: per-column (N,) f32.
    Shapes are padded up to block multiples and the result sliced back.
    """
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2, (a_q.shape, w_q.shape)
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m,))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))

    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    asc = _pad_to(a_scale, (bm,))
    wsc = _pad_to(w_scale, (bn,))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_mac_kernel, k_steps=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bm,), lambda i, j, t: (i,)),
            pl.BlockSpec((bn,), lambda i, j, t: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p, asc, wsc)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dcim_matmul_int_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                           *, bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """Integer-out variant (no epilogue): (M,K)i8 @ (K,N)i8 -> (M,N)i32."""
    m, k = a_q.shape
    _, n = w_q.shape
    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_int_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Multi-buffered manual DMA pipeline (depth-slot VMEM rotation over K chunks)
# ---------------------------------------------------------------------------


def _pipelined_mac_body(a_hbm, w_hbm, a_buf, w_buf, sems, *, bm: int, bn: int,
                        bk: int, k_steps: int, depth: int, mode: str):
    """Stream K chunks of one (bm, bn) output tile through a ``depth``-slot
    buffer rotation and accumulate the int32 partial sums.

    ``mode``: "fused" (real kernel), "copy" (DMA only, no MXU — the
    bandwidth leg of the profiling harness), "compute" (MXU only on resident
    buffers, no DMA — the compute leg)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    stream = mode != "compute"

    def a_dma(slot, t):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(i * bm, bm), pl.ds(t * bk, bk)],
            a_buf.at[slot], sems.at[0, slot])

    def w_dma(slot, t):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(t * bk, bk), pl.ds(j * bn, bn)],
            w_buf.at[slot], sems.at[1, slot])

    if stream:
        for t in range(min(depth, k_steps)):          # pipeline warm-up
            a_dma(t, t).start()
            w_dma(t, t).start()

    def body(t, acc):
        slot = jax.lax.rem(t, depth)
        if stream:
            a_dma(slot, t).wait()
            w_dma(slot, t).wait()
        if mode != "copy":
            src = slot if stream else 0
            acc = acc + jnp.dot(a_buf[src], w_buf[src],
                                preferred_element_type=jnp.int32)
        if stream:
            # chunk t is consumed; its slot refetches chunk t + depth
            @pl.when(t + depth < k_steps)
            def _():
                a_dma(slot, t + depth).start()
                w_dma(slot, t + depth).start()
        return acc

    acc = jax.lax.fori_loop(0, k_steps, body,
                            jnp.zeros((bm, bn), jnp.int32))
    if mode == "copy":
        # Data-depend the output on the streamed bytes so the DMA chain
        # survives DCE even though no math consumed it.
        acc = acc + (a_buf[0, 0, 0].astype(jnp.int32)
                     + w_buf[0, 0, 0].astype(jnp.int32))
    return acc


def _mac_pipelined_kernel(a_hbm, w_hbm, asc_ref, wsc_ref, o_ref, a_buf,
                          w_buf, sems, *, bm: int, bn: int, bk: int,
                          k_steps: int, depth: int, out_dtype, mode: str):
    acc = _pipelined_mac_body(a_hbm, w_hbm, a_buf, w_buf, sems, bm=bm, bn=bn,
                              bk=bk, k_steps=k_steps, depth=depth, mode=mode)
    scale = asc_ref[...].reshape(-1, 1) * wsc_ref[...].reshape(1, -1)
    o_ref[...] = (acc.astype(jnp.float32) * scale).astype(out_dtype)


def _int_pipelined_kernel(a_hbm, w_hbm, o_ref, a_buf, w_buf, sems, *,
                          bm: int, bn: int, bk: int, k_steps: int,
                          depth: int, mode: str):
    o_ref[...] = _pipelined_mac_body(a_hbm, w_hbm, a_buf, w_buf, sems,
                                     bm=bm, bn=bn, bk=bk, k_steps=k_steps,
                                     depth=depth, mode=mode)


def _pipeline_scratch(bm: int, bn: int, bk: int, depth: int):
    return [pltpu.VMEM((depth, bm, bk), jnp.int8),
            pltpu.VMEM((depth, bk, bn), jnp.int8),
            pltpu.SemaphoreType.DMA((2, depth))]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "depth",
                                             "out_dtype", "interpret",
                                             "_mode"))
def dcim_matmul_pipelined_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                                 a_scale: jnp.ndarray, w_scale: jnp.ndarray,
                                 *, bm: int = 128, bn: int = 128,
                                 bk: int = 128, depth: int = 2,
                                 out_dtype=jnp.float32,
                                 interpret: bool = False,
                                 _mode: str = "fused") -> jnp.ndarray:
    """Quantized matmul with fused dequant through the manual multi-buffered
    DMA pipeline.  Bit-identical accumulation to :func:`dcim_matmul_pallas`;
    ``depth`` VMEM slots of (bm, bk) + (bk, bn) operand chunks rotate so
    HBM->VMEM fetch overlaps the MXU."""
    m, k = a_q.shape
    _, n = w_q.shape
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m,))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))
    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    asc = _pad_to(a_scale, (bm,))
    wsc = _pad_to(w_scale, (bn,))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_mac_pipelined_kernel, bm=bm, bn=bn, bk=bk,
                          k_steps=kp // bk, depth=depth, out_dtype=out_dtype,
                          mode=_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=_pipeline_scratch(bm, bn, bk, depth),
        interpret=interpret,
    )(a_p, w_p, asc, wsc)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "depth",
                                             "interpret", "_mode"))
def dcim_matmul_int_pipelined_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                                     *, bm: int = 128, bn: int = 128,
                                     bk: int = 128, depth: int = 2,
                                     interpret: bool = False,
                                     _mode: str = "fused") -> jnp.ndarray:
    """Integer-out variant of the multi-buffered pipeline: bit-identical to
    :func:`dcim_matmul_int_pallas` (and hence the bit-serial DCIM oracle)."""
    m, k = a_q.shape
    _, n = w_q.shape
    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_int_pipelined_kernel, bm=bm, bn=bn, bk=bk,
                          k_steps=kp // bk, depth=depth, mode=_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=_pipeline_scratch(bm, bn, bk, depth),
        interpret=interpret,
    )(a_p, w_p)
    return out[:m, :n]
