"""Pallas TPU kernel for the DCIM MAC (the paper's compute hot-spot, adapted
to the MXU — see DESIGN.md §4 "Hardware adaptation").

The circuit mechanism (bit-serial WL streaming + per-column adder trees) has
no MXU analogue; the TPU-native mapping keeps the *roles*:

  weight-stationary SRAM tile   ->  W block resident in VMEM (BlockSpec)
  bitwise multiplier + CSA tree ->  MXU systolic int multiply-accumulate
  S&A (full-width accumulation) ->  int32 accumulator scratch in VMEM
  OFU / alignment epilogue      ->  fused per-channel dequant on final k step

Blocked matmul with grid (M/bm, N/bn, K/bk), k innermost (sequential on TPU)
so the int32 accumulator lives in a VMEM scratch across k steps.  Block shapes
default to MXU-aligned 128 multiples; int8 operands, int32 accumulate
(``preferred_element_type``), bf16/f32 output after the epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mac_kernel(a_ref, w_ref, asc_ref, wsc_ref, o_ref, acc_ref, *, k_steps: int,
                out_dtype):
    """One (bm, bn) output tile; accumulates over the k grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: int8 x int8 -> int32 (the adder tree + S&A, full width, one shot).
    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # OFU-equivalent: per-row activation scale x per-column weight scale.
        scale = asc_ref[...].reshape(-1, 1) * wsc_ref[...].reshape(1, -1)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(out_dtype)


def _int_kernel(a_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _out():
        o_ref[...] = acc_ref[...]


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = []
    for dim, m in zip(x.shape, mults):
        rem = (-dim) % m
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def dcim_matmul_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                       a_scale: jnp.ndarray, w_scale: jnp.ndarray,
                       *, bm: int = 128, bn: int = 128, bk: int = 128,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jnp.ndarray:
    """Quantized matmul with fused dequant: (M,K)i8 @ (K,N)i8 -> (M,N).

    ``a_scale``: per-row (M,) f32; ``w_scale``: per-column (N,) f32.
    Shapes are padded up to block multiples and the result sliced back.
    """
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2, (a_q.shape, w_q.shape)
    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m,))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (n,))

    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    asc = _pad_to(a_scale, (bm,))
    wsc = _pad_to(w_scale, (bn,))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_mac_kernel, k_steps=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bm,), lambda i, j, t: (i,)),
            pl.BlockSpec((bn,), lambda i, j, t: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p, asc, wsc)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def dcim_matmul_int_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                           *, bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """Integer-out variant (no epilogue): (M,K)i8 @ (K,N)i8 -> (M,N)i32."""
    m, k = a_q.shape
    _, n = w_q.shape
    a_p = _pad_to(a_q, (bm, bk))
    w_p = _pad_to(w_q, (bk, bn))
    mp, kp = a_p.shape
    _, np_ = w_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_int_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p)
    return out[:m, :n]
