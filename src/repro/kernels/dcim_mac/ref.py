"""Pure-jnp oracles for the DCIM MAC kernel.

Two references:

  * :func:`dcim_matmul_ref` — the mathematical contract: exact integer matmul
    with int32 accumulation plus the dequantization epilogue.
  * :func:`dcim_matmul_bitserial_ref` — the *faithful DCIM semantics*:
    activations stream bit-serially (WL drivers), weights are bit-sliced
    across columns, every bit-plane product is reduced by the adder tree,
    partial sums shift-accumulate in the S&A, and weight-bit column results
    fuse in the OFU.  Two's-complement MSBs carry negative weight.

Tests assert the MXU-shaped kernel == both oracles *bit-exactly*, i.e. the
compiled TPU kernel computes precisely what the synthesized DCIM macro would.
"""

from __future__ import annotations

import jax.numpy as jnp


def quant_range(bits: int) -> tuple[int, int]:
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def dcim_matmul_ref(a_q: jnp.ndarray, w_q: jnp.ndarray,
                    a_scale: jnp.ndarray | float = 1.0,
                    w_scale: jnp.ndarray | float = 1.0,
                    out_dtype=jnp.float32) -> jnp.ndarray:
    """Exact integer matmul + dequant: (M,K)i8 @ (K,N)i8 -> (M,N)out_dtype."""
    acc = jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32))
    scale = jnp.asarray(a_scale, jnp.float32) * jnp.asarray(w_scale, jnp.float32)
    return (acc.astype(jnp.float32) * scale).astype(out_dtype)


def dcim_matmul_int_ref(a_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """Integer-only oracle (no dequant)."""
    return jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32))


def _bit_planes(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Two's-complement bit planes: x == sum_b weight(b) * plane[b], with
    weight(b) = 2^b for b < bits-1 and -2^(bits-1) for the sign bit."""
    x_u = x.astype(jnp.int32) & ((1 << bits) - 1)   # two's complement view
    planes = jnp.stack([(x_u >> b) & 1 for b in range(bits)], axis=0)
    return planes.astype(jnp.int32)


def _bit_weights(bits: int) -> jnp.ndarray:
    w = [1 << b for b in range(bits - 1)] + [-(1 << (bits - 1))]
    return jnp.asarray(w, jnp.int32)


def dcim_matmul_bitserial_ref(a_q: jnp.ndarray, w_q: jnp.ndarray,
                              a_bits: int = 8, w_bits: int = 8) -> jnp.ndarray:
    """Faithful DCIM execution of the int matmul.

    Stage map (paper Fig. 1):
      WL bit-serial input  -> loop over activation bit planes ``ab``
      bit-sliced weights   -> loop over weight bit columns   ``wb``
      NOR multiplier       -> AND of bits == product of {0,1} planes
      adder tree           -> sum over K (the column reduction)
      S&A                  -> x2 shift-accumulate over activation bits
      OFU                  -> weighted fusion over weight bit columns
    """
    a_planes = _bit_planes(a_q, a_bits)            # (a_bits, M, K)
    w_planes = _bit_planes(w_q, w_bits)            # (w_bits, K, N)
    a_w = _bit_weights(a_bits)                     # signed bit weights
    w_w = _bit_weights(w_bits)

    # Adder tree: reduce over K for every (activation bit, weight bit) pair.
    # partial[ab, wb, M, N] = a_planes[ab] @ w_planes[wb]
    partial = jnp.einsum("amk,bkn->abmn", a_planes, w_planes,
                         preferred_element_type=jnp.int32)
    # S&A over activation bits, OFU over weight bits:
    fused = jnp.einsum("a,b,abmn->mn", a_w, w_w, partial,
                       preferred_element_type=jnp.int32)
    return fused.astype(jnp.int32)
