from .ops import dcim_matmul, dcim_matmul_int
from .kernel import (dcim_matmul_int_pallas, dcim_matmul_int_pipelined_pallas,
                     dcim_matmul_pallas, dcim_matmul_pipelined_pallas)
from . import ref

__all__ = ["dcim_matmul", "dcim_matmul_int", "dcim_matmul_pallas",
           "dcim_matmul_int_pallas", "dcim_matmul_pipelined_pallas",
           "dcim_matmul_int_pipelined_pallas", "ref"]
