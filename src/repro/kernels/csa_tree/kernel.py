"""Pallas kernel executing the paper's Fig. 4 carry-save reduction on the VPU.

This is the *bit-exact executable model* of the synthesized adder tree: the
4-2 compressor is evaluated as the "5-3 carry-save adder" of [11] using pure
bitwise ops (XOR/AND/OR + shift), level by level, with a final two-operand add
standing in for the ripple-carry stage.  The reduction schedule is generated
at trace time from the row count, exactly like the netlist builder in
``repro.core.csa`` — so the TPU kernel and the synthesized netlist share
structure.

Layout: operands (H, N) int32 arrive as (H, bn) VMEM blocks (full row dim in
VMEM — the adder tree is a column-local reduction, H <= 512 by construction);
the grid tiles N.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fa(a, b, c):
    """Full adder on integer lanes: exact carry-save split."""
    s = a ^ b ^ c
    carry = ((a & b) | (b & c) | (a & c)) << 1
    return s, carry


def _c42(a, b, c, d, cin):
    """4-2 compressor as a 5-3 carry-save adder (two chained FAs)."""
    s1, cout = _fa(a, b, c)
    s, carry = _fa(s1, d, cin)
    return s, carry, cout


def _reduce_level(lanes: list, rho_comp: bool) -> list:
    """One tree level: compress groups of 4 (compressors) or 3 (FAs)."""
    nxt = []
    i = 0
    if rho_comp:
        cout = None
        while len(lanes) - i >= 4:
            cin = cout if cout is not None else jnp.zeros_like(lanes[0])
            s, c, cout = _c42(lanes[i], lanes[i + 1], lanes[i + 2],
                              lanes[i + 3], cin)
            nxt += [s, c]
            i += 4
        if cout is not None:
            nxt.append(cout)
    while len(lanes) - i >= 3:
        s, c = _fa(lanes[i], lanes[i + 1], lanes[i + 2])
        nxt += [s, c]
        i += 3
    nxt += lanes[i:]
    return nxt


def _csa_kernel(x_ref, o_ref, *, h: int, use_compressors: bool):
    lanes = [x_ref[i, :] for i in range(h)]
    guard = 0
    while len(lanes) > 2 and guard < 64:
        guard += 1
        reduced = _reduce_level(lanes, use_compressors)
        if len(reduced) >= len(lanes):            # force progress on tiny n
            a = reduced[0] + reduced[1]
            reduced = [a] + reduced[2:]
        lanes = reduced
    total = lanes[0]
    for l in lanes[1:]:
        total = total + l                          # final RCA
    o_ref[...] = total


@functools.partial(jax.jit, static_argnames=("use_compressors", "bn",
                                             "interpret"))
def csa_tree_pallas(operands: jnp.ndarray, *, use_compressors: bool = True,
                    bn: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Carry-save column reduction: (H, N) int32 -> (N,) int32."""
    h, n = operands.shape
    rem = (-n) % bn
    x = jnp.pad(operands.astype(jnp.int32), ((0, 0), (0, rem)))
    np_ = x.shape[1]
    out = pl.pallas_call(
        functools.partial(_csa_kernel, h=h, use_compressors=use_compressors),
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((h, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:n]
