"""Pallas kernel executing the paper's Fig. 4 carry-save reduction on the VPU.

This is the *bit-exact executable model* of the synthesized adder tree: the
4-2 compressor is evaluated as the "5-3 carry-save adder" of [11] using pure
bitwise ops (XOR/AND/OR + shift), level by level, with a final two-operand add
standing in for the ripple-carry stage.  The reduction schedule is generated
at trace time from the row count, exactly like the netlist builder in
``repro.core.csa`` — so the TPU kernel and the synthesized netlist share
structure.

Layout: operands (H, N) int32 arrive as (H, bn) VMEM blocks (full row dim in
VMEM — the adder tree is a column-local reduction); the grid tiles N.  The
whole-rows kernel guards its H <= ``CSA_MAX_ROWS`` VMEM residency assumption
with an explicit ValueError; taller operand stacks go through
``csa_tree_tiled_pallas``, which tiles H into (bh, bn) blocks along a
sequential grid axis and accumulates tile sums in a VMEM scratch — int32
addition wraps mod 2^32 either way, so the tiled result is bit-identical to
the whole-rows kernel and to the ``sum(axis=0)`` oracle for any H.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Row budget of the whole-rows kernel: H int32 rows of a bn-lane block must
#: sit in VMEM at once, and the trace-time reduction schedule unrolls over
#: them — 512 is the seed kernel's stated construction limit, now enforced.
CSA_MAX_ROWS = 512


def _fa(a, b, c):
    """Full adder on integer lanes: exact carry-save split."""
    s = a ^ b ^ c
    carry = ((a & b) | (b & c) | (a & c)) << 1
    return s, carry


def _c42(a, b, c, d, cin):
    """4-2 compressor as a 5-3 carry-save adder (two chained FAs)."""
    s1, cout = _fa(a, b, c)
    s, carry = _fa(s1, d, cin)
    return s, carry, cout


def _reduce_level(lanes: list, rho_comp: bool) -> list:
    """One tree level: compress groups of 4 (compressors) or 3 (FAs)."""
    nxt = []
    i = 0
    if rho_comp:
        cout = None
        while len(lanes) - i >= 4:
            cin = cout if cout is not None else jnp.zeros_like(lanes[0])
            s, c, cout = _c42(lanes[i], lanes[i + 1], lanes[i + 2],
                              lanes[i + 3], cin)
            nxt += [s, c]
            i += 4
        if cout is not None:
            nxt.append(cout)
    while len(lanes) - i >= 3:
        s, c = _fa(lanes[i], lanes[i + 1], lanes[i + 2])
        nxt += [s, c]
        i += 3
    nxt += lanes[i:]
    return nxt


def _reduce_lanes(lanes: list, use_compressors: bool):
    """Run the full reduction schedule down to one lane (tree + final RCA)."""
    guard = 0
    while len(lanes) > 2 and guard < 64:
        guard += 1
        reduced = _reduce_level(lanes, use_compressors)
        if len(reduced) >= len(lanes):            # force progress on tiny n
            a = reduced[0] + reduced[1]
            reduced = [a] + reduced[2:]
        lanes = reduced
    total = lanes[0]
    for l in lanes[1:]:
        total = total + l                          # final RCA
    return total


def _csa_kernel(x_ref, o_ref, *, h: int, use_compressors: bool):
    o_ref[...] = _reduce_lanes([x_ref[i, :] for i in range(h)],
                               use_compressors)


@functools.partial(jax.jit, static_argnames=("use_compressors", "bn",
                                             "interpret"))
def csa_tree_pallas(operands: jnp.ndarray, *, use_compressors: bool = True,
                    bn: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Carry-save column reduction: (H, N) int32 -> (N,) int32.

    Whole-rows layout — requires H <= ``CSA_MAX_ROWS``; taller stacks must
    go through :func:`csa_tree_tiled_pallas` (``repro.kernels.csa_tree.
    csa_tree_sum`` routes there automatically)."""
    h, n = operands.shape
    if h > CSA_MAX_ROWS:
        raise ValueError(
            f"csa_tree_pallas keeps all H rows of a block in VMEM and "
            f"unrolls the reduction schedule over them; H={h} exceeds the "
            f"H<={CSA_MAX_ROWS} construction limit — use "
            f"csa_tree_tiled_pallas (csa_tree_sum routes automatically)")
    rem = (-n) % bn
    x = jnp.pad(operands.astype(jnp.int32), ((0, 0), (0, rem)))
    np_ = x.shape[1]
    out = pl.pallas_call(
        functools.partial(_csa_kernel, h=h, use_compressors=use_compressors),
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((h, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:n]


# ---------------------------------------------------------------------------
# Tiled-H variant: unbounded row count via sequential (bh, bn) tile waves
# ---------------------------------------------------------------------------


def _csa_tiled_kernel(x_ref, o_ref, acc_ref, *, bh: int, h_steps: int,
                      use_compressors: bool, mode: str):
    """One (bh, bn) tile per grid step, H innermost (sequential): reduce the
    tile's rows through the CSA schedule, accumulate tile sums in VMEM.
    int32 addition is associative mod 2^32, so the tiling is exact.

    ``mode == "copy"`` (profiling skeleton) keeps the BlockSpec streaming but
    skips the reduction tree — one row read per tile keeps the data
    dependency alive.  There is no compute-only mode: BlockSpec pipelines
    cannot disable their operand streaming, so the profiler derives the
    compute share as fused minus copy."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if mode == "copy":
        acc_ref[...] += x_ref[0, :]
    else:
        acc_ref[...] += _reduce_lanes([x_ref[i, :] for i in range(bh)],
                                      use_compressors)

    @pl.when(t == h_steps - 1)
    def _out():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("use_compressors", "bh", "bn",
                                             "interpret", "_mode"))
def csa_tree_tiled_pallas(operands: jnp.ndarray, *,
                          use_compressors: bool = True, bh: int = 128,
                          bn: int = 256, interpret: bool = False,
                          _mode: str = "fused") -> jnp.ndarray:
    """Tiled-H carry-save column reduction: (H, N) int32 -> (N,) int32 for
    ANY H.  H pads up to a bh multiple with zero rows (exact: zero lanes
    compress away), N to a bn multiple; the grid walks N tiles x H tiles
    with H sequential so the partial-sum scratch carries across tile waves."""
    h, n = operands.shape
    x = operands.astype(jnp.int32)
    x = jnp.pad(x, ((0, (-h) % bh), (0, (-n) % bn)))
    hp, np_ = x.shape
    h_steps = hp // bh
    out = pl.pallas_call(
        functools.partial(_csa_tiled_kernel, bh=bh, h_steps=h_steps,
                          use_compressors=use_compressors, mode=_mode),
        grid=(np_ // bn, h_steps),
        in_specs=[pl.BlockSpec((bh, bn), lambda j, t: (t, j))],
        out_specs=pl.BlockSpec((bn,), lambda j, t: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bn,), jnp.int32)],
        interpret=interpret,
    )(x)
    return out[:n]
