"""Entry point for the carry-save adder-tree reduction."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import csa_tree_pallas
from .ref import csa_tree_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "use_compressors",
                                             "interpret"))
def csa_tree_sum(operands: jnp.ndarray, *, use_pallas: bool | None = None,
                 use_compressors: bool = True,
                 interpret: bool = False) -> jnp.ndarray:
    """(H, N) int32 -> (N,) int32 column sums via the Fig. 4 CSA structure."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return csa_tree_pallas(operands, use_compressors=use_compressors,
                               interpret=interpret)
    return csa_tree_ref(operands)
