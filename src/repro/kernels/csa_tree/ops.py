"""Entry point for the carry-save adder-tree reduction."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..instrument import dispatch_span
from ..tiles import TileConfig, resolve_tile
from .kernel import CSA_MAX_ROWS, csa_tree_pallas, csa_tree_tiled_pallas
from .ref import csa_tree_ref


def csa_tree_sum(operands: jnp.ndarray, *, use_pallas: bool | None = None,
                 use_compressors: bool = True, interpret: bool = False,
                 tile_config: TileConfig | str | None = None) -> jnp.ndarray:
    """(H, N) int32 -> (N,) int32 column sums via the Fig. 4 CSA structure.

    H <= ``CSA_MAX_ROWS`` runs the whole-rows kernel; taller stacks route to
    the tiled-H variant automatically (bit-identical — int32 addition wraps
    mod 2^32 regardless of tiling)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        h = operands.shape[0]
        if tile_config == "auto":
            from .. import autotune
            tc, source = autotune.lookup_with_source("csa_tree",
                                                     operands.shape)
        else:
            tc = resolve_tile("csa_tree", tile_config)
            source = "default" if tile_config is None else "explicit"
        route = ("tiled" if h > CSA_MAX_ROWS or tile_config is not None
                 else "rows")
        with dispatch_span("csa_tree", operands.shape, tc, source, route):
            if route == "tiled":
                return csa_tree_tiled_pallas(
                    operands, use_compressors=use_compressors,
                    bh=tc.bh, bn=tc.bn, interpret=interpret)
            return csa_tree_pallas(operands,
                                   use_compressors=use_compressors,
                                   bn=tc.bn, interpret=interpret)
    with dispatch_span("csa_tree", operands.shape, None, "none", "xla"):
        return _ref_sum(operands)


_ref_sum = jax.jit(csa_tree_ref)
