"""Oracle for the carry-save adder-tree kernel: plain integer column sum."""

from __future__ import annotations

import jax.numpy as jnp


def csa_tree_ref(operands: jnp.ndarray) -> jnp.ndarray:
    """(H, N) int32 -> (N,) int32 exact column sums."""
    return operands.astype(jnp.int32).sum(axis=0)
