from .kernel import CSA_MAX_ROWS, csa_tree_pallas, csa_tree_tiled_pallas
from .ops import csa_tree_sum
from .ref import csa_tree_ref

__all__ = ["CSA_MAX_ROWS", "csa_tree_pallas", "csa_tree_tiled_pallas",
           "csa_tree_sum", "csa_tree_ref"]
