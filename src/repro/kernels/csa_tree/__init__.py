from .kernel import csa_tree_pallas
from .ops import csa_tree_sum
from .ref import csa_tree_ref

__all__ = ["csa_tree_pallas", "csa_tree_sum", "csa_tree_ref"]
