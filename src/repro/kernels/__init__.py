"""Pallas TPU kernels for the framework's compute hot-spots.

  dcim_mac  — the paper's MAC array, MXU-adapted (weight-stationary blocked
              int matmul, int32 accumulate, fused dequant epilogue) with a
              faithful bit-serial DCIM oracle.
  csa_tree  — bit-exact executable model of the Fig. 4 mixed-CSA adder tree
              (4-2 compressors as 5-3 carry-save adders) on the VPU, with a
              tiled-H variant for operand stacks past the VMEM row budget.
  ssm_scan  — chunked diagonal linear recurrence (SSM / linear-attention
              decode primitive) with VMEM-carried state.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec grid kernels plus a
multi-buffered manual-DMA pipeline), ops.py (dispatch with ``tile_config``
selection — explicit :class:`~repro.kernels.tiles.TileConfig`, the depth-2
default, or ``"auto"`` for the persisted autotuner winner) and ref.py (the
pure-jnp oracle); tests sweep shapes/dtypes in interpret mode against the
oracles.  ``repro.kernels.profile`` times copy-only / compute-only / fused
skeletons to classify kernels bandwidth- vs compute-bound;
``repro.kernels.autotune`` sweeps the tile lattice through the repo's DSE
Pareto machinery and persists winners in the artifact registry.

Every dispatch is observable (:mod:`repro.kernels.instrument`): a
``kernel.<name>`` span (tile config chosen, pipelined-vs-grid route,
autotune memo/registry/default source) when a trace is live, plus always-on
``kernel/<name>/*`` dispatch counters in the :mod:`repro.obs` metrics
registry.
"""

from .csa_tree import (CSA_MAX_ROWS, csa_tree_pallas, csa_tree_ref,
                       csa_tree_sum, csa_tree_tiled_pallas)
from .instrument import dispatch_span
from .dcim_mac import (dcim_matmul, dcim_matmul_int, dcim_matmul_int_pallas,
                       dcim_matmul_int_pipelined_pallas, dcim_matmul_pallas,
                       dcim_matmul_pipelined_pallas)
from .ssm_scan import (ssm_scan, ssm_scan_assoc_ref, ssm_scan_pallas,
                       ssm_scan_pipelined_pallas, ssm_scan_ref)
from .tiles import DEFAULT_TILES, TileConfig, resolve_tile, shape_class, tile_space

__all__ = [
    "CSA_MAX_ROWS", "csa_tree_pallas", "csa_tree_ref", "csa_tree_sum",
    "csa_tree_tiled_pallas",
    "dcim_matmul", "dcim_matmul_int", "dcim_matmul_int_pallas",
    "dcim_matmul_int_pipelined_pallas", "dcim_matmul_pallas",
    "dcim_matmul_pipelined_pallas",
    "ssm_scan", "ssm_scan_assoc_ref", "ssm_scan_pallas",
    "ssm_scan_pipelined_pallas", "ssm_scan_ref",
    "DEFAULT_TILES", "TileConfig", "resolve_tile", "shape_class",
    "tile_space", "dispatch_span",
]
