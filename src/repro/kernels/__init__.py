"""Pallas TPU kernels for the framework's compute hot-spots.

  dcim_mac  — the paper's MAC array, MXU-adapted (weight-stationary blocked
              int matmul, int32 accumulate, fused dequant epilogue) with a
              faithful bit-serial DCIM oracle.
  csa_tree  — bit-exact executable model of the Fig. 4 mixed-CSA adder tree
              (4-2 compressors as 5-3 carry-save adders) on the VPU.
  ssm_scan  — chunked diagonal linear recurrence (SSM / linear-attention
              decode primitive) with VMEM-carried state.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes in
interpret mode against the oracles.
"""

from .csa_tree import csa_tree_pallas, csa_tree_ref, csa_tree_sum
from .dcim_mac import (dcim_matmul, dcim_matmul_int, dcim_matmul_int_pallas,
                       dcim_matmul_pallas)
from .ssm_scan import ssm_scan, ssm_scan_assoc_ref, ssm_scan_pallas, ssm_scan_ref

__all__ = [
    "csa_tree_pallas", "csa_tree_ref", "csa_tree_sum",
    "dcim_matmul", "dcim_matmul_int", "dcim_matmul_int_pallas",
    "dcim_matmul_pallas",
    "ssm_scan", "ssm_scan_assoc_ref", "ssm_scan_pallas", "ssm_scan_ref",
]
