"""Tile/pipeline configuration shared by the three DCIM-path kernels.

A :class:`TileConfig` names every tunable of one kernel launch — the block
shape the grid is cut into and the DMA pipeline ``depth`` (how many VMEM
buffer slots the manual ``make_async_copy`` pipeline rotates through).  The
same object is the currency of the tile autotuner
(:mod:`repro.kernels.autotune`): candidate configs are enumerated from the
per-kernel :func:`tile_space`, timed, and the winner persisted under a
``(kernel, shape-class, backend)`` content address.

Field semantics per kernel (unused fields stay None):

  dcim_mac   bm x bn output tile, bk K-chunk, depth-slot operand streaming
  ssm_scan   bt T-chunk, bd D-tile (lanes), depth-slot (a, b) streaming
  csa_tree   bh row tile (the tiled-H variant), bn lane tile

``depth >= 2`` selects the manual multi-buffered DMA pipeline; ``depth == 1``
selects the classic BlockSpec grid kernel (compiler-managed double
buffering) — both compute identical bits, so the choice is purely a
performance decision and the autotuner sweeps it like any other axis.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

#: VMEM working-set budget one kernel launch may plan for (bytes).  Real
#: cores have ~16 MB; leave headroom for the compiler's own temporaries.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

#: Lane width of the VPU/MXU — the last block dim should stay a multiple.
LANE = 128

#: Sublane granularity floor (f32); int8 wants 32 but small interpret-mode
#: shapes legitimately tune below it, so feasibility clamps, never rounds up.
SUBLANE = 8


@dataclass(frozen=True)
class TileConfig:
    """One kernel launch posture.  Hashable, so it can ride as a jit static
    argument; ``None`` fields mean "not meaningful for this kernel"."""

    bm: int | None = None
    bn: int | None = None
    bk: int | None = None
    bt: int | None = None
    bd: int | None = None
    bh: int | None = None
    depth: int = 2

    def as_dict(self) -> dict[str, int]:
        """Only the set fields, for artifact payloads and bench rows."""
        out = {k: v for k, v in dataclasses.asdict(self).items()
               if v is not None and k != "depth"}
        out["depth"] = self.depth
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "TileConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in fields})


#: Per-kernel default launch posture (the seed kernels' historical blocks).
DEFAULT_TILES: dict[str, TileConfig] = {
    "dcim_mac": TileConfig(bm=128, bn=128, bk=128, depth=2),
    "ssm_scan": TileConfig(bt=128, bd=128, depth=2),
    "csa_tree": TileConfig(bh=128, bn=256, depth=1),
}

KERNELS = tuple(DEFAULT_TILES)


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def shape_class(kernel: str, shape: tuple[int, ...]) -> str:
    """Bucket a concrete shape so one tuning generalizes: every dim rounds
    up to the next power of two (decode M=1..128 share a class, long-context
    T=400k..524k share a class)."""
    def pow2(x: int) -> int:
        p = 1
        while p < x:
            p *= 2
        return p
    return f"{kernel}:" + "x".join(str(pow2(max(1, int(d)))) for d in shape)


def _fits_vmem(nbytes: int) -> bool:
    return nbytes <= VMEM_BUDGET_BYTES


def _clamp(cands: tuple[int, ...], dim: int, align: int) -> list[int]:
    """Feasible tile sizes for one dimension: a tile larger than the
    dimension's aligned extent only streams padding, so it is pruned (this
    is what makes the tuner's non-default picks deterministic on shapes
    smaller than the default block)."""
    ceil = max(align, round_up(dim, align))
    keep = sorted({min(c, ceil) for c in cands})
    return [c for c in keep if c <= ceil]


def tile_space(kernel: str, shape: tuple[int, ...]) -> list[TileConfig]:
    """The candidate (block-shape, buffer-depth) lattice for one kernel on
    one concrete shape — feasibility-pruned (no tile past the padded extent,
    no working set past the VMEM budget), default-first when the default
    survives pruning."""
    depths = (1, 2, 4)
    out: list[TileConfig] = []
    if kernel == "dcim_mac":
        m, k, n = shape
        for bm in _clamp((32, 64, 128, 256), m, SUBLANE):
            for bn in _clamp((128, 256), n, LANE):
                for bk in _clamp((128, 256, 512), k, LANE):
                    for depth in depths:
                        work = depth * (bm * bk + bk * bn) + 4 * bm * bn
                        if _fits_vmem(work):
                            out.append(TileConfig(bm=bm, bn=bn, bk=bk,
                                                  depth=depth))
    elif kernel == "ssm_scan":
        t, d = shape
        for bt in _clamp((32, 64, 128, 256), t, SUBLANE):
            for bd in _clamp((128, 256), d, LANE):
                for depth in depths:
                    work = 4 * (3 * depth * bt * bd + bd)
                    if _fits_vmem(work):
                        out.append(TileConfig(bt=bt, bd=bd, depth=depth))
    elif kernel == "csa_tree":
        h, n = shape
        for bh in _clamp((32, 64, 128, 256), h, SUBLANE):
            for bn in _clamp((128, 256, 512), n, LANE):
                if _fits_vmem(4 * (bh * bn + bn)):
                    out.append(TileConfig(bh=bh, bn=bn, depth=1))
    else:
        raise ValueError(f"unknown kernel {kernel!r}; have {KERNELS}")
    default = DEFAULT_TILES[kernel]
    if default in out:
        out.remove(default)
        out.insert(0, default)
    return out


def resolve_tile(kernel: str, tile_config: "TileConfig | None") -> TileConfig:
    """Fill unset fields of an explicit config from the kernel default."""
    default = DEFAULT_TILES[kernel]
    if tile_config is None:
        return default
    merged = {k: (v if v is not None else getattr(default, k))
              for k, v in dataclasses.asdict(tile_config).items()}
    return TileConfig(**merged)
