"""Kernel-dispatch observability: one span + counters per kernel launch.

Every public kernel entry point (``dcim_matmul``/``dcim_matmul_int``,
``ssm_scan``, ``csa_tree_sum``) routes its launch through
:func:`dispatch_span`, which records

  * a ``kernel.<name>`` span (child of whatever request/engine span is
    current) tagged with the shape, the tile config chosen, the route taken
    (``pipelined`` vs ``grid`` vs ``tiled``/``rows`` vs ``xla``), and where
    the tile came from (autotune ``memo``/``registry``/``default``, an
    ``explicit`` config, or the ``default`` posture);
  * always-on dispatch counters in the global metrics registry
    (``kernel/<name>/dispatch``, ``.../route/<route>``,
    ``.../tile_source/<source>``) — the cheap signal that answers "is the
    fleet actually running tuned pipelines?" without tracing enabled.
"""

from __future__ import annotations

import contextlib

from ..obs import tracer
from ..obs.metrics import get_registry


@contextlib.contextmanager
def dispatch_span(kernel: str, shape: tuple[int, ...], tile, source: str,
                  route: str):
    """Wrap one kernel launch: dispatch counters plus (when a trace is
    live) a ``kernel.<name>`` span.  ``tile`` is the resolved TileConfig
    (or None on the XLA path); ``source`` is the tile attribution."""
    reg = get_registry()
    reg.counter(f"kernel/{kernel}/dispatch").inc()
    reg.counter(f"kernel/{kernel}/route/{route}").inc()
    reg.counter(f"kernel/{kernel}/tile_source/{source}").inc()
    span = tracer.span(f"kernel.{kernel}", tags={
        "shape": "x".join(str(int(d)) for d in shape),
        "route": route, "tile_source": source})
    if span and tile is not None:
        span.set_tag("tile", tile.as_dict())
    with span:
        yield span
