from .kernel import ssm_scan_pallas, ssm_scan_pipelined_pallas
from .ops import ssm_scan
from .ref import ssm_scan_assoc_ref, ssm_scan_ref

__all__ = ["ssm_scan_pallas", "ssm_scan_pipelined_pallas", "ssm_scan",
           "ssm_scan_assoc_ref", "ssm_scan_ref"]
