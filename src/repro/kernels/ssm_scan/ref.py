"""Oracles for the chunked linear-recurrence (SSM/linear-attention) scan.

Recurrence (diagonal):  h_t = a_t * h_{t-1} + b_t,   h_{-1} = h0
Returns every state h_0..h_{T-1} plus the final carry.

Two oracles: a sequential ``lax.scan`` (ground truth) and an
``associative_scan`` formulation (validates the parallel decomposition the
chunked Pallas kernel relies on).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (T, D); h0: (D,) -> (states (T, D), final (D,))."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    final, states = jax.lax.scan(step, h0, (a, b))
    return states, final


def ssm_scan_assoc_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract via associative composition (A, B) o (A', B') =
    (A'A, A'B + B')."""
    a_all = jnp.concatenate([jnp.ones_like(h0)[None], a], axis=0)
    b_all = jnp.concatenate([h0[None], b], axis=0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    aa, bb = jax.lax.associative_scan(combine, (a_all, b_all), axis=0)
    states = bb[1:]
    return states, states[-1]
