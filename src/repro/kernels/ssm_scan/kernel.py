"""Pallas TPU kernel: chunked diagonal linear recurrence.

h_t = a_t * h_{t-1} + b_t over T steps of width-D state, the primitive under
the SSM (Mamba2 / RWKV6) layers for long-context decode — the ``long_500k``
shape class runs on this.

TPU adaptation: the recurrence is sequential in T, so the kernel tiles T into
chunks along the (sequential) grid axis and carries the running state in a
VMEM scratch between grid steps — a weight-stationary-style pipeline where
HBM->VMEM streaming of (a, b) chunks overlaps the VPU scan of the previous
chunk.  Within a chunk the scan runs as an unrolled log-depth associative
doubling (Blelloch up-sweep) over VREGs rather than a length-bt serial loop:
bt=128 costs 7 vector passes instead of 128.

D tiles along the second grid axis (lanes, 128-aligned); T chunks along the
last (sequential) axis.

``ssm_scan_pipelined_pallas`` is the multi-buffered variant: (a, b) stay in
HBM and the kernel streams (bt, bd) chunks itself through
``pltpu.make_async_copy`` into a ``depth``-slot VMEM rotation, with states
written back through a matching ``depth``-slot output staging rotation — so
chunk t+1..t+depth-1 fetch and chunk t-1 write-back both overlap the VPU
scan of chunk t.  Same arithmetic per chunk, so the tolerance contract vs
``ssm_scan_ref`` is unchanged; ``_mode`` exposes copy-only / compute-only
skeletons to the profiling harness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_states(a, b, h_in, *, bt: int):
    """States of one (bt, bd) chunk given the incoming carry: log-depth
    associative doubling — (A, B)_t composes prefix products,
    shift-and-combine doubles span — then states_t = A_t * h_in + B_t."""
    A, B = a, b
    span = 1
    while span < bt:
        A_shift = jnp.concatenate(
            [jnp.ones((span, A.shape[1]), A.dtype), A[:-span]], axis=0)
        B_shift = jnp.concatenate(
            [jnp.zeros((span, B.shape[1]), B.dtype), B[:-span]], axis=0)
        B = A * B_shift + B
        A = A * A_shift
        span *= 2
    return A * h_in[None, :] + B


def _scan_chunk_kernel(a_ref, b_ref, h0_ref, o_ref, hfin_ref, carry_ref,
                       *, bt: int, t_steps: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    # (bt, bd) chunk; prefix-inclusive states from the carried state.
    states = _chunk_states(a_ref[...], b_ref[...], carry_ref[...], bt=bt)
    o_ref[...] = states
    carry_ref[...] = states[-1, :]

    @pl.when(t == t_steps - 1)
    def _final():
        hfin_ref[...] = carry_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def ssm_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                    *, bt: int = 128, bd: int = 128,
                    interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (T, D) f32; h0: (D,) f32 -> (states (T, D), final (D,))."""
    t_len, d = a.shape
    pt, pd = (-t_len) % bt, (-d) % bd
    # Pad T with identity steps (a=1 keeps the carry; harmless since padded
    # rows are sliced off) — wait: a=1,b=0 *propagates* the carry, and padded
    # states are discarded, so the final state must come from the last REAL
    # row; we pad with a=1, b=0 and read the carry after the last real row by
    # slicing states.
    a_p = jnp.pad(a, ((0, pt), (0, pd)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, pt), (0, pd)))
    h0_p = jnp.pad(h0, (0, pd))
    tp, dp = a_p.shape
    grid = (dp // bd, tp // bt)  # T innermost: sequential carry axis

    states, hfin = pl.pallas_call(
        functools.partial(_scan_chunk_kernel, bt=bt, t_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bd,), lambda j, t: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bd,), lambda j, t: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, dp), a.dtype),
            jax.ShapeDtypeStruct((dp,), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bd,), a.dtype)],
        interpret=interpret,
    )(a_p, b_p, h0_p)
    out_states = states[:t_len, :d]
    final = out_states[-1, :] if pt else hfin[:d]
    return out_states, final


# ---------------------------------------------------------------------------
# Multi-buffered manual DMA pipeline (depth-slot rotation over T chunks)
# ---------------------------------------------------------------------------


def _scan_pipelined_kernel(a_hbm, b_hbm, h0_ref, o_hbm, hfin_ref, a_buf,
                           b_buf, o_buf, in_sems, out_sems, *, bt: int,
                           t_steps: int, depth: int, mode: str):
    """One D tile's full T sweep: stream (bt, bd) chunks of a and b through
    ``depth`` input slots, scan each chunk, and stream states back out
    through ``depth`` staging slots.  The carry rides the fori_loop."""
    j = pl.program_id(0)
    stream = mode != "compute"

    def in_dma(hbm, buf, row, slot, t):
        return pltpu.make_async_copy(
            hbm.at[pl.ds(t * bt, bt), pl.ds(j * a_buf.shape[2], a_buf.shape[2])],
            buf.at[slot], in_sems.at[row, slot])

    def out_dma(slot, t):
        return pltpu.make_async_copy(
            o_buf.at[slot],
            o_hbm.at[pl.ds(t * bt, bt),
                     pl.ds(j * o_buf.shape[2], o_buf.shape[2])],
            out_sems.at[slot])

    if stream:
        for t in range(min(depth, t_steps)):          # pipeline warm-up
            in_dma(a_hbm, a_buf, 0, t, t).start()
            in_dma(b_hbm, b_buf, 1, t, t).start()

    def body(t, h):
        slot = jax.lax.rem(t, depth)
        if stream:
            in_dma(a_hbm, a_buf, 0, slot, t).wait()
            in_dma(b_hbm, b_buf, 1, slot, t).wait()

            # The write-back that borrowed this staging slot ``depth`` chunks
            # ago must drain before the slot is overwritten.
            @pl.when(t >= depth)
            def _():
                out_dma(slot, t - depth).wait()
        if mode == "copy":
            o_buf[slot] = a_buf[slot] + b_buf[slot]
        else:
            src = slot if stream else 0
            states = _chunk_states(a_buf[src], b_buf[src], h, bt=bt)
            h = states[-1, :]
            if stream:
                o_buf[slot] = states
        if stream:
            out_dma(slot, t).start()

            @pl.when(t + depth < t_steps)
            def _():
                in_dma(a_hbm, a_buf, 0, slot, t + depth).start()
                in_dma(b_hbm, b_buf, 1, slot, t + depth).start()
        return h

    h = jax.lax.fori_loop(0, t_steps, body, h0_ref[...])
    if stream:
        for t in range(max(0, t_steps - depth), t_steps):   # drain stores
            out_dma(t % depth, t).wait()
    hfin_ref[...] = h


@functools.partial(jax.jit, static_argnames=("bt", "bd", "depth",
                                             "interpret", "_mode"))
def ssm_scan_pipelined_pallas(a: jnp.ndarray, b: jnp.ndarray,
                              h0: jnp.ndarray, *, bt: int = 128,
                              bd: int = 128, depth: int = 2,
                              interpret: bool = False, _mode: str = "fused"
                              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-buffered variant of :func:`ssm_scan_pallas` — same contract,
    same per-chunk arithmetic, explicit HBM<->VMEM streaming with a tunable
    buffer depth."""
    t_len, d = a.shape
    pt, pd = (-t_len) % bt, (-d) % bd
    a_p = jnp.pad(a, ((0, pt), (0, pd)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, pt), (0, pd)))
    h0_p = jnp.pad(h0, (0, pd))
    tp, dp = a_p.shape
    t_steps = tp // bt

    states, hfin = pl.pallas_call(
        functools.partial(_scan_pipelined_kernel, bt=bt, t_steps=t_steps,
                          depth=depth, mode=_mode),
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bd,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((bd,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, dp), a.dtype),
            jax.ShapeDtypeStruct((dp,), a.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((depth, bt, bd), a.dtype),
            pltpu.VMEM((depth, bt, bd), a.dtype),
            pltpu.VMEM((depth, bt, bd), a.dtype),
            pltpu.SemaphoreType.DMA((2, depth)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
        interpret=interpret,
    )(a_p, b_p, h0_p)
    out_states = states[:t_len, :d]
    final = out_states[-1, :] if pt else hfin[:d]
    return out_states, final
