"""Pallas TPU kernel: chunked diagonal linear recurrence.

h_t = a_t * h_{t-1} + b_t over T steps of width-D state, the primitive under
the SSM (Mamba2 / RWKV6) layers for long-context decode — the ``long_500k``
shape class runs on this.

TPU adaptation: the recurrence is sequential in T, so the kernel tiles T into
chunks along the (sequential) grid axis and carries the running state in a
VMEM scratch between grid steps — a weight-stationary-style pipeline where
HBM->VMEM streaming of (a, b) chunks overlaps the VPU scan of the previous
chunk.  Within a chunk the scan runs as an unrolled log-depth associative
doubling (Blelloch up-sweep) over VREGs rather than a length-bt serial loop:
bt=128 costs 7 vector passes instead of 128.

D tiles along the second grid axis (lanes, 128-aligned); T chunks along the
last (sequential) axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_chunk_kernel(a_ref, b_ref, h0_ref, o_ref, hfin_ref, carry_ref,
                       *, bt: int, t_steps: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    a = a_ref[...]                      # (bt, bd)
    b = b_ref[...]

    # Log-depth associative doubling within the chunk:
    #   (A, B)_t composes prefix products; shift-and-combine doubles span.
    A, B = a, b
    span = 1
    while span < bt:
        A_shift = jnp.concatenate(
            [jnp.ones((span, A.shape[1]), A.dtype), A[:-span]], axis=0)
        B_shift = jnp.concatenate(
            [jnp.zeros((span, B.shape[1]), B.dtype), B[:-span]], axis=0)
        B = A * B_shift + B
        A = A * A_shift
        span *= 2
    # states_t = A_t * h_in + B_t  (prefix-inclusive)
    h_in = carry_ref[...]
    states = A * h_in[None, :] + B
    o_ref[...] = states
    carry_ref[...] = states[-1, :]

    @pl.when(t == t_steps - 1)
    def _final():
        hfin_ref[...] = carry_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def ssm_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                    *, bt: int = 128, bd: int = 128,
                    interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (T, D) f32; h0: (D,) f32 -> (states (T, D), final (D,))."""
    t_len, d = a.shape
    pt, pd = (-t_len) % bt, (-d) % bd
    # Pad T with identity steps (a=1 keeps the carry; harmless since padded
    # rows are sliced off) — wait: a=1,b=0 *propagates* the carry, and padded
    # states are discarded, so the final state must come from the last REAL
    # row; we pad with a=1, b=0 and read the carry after the last real row by
    # slicing states.
    a_p = jnp.pad(a, ((0, pt), (0, pd)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, pt), (0, pd)))
    h0_p = jnp.pad(h0, (0, pd))
    tp, dp = a_p.shape
    grid = (dp // bd, tp // bt)  # T innermost: sequential carry axis

    states, hfin = pl.pallas_call(
        functools.partial(_scan_chunk_kernel, bt=bt, t_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bd,), lambda j, t: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, bd), lambda j, t: (t, j)),
            pl.BlockSpec((bd,), lambda j, t: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, dp), a.dtype),
            jax.ShapeDtypeStruct((dp,), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bd,), a.dtype)],
        interpret=interpret,
    )(a_p, b_p, h0_p)
    out_states = states[:t_len, :d]
    final = out_states[-1, :] if pt else hfin[:d]
    return out_states, final
