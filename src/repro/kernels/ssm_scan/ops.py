"""Entry point for the SSM linear-recurrence scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..instrument import dispatch_span
from ..tiles import TileConfig, resolve_tile
from .kernel import ssm_scan_pallas, ssm_scan_pipelined_pallas
from .ref import ssm_scan_assoc_ref


def ssm_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
             *, use_pallas: bool | None = None, interpret: bool = False,
             tile_config: TileConfig | str | None = None
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (T, D); h0: (D,).  Returns (states (T, D), final (D,)).
    ``tile_config`` as in :func:`repro.kernels.dcim_mac.dcim_matmul`:
    None = default depth-2 pipeline, ``depth == 1`` = the BlockSpec grid
    kernel, "auto" = the autotuner's winner for this shape class.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        if tile_config == "auto":
            from .. import autotune
            tc, source = autotune.lookup_with_source("ssm_scan", a.shape)
        else:
            tc = resolve_tile("ssm_scan", tile_config)
            source = "default" if tile_config is None else "explicit"
        route = "pipelined" if tc.depth >= 2 else "grid"
        with dispatch_span("ssm_scan", a.shape, tc, source, route):
            if tc.depth >= 2:
                return ssm_scan_pipelined_pallas(a, b, h0, bt=tc.bt,
                                                 bd=tc.bd, depth=tc.depth,
                                                 interpret=interpret)
            return ssm_scan_pallas(a, b, h0, bt=tc.bt, bd=tc.bd,
                                   interpret=interpret)
    with dispatch_span("ssm_scan", a.shape, None, "none", "xla"):
        return _ref_scan(a, b, h0)


_ref_scan = jax.jit(ssm_scan_assoc_ref)
