"""Entry point for the SSM linear-recurrence scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_pallas
from .ref import ssm_scan_assoc_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ssm_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
             *, use_pallas: bool | None = None, interpret: bool = False
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (T, D); h0: (D,).  Returns (states (T, D), final (D,)).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ssm_scan_pallas(a, b, h0, interpret=interpret)
    return ssm_scan_assoc_ref(a, b, h0)
