"""Architecture + workload-shape configuration.

Every assigned architecture is a selectable config (``--arch <id>``); each
pairs with the four workload shape classes.  ``smoke()`` returns the reduced
same-family config used by CPU smoke tests; full configs are exercised only
through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    state: int = 64           # N (ssm state per head-channel)
    head_dim: int = 64        # P
    conv_kernel: int = 4
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 256          # chunked-scan block length


@dataclass(frozen=True)
class FrontendCfg:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""

    kind: str                 # 'vision' | 'audio'
    n_tokens: int             # patches / frames after the (stubbed) frontend
    d_frontend: int           # embedding dim delivered by the stub


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # defaults to d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    attn_every: int | None = None        # hybrid: shared attn after every N ssm blocks
    encoder_layers: int = 0              # enc-dec (whisper): encoder depth
    frontend: FrontendCfg | None = None
    # DCIM quantization of linear layers (the paper's technique in the model):
    dcim_a_bits: int = 8
    dcim_w_bits: int = 8
    dcim_enabled: bool = True
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # perf knobs (hillclimbed per arch x shape; see EXPERIMENTS.md §Perf)
    act_shard: bool = False      # activation sharding constraints (§Perf it.1)
    remat: bool = True
    attn_q_block: int = 512              # blockwise-attention query tile
    attn_kv_block: int = 1024
    sharding_overrides: dict[str, Any] = field(default_factory=dict)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded for even sharding (multiple of 128
        when the exact vocab doesn't divide the 16-way model axis).  Logits
        are sliced back to the exact vocab before loss/argmax."""
        if self.vocab % 16 == 0:
            return self.vocab
        return ((self.vocab + 127) // 128) * 128

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- parameters
    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * hd * n_q + 2 * d * hd * n_kv + hd * n_q * d
        dense_mlp = 3 * d * ff
        if self.family == "moe":
            mlp = self.moe.n_experts * 3 * d * self.moe.d_expert \
                + d * self.moe.n_experts
        else:
            mlp = dense_mlp
        if self.family == "ssm":        # rwkv6: time-mix + channel-mix
            tmix = 4 * d * d + d * d // 2
            cmix = 2 * d * int(self.d_ff)
            block = tmix + cmix
        elif self.family == "hybrid":   # mamba2 blocks + one shared attn
            di = self.d_inner
            mamba = d * (2 * di + 2 * self.ssm.state + di // self.ssm.head_dim) \
                + di * d
            block = mamba + dense_mlp // self.n_layers  # amortized shared blk
        else:
            block = attn + dense_mlp if self.family != "moe" else attn + mlp
        total = v * d * (1 if self.tie_embeddings else 2) \
            + self.n_layers * block + self.encoder_layers * (attn + dense_mlp)
        return int(total)

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count()
        all_experts = self.moe.n_experts * 3 * d * self.moe.d_expert
        active = self.moe.top_k * 3 * d * self.moe.d_expert
        return int(dense - self.n_layers * (all_experts - active))


# ---------------------------------------------------------------------------
# Workload shapes (assigned): seq_len x global_batch per class
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}

# Families with sub-quadratic long-context decode (O(1) or O(window) state).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        out.append("long_500k")
    return out
