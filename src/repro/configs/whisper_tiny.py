"""whisper-tiny [audio]: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (1500 frames x d_frontend = 2x-downsampled
mel-conv output) consumed by the transformer encoder; the decoder follows the
workload shape class.
"""

from .base import ArchConfig, FrontendCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51_865, head_dim=64,
        encoder_layers=4,
        frontend=FrontendCfg(kind="audio", n_tokens=1500, d_frontend=384),
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="whisper-tiny-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, encoder_layers=2,
        frontend=FrontendCfg(kind="audio", n_tokens=32, d_frontend=64),
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
