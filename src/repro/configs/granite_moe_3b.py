"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from .base import ArchConfig, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49_155, head_dim=64,
        rope_theta=10_000.0, tie_embeddings=True,
        moe=MoECfg(n_experts=40, top_k=8, d_expert=512),
        # 40 experts % 16-way model axis != 0: run experts replicated with
        # per-expert TP (d_model over 'data', d_expert over 'model') instead
        # of expert-parallel dispatch (granite-1b keeps true EP with 32e).
        sharding_overrides={"experts": None, "expert_ff": "model"},
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="granite-moe-3b-a800m-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab=256, head_dim=16,
        moe=MoECfg(n_experts=4, top_k=2, d_expert=32),
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
