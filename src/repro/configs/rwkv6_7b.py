"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892; hf]"""

from .base import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14_336, vocab=65_536, head_dim=64,
        ssm=SSMCfg(state=64, head_dim=64, chunk=256),
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="rwkv6-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        ssm=SSMCfg(state=16, head_dim=16, chunk=32),
        param_dtype="float32", compute_dtype="float32",
    )
