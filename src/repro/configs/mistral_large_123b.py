"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b", family="dense",
        n_layers=88, d_model=12_288, n_heads=96, n_kv_heads=8,
        d_ff=28_672, vocab=32_768, head_dim=128,
        rope_theta=1_000_000.0,
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="mistral-large-123b-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=192, vocab=256, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
