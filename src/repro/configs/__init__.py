"""Assigned architecture configs (exact, from the task sheet) + smoke variants.

``get_config(name)`` / ``list_archs()`` are the CLI entry points
(``--arch <id>``); ``smoke_config(name)`` returns the reduced same-family
config for CPU smoke tests.
"""

from __future__ import annotations

from .base import (ArchConfig, FrontendCfg, MoECfg, SSMCfg, SHAPES, ShapeCfg,
                   SUBQUADRATIC_FAMILIES, applicable_shapes)
from . import (granite_moe_1b, granite_moe_3b, internvl2_1b, llama32_3b,
               mistral_large_123b, phi3_mini_38b, qwen3_4b, rwkv6_7b,
               whisper_tiny, zamba2_12b)

_MODULES = {
    "llama3.2-3b": llama32_3b,
    "qwen3-4b": qwen3_4b,
    "mistral-large-123b": mistral_large_123b,
    "phi3-mini-3.8b": phi3_mini_38b,
    "internvl2-1b": internvl2_1b,
    "zamba2-1.2b": zamba2_12b,
    "rwkv6-7b": rwkv6_7b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "whisper-tiny": whisper_tiny,
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list_archs()}")
    return _MODULES[name].config()


def smoke_config(name: str) -> ArchConfig:
    return _MODULES[name].smoke()


__all__ = ["ArchConfig", "FrontendCfg", "MoECfg", "SSMCfg", "SHAPES",
           "ShapeCfg", "SUBQUADRATIC_FAMILIES", "applicable_shapes",
           "get_config", "smoke_config", "list_archs"]
