"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 —
InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The ViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (256 tokens of d_frontend) which a learned
projector maps into the LM embedding space.
"""

from .base import ArchConfig, FrontendCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151_655, head_dim=64,
        rope_theta=1_000_000.0,
        frontend=FrontendCfg(kind="vision", n_tokens=256, d_frontend=1024),
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="internvl2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        frontend=FrontendCfg(kind="vision", n_tokens=8, d_frontend=32),
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
