"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38 Mamba2 blocks with one *shared* attention+MLP block invoked after every
6th Mamba block (weights shared across invocations, Zamba-style).
"""

from .base import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32_000, head_dim=64,
        ssm=SSMCfg(state=64, head_dim=64, conv_kernel=4, expand=2, chunk=256),
        attn_every=6,
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="zamba2-1.2b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        ssm=SSMCfg(state=8, head_dim=16, conv_kernel=4, expand=2, chunk=32),
        attn_every=2,
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
