"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]"""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32_064, head_dim=96,
        rope_theta=10_000.0,
    )


def smoke() -> ArchConfig:
    return config().replace(
        name="phi3-mini-3.8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        attn_q_block=32, attn_kv_block=64,
    )
