"""The async/streaming synthesis front: admission queue, priority classes,
batching window, backpressure, and streamed partial results.

:class:`SynthesisService` (one layer down) is a blocking batch call — the
caller must already hold a batch to get fusion.  This module is the piece
that *builds* those batches from an online request stream, the
continuous-batching serving idiom applied to synthesis requests:

  admission   ``submit`` pushes a typed request onto a **bounded** priority
              queue (INTERACTIVE ahead of BULK, FIFO within a class).  A
              full queue sheds the request immediately with a typed
              :class:`~repro.service.requests.SheddedResponse` (reason
              ``queue_full``) — backpressure is explicit, never a timeout
              and never a silent drop;
  window      the scheduler collects requests for a batching window (until
              ``max_batch`` requests are waiting or ``window`` seconds
              elapse since the first one), then drains the queue in
              priority order;
  fused pass  the drained batch goes through ``service.serve`` — the
              cache / coalesce / ONE-``engine.execute`` tiers of PR 5 — so
              concurrency raises fusion instead of contention.  The window
              *adapts*: the engine's latency hooks
              (:func:`repro.core.engine.add_latency_hook`) feed observed
              per-pass latency back, and the window tracks a fraction of it
              (clamped), so a slow engine grows batches instead of queues;
  stream      every lifecycle transition (queued → batched → served /
              shedded) and every finished spec lane fires
              :class:`~repro.service.requests.StreamEvent` callbacks — a
              long lattice sweep streams its frontier-so-far
              (:meth:`ServiceFrontend.submit_sweep`) while later lanes are
              still computing.

Results are bit-identical to the blocking path in every tier: the frontend
adds scheduling, not arithmetic — a drained batch is served by exactly the
``synthesize_many`` machinery the differential harness pins.

    from repro.service import ServiceFrontend, SynthesisRequest
    with ServiceFrontend() as front:
        t = front.submit(SynthesisRequest(spec=spec))
        resp = t.result(timeout=60)          # SynthesisResponse | Shedded
"""

from __future__ import annotations

import heapq
import threading
import time
from typing import Callable, Optional, Sequence

from ..core import engine as E
from ..core.macro import MacroSpec
from ..obs import tracer
from ..obs.metrics import StatsView, get_registry
from .requests import (FRONTIER_EVENT, Priority, RequestState,
                       SheddedResponse, StreamEvent, SynthesisRequest,
                       SynthesisResponse)
from .service import SynthesisService

#: Bounds the adaptive batching window (seconds): the window never shrinks
#: below the floor (pure dispatch jitter) nor grows past the ceiling (an
#: interactive request never waits longer than this for co-batching).
WINDOW_BOUNDS = (0.001, 0.25)

#: The adaptive window targets this fraction of the observed fused-pass
#: latency — batching overhead stays a bounded tax on what the engine
#: already costs.
WINDOW_FRACTION = 0.1


class FrontendStats(StatsView):
    """Admission-queue counters, backed by a metrics registry
    (:class:`repro.obs.metrics.StatsView` — same attributes and
    ``as_dict()`` key set as the historical dataclass).

    - ``submitted``: admitted to the queue
    - ``shedded``: typed rejections (all reasons)
    - ``batches``: scheduler drains that reached the service
    - ``max_batch``: largest drained batch
    - ``depth_hwm``: admission-queue depth high-water mark
    """

    _NAMESPACE = "frontend"
    _FIELDS = ("submitted", "served", "shedded", "batches", "max_batch",
               "depth_hwm")


class Ticket:
    """The caller's handle on one submitted request: blocks on
    :meth:`result` until the terminal response (served or shedded) exists.
    """

    def __init__(self, request: SynthesisRequest):
        self.request = request
        self._done = threading.Event()
        self._response: SynthesisResponse | SheddedResponse | None = None
        self.state = RequestState.QUEUED

    def _resolve(self, response) -> None:
        self._response = response
        self.state = response.state
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None
               ) -> SynthesisResponse | SheddedResponse:
        """The terminal response.  Raises ``TimeoutError`` if it does not
        arrive in ``timeout`` seconds (the request stays in flight)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s "
                               f"(state={self.state.value})")
        return self._response


class _Entry:
    """One queued request plus its scheduling state."""

    __slots__ = ("request", "ticket", "on_event", "submitted_at",
                 "deadline_at", "batched_at", "span")

    def __init__(self, request, ticket, on_event, submitted_at, deadline_at,
                 span=None):
        self.request = request
        self.ticket = ticket
        self.on_event = on_event
        self.submitted_at = submitted_at
        self.deadline_at = deadline_at
        self.batched_at = None
        self.span = span        # the request's trace root (SpanHandle|noop)


class SweepHandle:
    """Handle on a bulk multi-spec sweep: collects the per-spec tickets and
    streams the pooled frontier-so-far as lanes complete."""

    def __init__(self, tickets: list[Ticket]):
        self.tickets = tickets
        self.total = len(tickets)

    def results(self, timeout: float | None = None
                ) -> list[SynthesisResponse | SheddedResponse]:
        """All terminal responses, in submission order."""
        return [t.result(timeout) for t in self.tickets]


class ServiceFrontend:
    """The admission queue + scheduler over one :class:`SynthesisService`.

    ``window`` seconds is the base batching window; with
    ``adaptive_window`` (default) it tracks :data:`WINDOW_FRACTION` of the
    engine's observed per-pass latency within :data:`WINDOW_BOUNDS`.
    ``max_batch`` caps one drain; ``max_depth`` bounds the admission queue —
    the backpressure limit past which submits are shed.  ``start=False``
    skips the scheduler thread; tests then drive batches deterministically
    with :meth:`run_pending`.
    """

    def __init__(self, service: SynthesisService | None = None, *,
                 window: float = 0.005, max_batch: int = 32,
                 max_depth: int = 128, adaptive_window: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 start: bool = True):
        if max_batch < 1 or max_depth < 1:
            raise ValueError("max_batch and max_depth must be >= 1")
        self.service = service if service is not None else SynthesisService()
        self.window = float(window)
        self.max_batch = int(max_batch)
        self.max_depth = int(max_depth)
        self.adaptive_window = bool(adaptive_window)
        self.stats = FrontendStats()
        self._clock = clock
        self._heap: list[tuple[int, int, _Entry]] = []
        self._seq = 0
        self._inflight = 0               # batches currently being served
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._stopping = False
        self._pass_latency_ewma: float | None = None
        E.add_latency_hook(self._observe_pass)
        self._thread: threading.Thread | None = None
        if start:
            self._thread = threading.Thread(target=self._loop,
                                            name="synthesis-frontend",
                                            daemon=True)
            self._thread.start()

    # -- admission -----------------------------------------------------------

    def submit(self, request: SynthesisRequest,
               on_event: Optional[Callable[[StreamEvent], None]] = None
               ) -> Ticket:
        """Admit one request.  Returns immediately with a :class:`Ticket`;
        if the queue is full (or the frontend is shutting down) the ticket
        is already resolved with a typed :class:`SheddedResponse` — the
        caller always learns the fate of every request."""
        if not isinstance(request, SynthesisRequest):
            raise TypeError("submit() takes a SynthesisRequest, got "
                            f"{type(request).__name__}")
        ticket = Ticket(request)
        now = self._clock()
        span = tracer.start_trace("request", start_s=now, tags={
            "kind": request.kind, "priority": int(request.priority)})
        if span and request.tag is not None:
            span.set_tag("tag", request.tag)
        with self._work:
            depth = len(self._heap)
            reason = None
            if self._stopping:
                reason = "shutdown"
            elif depth >= self.max_depth:
                reason = "queue_full"
            if reason is not None:
                self.stats.shedded += 1
                resp = SheddedResponse(request=request, reason=reason,
                                       queue_depth=depth)
                ticket._resolve(resp)
                if span:
                    span.set_tag("shedded", reason)
                    span.finish(end_s=self._clock())
                self._emit(on_event, StreamEvent(
                    request=request, kind=RequestState.SHEDDED.value,
                    response=resp))
                return ticket
            entry = _Entry(request, ticket, on_event, now,
                           None if request.deadline_s is None
                           else now + request.deadline_s, span=span)
            heapq.heappush(self._heap,
                           (int(request.priority), self._seq, entry))
            self._seq += 1
            self.stats.submitted += 1
            self.stats.depth_hwm = max(self.stats.depth_hwm,
                                       len(self._heap))
            self._emit(on_event, StreamEvent(
                request=request, kind=RequestState.QUEUED.value))
            self._work.notify_all()
        return ticket

    def submit_sweep(self, specs: Sequence[MacroSpec], *, tech=None,
                     resolution=None, mode=None,
                     priority: Priority = Priority.BULK,
                     on_frontier: Optional[Callable[[int, int, tuple],
                                                    None]] = None
                     ) -> SweepHandle:
        """Submit a long lattice sweep as one bulk request per spec and
        stream the pooled frontier-so-far: ``on_frontier(done, total,
        pool)`` fires each time a lane completes, with ``pool`` the
        eps-nondominated union over every finished lane (the same
        ``frontier_union`` the offline sweeps end with) — so a caller
        watches the sweep's frontier grow instead of blocking on the last
        spec."""
        specs = list(specs)
        done: list = []
        stream_lock = threading.Lock()

        def lane_event(ev: StreamEvent) -> None:
            if ev.kind != FRONTIER_EVENT or on_frontier is None:
                return
            from ..core.multispec import frontier_union
            with stream_lock:
                done.append(ev.result)
                pool, _ = frontier_union(
                    done, [f"sweep[{i}]" for i in range(len(done))])
                on_frontier(len(done), len(specs), tuple(pool))

        tickets = [self.submit(SynthesisRequest(
            spec=s, tech=tech, resolution=resolution, mode=mode,
            priority=priority), on_event=lane_event) for s in specs]
        return SweepHandle(tickets)

    def serve(self, requests: Sequence[SynthesisRequest],
              timeout: float | None = None) -> list[SynthesisResponse]:
        """Blocking convenience: submit every request, wait for all of
        them.  Raises ``RuntimeError`` if any was shedded (callers that
        want typed sheds use :meth:`submit` directly) — so this method has
        the same all-or-nothing contract as ``SynthesisService.serve`` and
        ``select_macros`` can run through a frontend unchanged."""
        tickets = [self.submit(r) for r in requests]
        out = []
        for t in tickets:
            resp = t.result(timeout)
            if isinstance(resp, SheddedResponse):
                raise RuntimeError(
                    f"request shedded ({resp.reason}, queue_depth="
                    f"{resp.queue_depth}); retry with backoff or raise "
                    "max_depth")
            out.append(resp)
        return out

    # -- the batching window -------------------------------------------------

    def effective_window(self) -> float:
        """The batching window currently in force: the base window, or —
        once the engine's latency hooks have reported fused-pass times —
        :data:`WINDOW_FRACTION` of the latency EWMA, clamped to
        :data:`WINDOW_BOUNDS`."""
        if not self.adaptive_window or self._pass_latency_ewma is None:
            return self.window
        lo, hi = WINDOW_BOUNDS
        return min(max(self._pass_latency_ewma * WINDOW_FRACTION, lo,
                       self.window), hi)

    def _observe_pass(self, plan, elapsed_s: float) -> None:
        """Engine latency hook: feed per-pass latency back to the window."""
        with self._lock:
            if self._pass_latency_ewma is None:
                self._pass_latency_ewma = elapsed_s
            else:
                self._pass_latency_ewma = (0.7 * self._pass_latency_ewma
                                           + 0.3 * elapsed_s)

    # -- the scheduler -------------------------------------------------------

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            self._serve_batch(batch)
            with self._work:
                self._inflight -= 1
                if not self._heap and self._inflight == 0:
                    self._idle.notify_all()

    def _collect(self) -> list[_Entry] | None:
        """Block until work exists, hold the batching window open, then
        drain up to ``max_batch`` entries in (priority, FIFO) order."""
        with self._work:
            while not self._heap and not self._stopping:
                self._work.wait()
            if not self._heap:
                return None                      # stopping and drained
            deadline = self._clock() + self.effective_window()
            while (len(self._heap) < self.max_batch
                   and not self._stopping):
                remaining = deadline - self._clock()
                if remaining <= 0:
                    break
                self._work.wait(remaining)
            batch = self._pop_batch_locked()
            self._inflight += 1
            return batch

    def _pop_batch_locked(self) -> list[_Entry]:
        batch = []
        while self._heap and len(batch) < self.max_batch:
            _, _, entry = heapq.heappop(self._heap)
            batch.append(entry)
        return batch

    def run_pending(self) -> int:
        """Drain and serve one batch synchronously on the calling thread —
        the deterministic drive the tests and single-threaded callers use
        (no scheduler races: submissions already queued are batched in
        strict priority order).  Returns the number of requests served or
        shedded; 0 when the queue is empty."""
        with self._work:
            batch = self._pop_batch_locked()
        if not batch:
            return 0
        self._serve_batch(batch)
        return len(batch)

    def _serve_batch(self, batch: list[_Entry]) -> None:
        now = self._clock()
        live: list[_Entry] = []
        for e in batch:
            if e.deadline_at is not None and now > e.deadline_at:
                self.stats.shedded += 1
                resp = SheddedResponse(request=e.request, reason="deadline",
                                       queue_depth=len(self._heap))
                resp_ev = StreamEvent(request=e.request,
                                      kind=RequestState.SHEDDED.value,
                                      response=resp)
                e.ticket._resolve(resp)
                if e.span:
                    e.span.set_tag("shedded", "deadline")
                    e.span.finish(end_s=now)
                self._emit(e.on_event, resp_ev)
                continue
            e.batched_at = now
            e.ticket.state = RequestState.BATCHED
            self._emit(e.on_event, StreamEvent(
                request=e.request, kind=RequestState.BATCHED.value))
            live.append(e)
        if not live:
            return
        self.stats.batches += 1
        self.stats.max_batch = max(self.stats.max_batch, len(live))

        def partial(i: int, result) -> None:
            e = live[i]
            self._emit(e.on_event, StreamEvent(
                request=e.request, kind=FRONTIER_EVENT, index=i,
                result=result, done=i + 1, total=len(live)))

        try:
            responses = self.service.serve(
                [e.request for e in live], on_partial=partial,
                contexts=[e.span.context if e.span else None for e in live])
        except Exception as exc:                     # typed, never silent
            with self._lock:
                depth = len(self._heap)
            for e in live:
                self.stats.shedded += 1
                resp = SheddedResponse(request=e.request,
                                       reason="internal_error",
                                       queue_depth=depth,
                                       detail=f"{type(exc).__name__}: {exc}")
                e.ticket._resolve(resp)
                if e.span:
                    e.span.set_tag("error", type(exc).__name__)
                    e.span.finish(end_s=self._clock())
                self._emit(e.on_event, StreamEvent(
                    request=e.request, kind=RequestState.SHEDDED.value,
                    response=resp))
            return
        served_at = self._clock()
        latency = get_registry().histogram("frontend/request_latency_s")
        for e, resp in zip(live, responses):
            resp.queued_at = e.submitted_at
            resp.batched_at = e.batched_at
            resp.served_at = served_at
            self.stats.served += 1
            latency.observe(served_at - e.submitted_at)
            if e.span:
                # Lifecycle children carry the very timestamps the response
                # is stamped with, so span boundaries == response times.
                tracer.start("request.queued", parent=e.span.context,
                             start_s=e.submitted_at
                             ).finish(end_s=e.batched_at)
                tracer.start("request.batched", parent=e.span.context,
                             start_s=e.batched_at,
                             tags={"batch_size": len(live)}
                             ).finish(end_s=served_at)
                e.span.set_tag("served_from", resp.served_from)
                e.span.finish(end_s=served_at)
            e.ticket._resolve(resp)
            self._emit(e.on_event, StreamEvent(
                request=e.request, kind=RequestState.SERVED.value,
                response=resp))

    @staticmethod
    def _emit(on_event, event: StreamEvent) -> None:
        if on_event is None:
            return
        try:
            on_event(event)
        except Exception:
            pass          # a broken observer must not take down the front

    # -- lifecycle -----------------------------------------------------------

    def drain(self, timeout: float | None = None) -> bool:
        """Block until the queue is empty and no batch is in flight."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._idle:
            while self._heap or self._inflight:
                remaining = (None if deadline is None
                             else deadline - self._clock())
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(remaining)
        return True

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler.  With ``drain`` (default) queued requests
        are served first; otherwise they are shed with reason
        ``shutdown``.  Idempotent; also removes the engine latency hook."""
        with self._work:
            self._stopping = True
            if not drain:
                leftovers = self._pop_batch_locked()
                while leftovers:
                    for e in leftovers:
                        self.stats.shedded += 1
                        resp = SheddedResponse(request=e.request,
                                               reason="shutdown",
                                               queue_depth=0)
                        e.ticket._resolve(resp)
                        self._emit(e.on_event, StreamEvent(
                            request=e.request,
                            kind=RequestState.SHEDDED.value, response=resp))
                    leftovers = self._pop_batch_locked()
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=60)
            self._thread = None
        elif drain:
            while self.run_pending():
                pass
        try:
            E.remove_latency_hook(self._observe_pass)
        except ValueError:
            pass          # already removed (double close)

    def __enter__(self) -> "ServiceFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def depth(self) -> int:
        """Current admission-queue depth (the backpressure observable)."""
        with self._lock:
            return len(self._heap)

    def telemetry(self) -> dict:
        """The full serving-stack stats rollup: the service's request /
        cache / shared-registry counters (:meth:`SynthesisService.
        telemetry`) plus this front's admission-queue counters — the one
        dict a fleet dashboard scrapes per host."""
        out = self.service.telemetry()
        out["frontend"] = self.stats.as_dict()
        return out
