"""The typed request/response surface of the synthesis service.

Before this module, a synthesis request travelled the service as a parallel
kwarg tuple — ``(spec, tech=..., resolution=...)`` threaded separately
through ``synthesize``, ``synthesize_many``, ``request_key`` and
``select_macros``, with no place to hang serving-side intent (priority,
deadline).  :class:`SynthesisRequest` is the one value that carries all of
it; every service entry point consumes it and every answer comes back as a
typed response:

  :class:`SynthesisResponse`   a served request: the ``SearchResult``, which
                               tier answered it (``cache`` / ``coalesced`` /
                               ``engine``) and the lifecycle timestamps the
                               async front stamps on it;
  :class:`SheddedResponse`     an explicitly rejected request (queue full,
                               deadline passed, frontend shut down) — load
                               shedding is typed, never a silent drop.

Lifecycle: a request is QUEUED on admission, BATCHED when the scheduler
folds it into a fused engine pass, and ends SERVED or SHEDDED
(:class:`RequestState`).  :class:`StreamEvent` is the streaming unit —
lifecycle transitions plus frontier-so-far partials for long sweeps — fired
on the callbacks a caller registers at submit time.

Requests are frozen and hashable (spec and tech are frozen dataclasses), so
they can key dicts and travel between threads without copying.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional

from ..core.axes import LatticeConfig
from ..core.macro import MacroSpec
from ..core.searcher import SearchResult
from ..core.tech import TechModel

#: ``SynthesisRequest.kind`` values: ``"search"`` replays Algorithm 1 over
#: the preference grid (the historical request shape); ``"sweep"`` returns
#: the exhaustive design-space frontier — the shape the service answers
#: incrementally from per-axis slice caches when only one axis changed.
REQUEST_KINDS = ("search", "sweep")


class Priority(enum.IntEnum):
    """Admission-queue priority classes (lower value = served first).

    INTERACTIVE is the ``--dcim-select`` shape of traffic — a user waiting
    on one selection; BULK is the 100+-spec sweep shape that can absorb
    batching-window latency.  Ordering within a class is FIFO.
    """

    INTERACTIVE = 0
    BULK = 1


class RequestState(str, enum.Enum):
    """Lifecycle of one request through the async front."""

    QUEUED = "queued"      # admitted, waiting for a batching window
    BATCHED = "batched"    # folded into a fused engine pass
    SERVED = "served"      # answered with a SynthesisResponse
    SHEDDED = "shedded"    # rejected with a SheddedResponse

    def terminal(self) -> bool:
        return self in (RequestState.SERVED, RequestState.SHEDDED)


#: ``SheddedResponse.reason`` values — the complete set, so callers can
#: switch on them.
SHED_REASONS = ("queue_full", "deadline", "shutdown", "internal_error")


@dataclass(frozen=True)
class SynthesisRequest:
    """One synthesis request: what to synthesize plus how to serve it.

    ``tech`` / ``resolution`` / ``mode`` / ``config`` default to the serving
    :class:`~repro.service.service.SynthesisService`'s own defaults when
    ``None`` — the response's cache address always reflects the values the
    request actually ran under.  ``kind`` selects the result shape
    (:data:`REQUEST_KINDS`): a ``"search"`` replays Algorithm 1 over the
    preference grid, a ``"sweep"`` returns the exhaustive lattice frontier
    (and is eligible for incremental re-synthesis from per-axis slice
    caches).  ``config`` picks the lattice axis set
    (:class:`repro.core.axes.LatticeConfig`; the seed axes when unset).
    ``priority`` orders the admission queue; ``deadline_s`` is a relative
    admission deadline (seconds from submit): a request still queued past it
    is shedded, never served stale.
    """

    spec: MacroSpec
    tech: Optional[TechModel] = None
    resolution: Optional[int] = None
    mode: Optional[str] = None
    kind: str = "search"
    config: Optional[LatticeConfig] = None
    priority: Priority = Priority.INTERACTIVE
    deadline_s: Optional[float] = None
    tag: Optional[str] = None        # caller correlation id, echoed back

    def __post_init__(self):
        if not isinstance(self.spec, MacroSpec):
            raise TypeError(f"spec must be a MacroSpec, got "
                            f"{type(self.spec).__name__}")
        if self.kind not in REQUEST_KINDS:
            raise ValueError(f"unknown request kind {self.kind!r}; "
                             f"pick from {REQUEST_KINDS}")
        if self.config is not None and not isinstance(self.config,
                                                      LatticeConfig):
            raise TypeError(f"config must be a LatticeConfig, got "
                            f"{type(self.config).__name__}")
        object.__setattr__(self, "priority", Priority(self.priority))
        if self.resolution is not None and int(self.resolution) < 1:
            raise ValueError("resolution must be >= 1")
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError("deadline_s is relative to submit time and "
                             "must be > 0")


@dataclass
class SynthesisResponse:
    """A served request.  ``served_from`` names the tier that answered it:
    ``"cache"`` (FrontierCache hit, memory or disk), ``"coalesced"`` (folded
    onto an identical in-batch miss) or ``"engine"`` (a fused-pass lane).
    The ``*_at`` stamps are :func:`time.monotonic` seconds filled in by the
    async front (``None`` on the direct blocking path)."""

    request: SynthesisRequest
    result: SearchResult
    served_from: str
    state: RequestState = RequestState.SERVED
    queued_at: Optional[float] = None
    batched_at: Optional[float] = None
    served_at: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-served wall latency (the benchmark's p50/p99 metric)."""
        if self.queued_at is None or self.served_at is None:
            return None
        return self.served_at - self.queued_at

    @property
    def queue_delay_s(self) -> Optional[float]:
        """Time spent waiting for a batching window."""
        if self.queued_at is None or self.batched_at is None:
            return None
        return self.batched_at - self.queued_at


@dataclass
class SheddedResponse:
    """An explicitly rejected request — the typed form of load shedding.
    ``reason`` is one of :data:`SHED_REASONS`; ``queue_depth`` is the
    admission-queue depth observed at the shedding decision (the
    backpressure signal a client retries against)."""

    request: SynthesisRequest
    reason: str
    queue_depth: int
    state: RequestState = RequestState.SHEDDED
    detail: str = ""
    result: None = None              # uniform access with SynthesisResponse


@dataclass(frozen=True)
class StreamEvent:
    """One streaming callback unit.

    ``kind`` is a :class:`RequestState` value for lifecycle transitions
    (``queued`` / ``batched`` / ``served`` / ``shedded``) or the string
    ``"frontier"`` for a frontier-so-far partial: ``result`` then carries
    the finished per-spec :class:`SearchResult` and ``done``/``total``
    report sweep progress, so a long lattice sweep streams its frontier as
    each spec lane completes instead of blocking until the last one."""

    request: SynthesisRequest
    kind: str
    index: int = 0
    result: Optional[SearchResult] = None
    response: object = None          # SynthesisResponse | SheddedResponse
    done: int = 0
    total: int = 0


#: ``StreamEvent.kind`` for frontier-so-far partials.
FRONTIER_EVENT = "frontier"


def as_requests(specs, tech=None, resolution=None, mode=None,
                priority: Priority = Priority.INTERACTIVE,
                deadline_s: float | None = None) -> list[SynthesisRequest]:
    """Lift a sequence of bare specs into typed requests with shared
    serving parameters — the helper every deprecation shim builds on."""
    return [SynthesisRequest(spec=s, tech=tech, resolution=resolution,
                             mode=mode, priority=priority,
                             deadline_s=deadline_s) for s in specs]
