"""repro.service — the online synthesis service layer.

Turns the offline multi-spec compiler into a serving system: typed
single-spec requests (:mod:`repro.service.requests`) are canonicalized
(:mod:`repro.service.keys`), answered from a content-addressed frontier
cache (:mod:`repro.service.cache`), and cache misses are coalesced into one
fused pass through the shared execution engine
(:mod:`repro.service.service`).  The async front
(:mod:`repro.service.frontend`) builds those batches from an online request
stream: bounded admission queue, priority classes, an adaptive batching
window, explicit load shedding, and streamed frontier-so-far partials.
The shared artifact registry (:mod:`repro.service.registry`) is the
fleet-wide third cache tier: CAS-safe concurrent writers on shared storage,
claim files so one host synthesizes a missing key, and scope-digest records
for fleet-wide invalidation scoped to exactly the recalibrated axis values.
Responses are bit-identical to fresh unbatched engine runs in every tier.
"""

from .artifacts import (ARTIFACT_SCHEMA, CacheArtifactError,
                        atomic_write_json, load_artifact, quarantine_artifact,
                        result_from_payload, result_to_payload)
from .cache import CacheStats, FrontierCache
from .frontend import (WINDOW_BOUNDS, WINDOW_FRACTION, FrontendStats,
                       ServiceFrontend, SweepHandle, Ticket)
from .keys import (axis_signatures, cache_key, canonical_spec, key_scope,
                   lattice_signature, slice_key, spec_key, stale_digests,
                   sweep_key)
from .registry import (CLAIM_TTL_S, ArtifactRegistry, RegistryClaim,
                       RegistryStats)
from .requests import (FRONTIER_EVENT, REQUEST_KINDS, SHED_REASONS, Priority,
                       RequestState, SheddedResponse, StreamEvent,
                       SynthesisRequest, SynthesisResponse, as_requests)
from .service import (SERVICE_MODES, ServiceStats, SynthesisService,
                      get_service, reset_service, resolve_service_mode)

__all__ = [
    "ARTIFACT_SCHEMA", "ArtifactRegistry", "CLAIM_TTL_S",
    "CacheArtifactError", "CacheStats", "FRONTIER_EVENT", "FrontendStats",
    "FrontierCache", "Priority", "RegistryClaim", "RegistryStats",
    "RequestState", "SERVICE_MODES", "SHED_REASONS", "ServiceFrontend",
    "ServiceStats", "SheddedResponse", "StreamEvent", "SweepHandle",
    "SynthesisRequest", "REQUEST_KINDS", "SynthesisResponse",
    "SynthesisService", "Ticket", "WINDOW_BOUNDS", "WINDOW_FRACTION",
    "as_requests", "atomic_write_json", "axis_signatures", "cache_key",
    "canonical_spec", "get_service", "key_scope", "lattice_signature",
    "load_artifact", "quarantine_artifact", "reset_service",
    "result_from_payload", "result_to_payload", "resolve_service_mode",
    "slice_key", "spec_key", "stale_digests", "sweep_key",
]
