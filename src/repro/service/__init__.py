"""repro.service — the online synthesis service layer.

Turns the offline multi-spec compiler into a serving system: single-spec
requests are canonicalized (:mod:`repro.service.keys`), answered from a
content-addressed frontier cache (:mod:`repro.service.cache`), and cache
misses are coalesced into one fused pass through the shared execution
engine (:mod:`repro.service.service`).  Responses are bit-identical to
fresh unbatched engine runs in every tier.
"""

from .artifacts import (ARTIFACT_SCHEMA, result_from_payload,
                        result_to_payload)
from .cache import CacheArtifactError, CacheStats, FrontierCache
from .keys import cache_key, canonical_spec, lattice_signature, spec_key
from .service import (SERVICE_MODES, ServiceStats, SynthesisService,
                      get_service, reset_service, resolve_service_mode)

__all__ = [
    "ARTIFACT_SCHEMA", "CacheArtifactError", "CacheStats", "FrontierCache",
    "SERVICE_MODES", "ServiceStats", "SynthesisService", "cache_key",
    "canonical_spec", "get_service", "lattice_signature",
    "reset_service", "resolve_service_mode", "result_from_payload",
    "result_to_payload", "spec_key",
]
