"""repro.service — the online synthesis service layer.

Turns the offline multi-spec compiler into a serving system: typed
single-spec requests (:mod:`repro.service.requests`) are canonicalized
(:mod:`repro.service.keys`), answered from a content-addressed frontier
cache (:mod:`repro.service.cache`), and cache misses are coalesced into one
fused pass through the shared execution engine
(:mod:`repro.service.service`).  The async front
(:mod:`repro.service.frontend`) builds those batches from an online request
stream: bounded admission queue, priority classes, an adaptive batching
window, explicit load shedding, and streamed frontier-so-far partials.
Responses are bit-identical to fresh unbatched engine runs in every tier.
"""

from .artifacts import (ARTIFACT_SCHEMA, result_from_payload,
                        result_to_payload)
from .cache import CacheArtifactError, CacheStats, FrontierCache
from .frontend import (WINDOW_BOUNDS, WINDOW_FRACTION, FrontendStats,
                       ServiceFrontend, SweepHandle, Ticket)
from .keys import (axis_signatures, cache_key, canonical_spec,
                   lattice_signature, slice_key, spec_key, sweep_key)
from .requests import (FRONTIER_EVENT, REQUEST_KINDS, SHED_REASONS, Priority,
                       RequestState, SheddedResponse, StreamEvent,
                       SynthesisRequest, SynthesisResponse, as_requests)
from .service import (SERVICE_MODES, ServiceStats, SynthesisService,
                      get_service, reset_service, resolve_service_mode)

__all__ = [
    "ARTIFACT_SCHEMA", "CacheArtifactError", "CacheStats", "FRONTIER_EVENT",
    "FrontendStats", "FrontierCache", "Priority", "RequestState",
    "SERVICE_MODES", "SHED_REASONS", "ServiceFrontend", "ServiceStats",
    "SheddedResponse", "StreamEvent", "SweepHandle", "SynthesisRequest",
    "REQUEST_KINDS", "SynthesisResponse", "SynthesisService", "Ticket",
    "WINDOW_BOUNDS", "WINDOW_FRACTION", "as_requests", "axis_signatures",
    "cache_key", "canonical_spec", "get_service", "lattice_signature",
    "reset_service", "result_from_payload", "result_to_payload",
    "resolve_service_mode", "slice_key", "spec_key", "sweep_key",
]
