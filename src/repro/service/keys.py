"""Spec canonicalization and content-addressed cache keys.

A synthesis result is fully determined by four ingredients: the macro spec,
the calibrated tech model, the enumerated lattice shape (memcell set plus
the discrete axis constants), and the search configuration (preference-grid
resolution, Pareto eps band).  This module turns each ingredient into a
deterministic canonical form and hashes them into the content address the
:class:`repro.service.cache.FrontierCache` stores frontiers under:

  :func:`spec_key`          sha256 of the canonical ``MacroSpec`` encoding —
                            two structurally equal specs (however they were
                            constructed) share one key;
  :func:`lattice_signature` sha256 over the tech calibration and the lattice
                            axis constants — a recalibrated tech or a changed
                            memcell set can never alias a cached frontier;
  :func:`cache_key`         the composite ``(spec_key, lattice signature,
                            resolution, PARETO_EPS)`` address.

Canonical encodings are JSON with sorted keys and no NaN/Inf; Python's float
repr round-trips IEEE-754 doubles exactly, so equal float fields hash
equally and nothing is quantized.  Everything here is numpy/json-only — key
computation never touches jax.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Sequence

from ..core.macro import MacroSpec
from ..core.pareto import PARETO_EPS
from ..core.searcher import RHO_STEPS
from ..core.subcircuits import MemCellKind
from ..core.tech import TechModel


def _digest(obj) -> str:
    """sha256 hex digest of the canonical JSON encoding of ``obj``."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)
    return hashlib.sha256(blob.encode()).hexdigest()


def canonical_spec(spec: MacroSpec) -> dict:
    """The canonical plain-data encoding of a spec: every dataclass field,
    container types normalized (tuples as lists, precisions in declaration
    order — order is semantic: it defines the mode list)."""
    out = dataclasses.asdict(spec)
    out["int_precisions"] = [int(b) for b in spec.int_precisions]
    out["fp_precisions"] = [str(f) for f in spec.fp_precisions]
    return out


def spec_key(spec: MacroSpec) -> str:
    """Deterministic content hash of a spec — the per-request half of the
    cache address."""
    return _digest(canonical_spec(spec))


def canonical_tech(tech: TechModel) -> dict:
    """Every calibration knob and relative constant of the tech model."""
    return {k: (float(v) if isinstance(v, float) else v)
            for k, v in dataclasses.asdict(tech).items()}


def lattice_signature(tech: TechModel,
                      memcells: Sequence[MemCellKind]) -> str:
    """Content hash of everything the enumerated design lattice and its PPA
    tables depend on besides the spec: the tech calibration and the discrete
    axis constants (memcell set, CSA rho steps, OFU pipeline depths)."""
    from ..core.batched import PIPE_STEPS
    return _digest({
        "tech": canonical_tech(tech),
        "memcells": [m.value for m in memcells],
        "rho_steps": [float(r) for r in RHO_STEPS],
        "pipe_steps": [int(p) for p in PIPE_STEPS],
    })


def cache_key(spec: MacroSpec, tech: TechModel,
              memcells: Sequence[MemCellKind], resolution: int,
              eps: float = PARETO_EPS) -> str:
    """The content address of one synthesized frontier:
    ``(spec_key, lattice signature, resolution, eps)`` hashed together."""
    return _digest({
        "spec": spec_key(spec),
        "lattice": lattice_signature(tech, memcells),
        "resolution": int(resolution),
        "pareto_eps": float(eps),
    })
