"""Spec canonicalization and content-addressed cache keys.

A synthesis result is fully determined by four ingredients: the macro spec,
the calibrated tech model, the enumerated lattice shape (the registered axis
set, :mod:`repro.core.axes`), and the search configuration (preference-grid
resolution, Pareto eps band).  This module turns each ingredient into a
deterministic canonical form and hashes them into the content address the
:class:`repro.service.cache.FrontierCache` stores frontiers under:

  :func:`spec_key`          sha256 of the canonical ``MacroSpec`` encoding —
                            two structurally equal specs (however they were
                            constructed) share one key;
  :func:`axis_signatures`   one sha256 per registered sliceable axis plus a
                            ``"__global__"`` digest (see below) — the unit of
                            scoped cache invalidation;
  :func:`lattice_signature` sha256 over the per-axis signature map — a
                            recalibrated tech or a changed axis set can never
                            alias a cached frontier;
  :func:`cache_key`         the composite ``(spec_key, lattice signature,
                            resolution, PARETO_EPS)`` address of one search
                            frontier;
  :func:`sweep_key` /       the addresses of one exhaustive sweep frontier
  :func:`slice_key`         and of one per-axis-value *slice* frontier — the
                            incremental re-synthesis units.

Per-axis cache-invalidation semantics
-------------------------------------

Each sliceable axis (:data:`repro.core.axes.SLICEABLE_AXES`) signs exactly
the inputs that can change *its own* per-value PPA contributions: the axis's
value list and the tech-model fields attributed to those values
(:data:`repro.core.axes.MEMCELL_TECH_FIELDS` /
:data:`~repro.core.axes.MULTMUX_TECH_FIELDS`).  Every tech field *not*
attributed to a single axis — wordline drivers, sense amps, adder-tree cells,
OFU/alignment constants, the shared mux — lands in the ``"__global__"``
digest, because a change there moves every lattice point.

A *slice* key (axis ``A`` restricted to one value ``v``) hashes ``v``'s own
value digest together with the full digests of every OTHER axis and the
global digest — but NOT the rest of ``A``'s values.  Consequences, which
:class:`repro.service.service.SynthesisService` exploits for incremental
re-synthesis:

  * recalibrating a field scoped to one value of ``A`` (say the 6T cell
    area) invalidates exactly the ``A=SRAM_6T`` slice — every other
    ``A``-slice key is unchanged and still hits;
  * growing axis ``A`` by a value leaves all existing ``A``-slices valid
    (their keys never covered ``A``'s sibling values) — only the new value's
    slice is evaluated;
  * both changes invalidate every *other* axis's slices and the full-sweep
    key (their digests cover ``A`` as a whole), so nothing stale can ever be
    served — the degradation is re-derivation cost, never correctness.

Canonical encodings are JSON with sorted keys and no NaN/Inf; Python's float
repr round-trips IEEE-754 doubles exactly, so equal float fields hash
equally and nothing is quantized.  Everything here is numpy/json-only — key
computation never touches jax.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Sequence

from ..core.axes import (MEMCELL_TECH_FIELDS, MULTMUX_TECH_FIELDS,
                         SCOPED_TECH_FIELDS, LatticeConfig, seed_config)
from ..core.macro import MacroSpec
from ..core.pareto import PARETO_EPS
from ..core.subcircuits import MemCellKind
from ..core.tech import TechModel


def _digest(obj) -> str:
    """sha256 hex digest of the canonical JSON encoding of ``obj``."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)
    return hashlib.sha256(blob.encode()).hexdigest()


def canonical_spec(spec: MacroSpec) -> dict:
    """The canonical plain-data encoding of a spec: every dataclass field,
    container types normalized (tuples as lists, precisions in declaration
    order — order is semantic: it defines the mode list)."""
    out = dataclasses.asdict(spec)
    out["int_precisions"] = [int(b) for b in spec.int_precisions]
    out["fp_precisions"] = [str(f) for f in spec.fp_precisions]
    return out


def spec_key(spec: MacroSpec) -> str:
    """Deterministic content hash of a spec — the per-request half of the
    cache address."""
    return _digest(canonical_spec(spec))


def canonical_tech(tech: TechModel) -> dict:
    """Every calibration knob and relative constant of the tech model."""
    return {k: (float(v) if isinstance(v, float) else v)
            for k, v in dataclasses.asdict(tech).items()}


def _normalize_config(memcells: Sequence[MemCellKind] | None,
                      config: LatticeConfig | None) -> LatticeConfig:
    if config is None:
        return seed_config(tuple(memcells) if memcells is not None else None)
    if memcells is not None:
        return config.with_memcells(tuple(memcells))
    return config


def axis_value_payloads(tech: TechModel,
                        config: LatticeConfig | None = None
                        ) -> dict[str, list[dict]]:
    """Canonical per-value payload of every sliceable axis the config
    enables: the value identity plus the tech fields scoped to it.  This is
    what a single axis value's PPA table contribution depends on besides the
    spec and the global tech digest."""
    config = _normalize_config(None, config)
    techd = canonical_tech(tech)
    out: dict[str, list[dict]] = {
        "memcell": [{"value": m.value,
                     "tech": {f: techd[f] for f in MEMCELL_TECH_FIELDS[m]}}
                    for m in config.memcells],
        "multmux": [{"value": v.value,
                     "tech": {f: techd[f] for f in MULTMUX_TECH_FIELDS[v]
                              if f in SCOPED_TECH_FIELDS}}
                    for v in config.multmuxes],
        "rho": [{"value": float(r)} for r in config.rho_steps],
        "pipe": [{"value": int(p)} for p in config.pipe_steps],
    }
    if config.precision_modes:
        # The plan *recipe* per mode index is deterministic given the spec,
        # and the spec is hashed separately in every composite key.
        out["precision"] = [{"value": i}
                            for i in range(config.precision_modes)]
    if config.approx_cells:
        out["approx_cell"] = [{"name": c.name, "k_delay": float(c.k_delay),
                               "k_energy": float(c.k_energy),
                               "k_area": float(c.k_area)}
                              for c in config.approx_cells]
    return out


def axis_signatures(tech: TechModel,
                    config: LatticeConfig | None = None) -> dict[str, str]:
    """One content digest per sliceable axis (its value-payload list) plus
    the ``"__global__"`` digest of every tech field not scoped to a single
    axis — the complete invalidation map of a lattice (see the module
    docstring for the semantics)."""
    sigs = {axis: _digest(payloads)
            for axis, payloads in axis_value_payloads(tech, config).items()}
    techd = canonical_tech(tech)
    sigs["__global__"] = _digest({k: v for k, v in techd.items()
                                  if k not in SCOPED_TECH_FIELDS})
    return sigs


def lattice_signature(tech: TechModel,
                      memcells: Sequence[MemCellKind] | None = None,
                      config: LatticeConfig | None = None) -> str:
    """Content hash of everything the enumerated design lattice and its PPA
    tables depend on besides the spec: the digest of the per-axis signature
    map, so it changes exactly when some :func:`axis_signatures` entry
    does."""
    return _digest(axis_signatures(tech, _normalize_config(memcells, config)))


def cache_key(spec: MacroSpec, tech: TechModel,
              memcells: Sequence[MemCellKind] | None = None,
              resolution: int = 4, eps: float = PARETO_EPS,
              config: LatticeConfig | None = None) -> str:
    """The content address of one synthesized search frontier:
    ``(spec_key, lattice signature, resolution, eps)`` hashed together."""
    return _digest({
        "spec": spec_key(spec),
        "lattice": lattice_signature(tech, memcells, config),
        "resolution": int(resolution),
        "pareto_eps": float(eps),
    })


def key_scope(tech: TechModel, config: LatticeConfig | None = None,
              axis: str | None = None,
              value_index: int | None = None) -> dict[str, str]:
    """The invalidation scope of one cache entry: every named content digest
    the entry depends on, published alongside its shared-registry copy
    (:meth:`repro.service.registry.ArtifactRegistry.publish`).

    Without ``axis``, the scope of a full search/sweep entry: one
    ``axis:<name>`` digest per sliceable axis, the ``__global__`` tech
    digest, and the composite ``lattice`` signature (so eviction can also be
    scoped by :func:`lattice_signature` alone).  With ``axis``/
    ``value_index``, the scope of one per-axis-value *slice* entry: the
    value's OWN payload digest (``value:<axis>``) plus every OTHER axis's
    digest and the global digest — exactly the ingredients
    :func:`slice_key` hashes, so an entry is evicted iff its key became
    unreachable."""
    config = _normalize_config(None, config)
    sigs = axis_signatures(tech, config)

    def named(a: str) -> str:
        return a if a == "__global__" else f"axis:{a}"

    if axis is None:
        scope = {named(a): s for a, s in sigs.items()}
        scope["lattice"] = _digest(sigs)       # == lattice_signature
        return scope
    payloads = axis_value_payloads(tech, config)
    if axis not in payloads:
        raise KeyError(f"axis {axis!r} is not sliceable under this config "
                       f"(have {sorted(payloads)})")
    values = payloads[axis]
    if value_index is None or not 0 <= value_index < len(values):
        raise IndexError(f"axis {axis!r} has {len(values)} values; "
                         f"got index {value_index}")
    scope = {named(a): s for a, s in sigs.items() if a != axis}
    scope[f"value:{axis}"] = _digest(values[value_index])
    return scope


def stale_digests(old_tech: TechModel, new_tech: TechModel,
                  config: LatticeConfig | None = None,
                  new_config: LatticeConfig | None = None) -> set[str]:
    """The content digests a recalibration (or axis-set change) retired:
    every digest that appears in some entry's :func:`key_scope` under the
    OLD (tech, config) but no longer holds under the new one.  Feeding this
    set to :meth:`repro.service.registry.ArtifactRegistry.
    invalidate_digests` evicts exactly the entries whose cache keys became
    unreachable — a change scoped to one axis value keeps every other value's
    slice entries warm, fleet-wide.

    Digest classes compared: per-axis signatures (retired when the axis's
    value-payload list changed), per-value payload digests (retired when the
    value's own payload no longer appears anywhere on the axis — growth and
    reordering keep surviving values' slice entries warm, since
    :func:`slice_key` hashes payloads, not positions), the ``__global__``
    tech digest, and the composite lattice signature."""
    old_config = _normalize_config(None, config)
    new_cfg = old_config if new_config is None else new_config
    old_sigs = axis_signatures(old_tech, old_config)
    new_sigs = axis_signatures(new_tech, new_cfg)
    stale = {d for a, d in old_sigs.items() if new_sigs.get(a) != d}
    if old_sigs != new_sigs:
        stale.add(_digest(old_sigs))           # the old lattice_signature
    old_payloads = axis_value_payloads(old_tech, old_config)
    new_payloads = axis_value_payloads(new_tech, new_cfg)
    for axis, values in old_payloads.items():
        new_values = new_payloads.get(axis, [])
        for payload in values:
            if payload not in new_values:
                stale.add(_digest(payload))
    return stale


def sweep_key(spec: MacroSpec, tech: TechModel,
              config: LatticeConfig | None = None,
              eps: float = PARETO_EPS) -> str:
    """The content address of one exhaustive-sweep frontier (no preference
    resolution — a sweep covers the whole lattice)."""
    return _digest({
        "kind": "sweep",
        "spec": spec_key(spec),
        "axes": axis_signatures(tech, config),
        "pareto_eps": float(eps),
    })


def slice_key(spec: MacroSpec, tech: TechModel, axis: str, value_index: int,
              config: LatticeConfig | None = None,
              eps: float = PARETO_EPS) -> str:
    """The content address of one per-axis-value slice frontier: the sweep
    of the sublattice where ``axis`` is pinned to its ``value_index``-th
    value.  Hashes the value's OWN payload digest plus every OTHER axis's
    digest and the global digest — deliberately not the rest of ``axis``'s
    values, which is what keeps unchanged slices warm across a scoped
    recalibration or an axis growth (module docstring)."""
    config = _normalize_config(None, config)
    payloads = axis_value_payloads(tech, config)
    if axis not in payloads:
        raise KeyError(f"axis {axis!r} is not sliceable under this config "
                       f"(have {sorted(payloads)})")
    values = payloads[axis]
    if not 0 <= value_index < len(values):
        raise IndexError(f"axis {axis!r} has {len(values)} values; "
                         f"got index {value_index}")
    sigs = axis_signatures(tech, config)
    return _digest({
        "kind": "slice",
        "spec": spec_key(spec),
        "axis": axis,
        "value": _digest(values[value_index]),
        "others": {a: s for a, s in sigs.items() if a != axis},
        "pareto_eps": float(eps),
    })
