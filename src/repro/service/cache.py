"""Content-addressed frontier cache: in-memory LRU + on-disk + shared tiers.

The cache maps :func:`repro.service.keys.cache_key` content addresses to
synthesized :class:`repro.core.searcher.SearchResult` frontiers.  Hits are
bit-identical to a fresh engine run by construction: the in-memory tier
returns the very object the engine produced, and the disk tiers round-trip
through the lossless JSON encoding of :mod:`repro.service.artifacts`.

Lookups probe three tiers, cheapest first:

  1. **memory** — the per-process LRU (``capacity`` entries);
  2. **local disk** — one ``<key>.json`` artifact per frontier under
     ``store_dir``, what makes a *second process on the same host* warm
     (``launch.serve --dcim-cache PATH``);
  3. **shared registry** — an optional
     :class:`repro.service.registry.ArtifactRegistry` on shared storage,
     what makes a *fleet* warm: any spec synthesized anywhere is a hit
     everywhere (``launch.serve --dcim-registry PATH``).

A hit in a lower tier is promoted into every tier above it (a registry hit
is re-persisted into the local store and inserted into the LRU), and a put
is written through every tier below the LRU.  A corrupted or foreign
artifact in either disk tier is rejected (:class:`CacheArtifactError`),
counted, **quarantined at rejection time** (renamed to ``<key>.corrupt`` so
it can never warm-start another process), and treated as a miss of that
tier — the lookup falls through to the next tier, so a shared registry can
heal a locally poisoned artifact; a bad byte on disk can never poison a
served frontier.
"""

from __future__ import annotations

import os
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path

from ..core.searcher import SearchResult
from ..obs import tracer
from ..obs.metrics import StatsView
from .artifacts import (ARTIFACT_SCHEMA, CacheArtifactError,  # noqa: F401
                        artifact_payload, atomic_write_json, load_artifact,
                        quarantine_artifact)
from .registry import ArtifactRegistry


class CacheStats(StatsView):
    """Per-tier accounting, backed by a metrics registry
    (:class:`repro.obs.metrics.StatsView` — same attributes and
    ``as_dict()`` key set as the historical dataclass).

    Invariant (property-tested): ``gets == hits + disk_hits + shared_hits
    + misses`` — every ``get()`` resolves in exactly one tier or is a
    miss; ``corrupt`` counts rejected artifacts on the side (a rejection
    is not a resolution).  ``hits`` are in-memory LRU hits, ``disk_hits``
    artifacts loaded (and promoted) from local disk, ``shared_hits``
    artifacts fetched from the shared registry.  Evictions split by
    durability: ``evictions`` are LRU entries that survive in a disk
    tier, ``evictions_lost`` left no copy anywhere."""

    _NAMESPACE = "cache"
    _FIELDS = ("gets", "hits", "disk_hits", "shared_hits", "misses",
               "puts", "evictions", "evictions_lost", "corrupt")


@dataclass
class FrontierCache:
    """LRU of synthesized frontiers, content-addressed, optionally
    persistent and optionally fleet-shared.

    ``capacity`` bounds the in-memory tier only; with a ``store_dir`` every
    put is also written through to local disk, and with a ``registry`` it is
    additionally published to the shared store (misses fall back through
    both, promoting on the way up)."""

    capacity: int = 256
    store_dir: str | os.PathLike | None = None
    registry: ArtifactRegistry | None = None
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self._lru: OrderedDict[str, SearchResult] = OrderedDict()
        if self.store_dir is not None:
            self.store_dir = Path(self.store_dir)
            self.store_dir.mkdir(parents=True, exist_ok=True)

    def __len__(self) -> int:
        return len(self._lru)

    # Deliberately no __contains__: the only truthful presence probe is
    # get(), which validates disk artifacts; a cheaper membership test would
    # report corrupted artifacts as present.

    # -- artifact layer ------------------------------------------------------

    def artifact_path(self, key: str) -> Path | None:
        return None if self.store_dir is None else self.store_dir / f"{key}.json"

    @staticmethod
    def load_artifact(path) -> tuple[str, SearchResult]:
        """Read and validate one artifact; returns ``(key, result)``.
        Raises :class:`CacheArtifactError` on any defect."""
        return load_artifact(path)

    def save_artifact(self, key: str, result: SearchResult) -> Path:
        """Write one local artifact via the unique-temp-then-atomic-rename
        discipline (:func:`repro.service.artifacts.atomic_write_json`):
        concurrent writers of the same key — even across hosts when
        ``store_dir`` is on shared storage — never clobber each other's
        partial writes, and readers only ever see complete artifacts."""
        return atomic_write_json(self.artifact_path(key),
                                 artifact_payload(key, result))

    def _load_local(self, key: str) -> SearchResult | None:
        """Tier 2: the validated local artifact, quarantining rejects."""
        path = self.artifact_path(key)
        if path is None or not path.exists():
            return None
        try:
            stored_key, result = load_artifact(path)
            if stored_key != key:
                raise CacheArtifactError(
                    f"{path}: content key mismatch "
                    f"(stored {stored_key[:12]}…, wanted {key[:12]}…)")
        except CacheArtifactError:
            self.stats.corrupt += 1
            quarantine_artifact(path)
            return None
        return result

    # -- cache protocol ------------------------------------------------------

    def get(self, key: str) -> SearchResult | None:
        """The cached frontier for ``key``, or None.  Probes memory, then
        local disk, then the shared registry; disk-tier artifacts are
        validated and a rejected artifact is quarantined on the spot, the
        lookup falling through to the next tier."""
        self.stats.gets += 1
        with tracer.span("cache.mem") as span:
            if key in self._lru:
                self._lru.move_to_end(key)
                self.stats.hits += 1
                span.set_tag("outcome", "hit")
                return self._lru[key]
            span.set_tag("outcome", "miss")
        if self.store_dir is not None:
            with tracer.span("cache.disk") as span:
                result = self._load_local(key)
                if result is not None:
                    self.stats.disk_hits += 1
                    self._insert(key, result)
                    span.set_tag("outcome", "hit")
                    return result
                span.set_tag("outcome", "miss")
        if self.registry is not None:
            with tracer.span("cache.registry") as span:
                result = self.registry.fetch(key)
                if result is not None:
                    self.stats.shared_hits += 1
                    if self.store_dir is not None:
                        self.save_artifact(key, result)  # promote to tier 2
                    self._insert(key, result)
                    span.set_tag("outcome", "hit")
                    return result
                span.set_tag("outcome", "miss")
        self.stats.misses += 1
        return None

    def put(self, key: str, result: SearchResult,
            scope: dict[str, str] | None = None) -> None:
        """Insert into every tier.  ``scope`` is the invalidation-scope
        digest map published alongside the shared-registry copy
        (:func:`repro.service.keys.key_scope`); ignored without a
        registry."""
        self.stats.puts += 1
        self._insert(key, result)
        if self.store_dir is not None:
            self.save_artifact(key, result)
        if self.registry is not None:
            self.registry.publish(key, result, scope=scope)

    def _insert(self, key: str, result: SearchResult) -> None:
        self._lru[key] = result
        self._lru.move_to_end(key)
        while len(self._lru) > self.capacity:
            evicted, _ = self._lru.popitem(last=False)
            path = self.artifact_path(evicted)
            durable = ((path is not None and path.exists())
                       or (self.registry is not None
                           and self.registry.has(evicted)))
            if durable:
                self.stats.evictions += 1
            else:
                self.stats.evictions_lost += 1
