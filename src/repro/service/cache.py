"""Content-addressed frontier cache: in-memory LRU + optional on-disk store.

The cache maps :func:`repro.service.keys.cache_key` content addresses to
synthesized :class:`repro.core.searcher.SearchResult` frontiers.  Hits are
bit-identical to a fresh engine run by construction: the in-memory tier
returns the very object the engine produced, and the on-disk tier round-trips
through the lossless JSON encoding of :mod:`repro.service.artifacts`.

The disk store (one ``<key>.json`` artifact per frontier under
``store_dir``) is what makes a *second process* warm: ``launch.serve
--dcim-cache PATH`` points the serving launcher's service at a persistent
directory, so the second launch of the same deployment config performs zero
engine executions.  A corrupted or foreign artifact is rejected
(:class:`CacheArtifactError`), counted, and treated as a miss — the engine
re-synthesizes and overwrites it; a bad byte on disk can never poison a
served frontier.
"""

from __future__ import annotations

import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path

from ..core.searcher import SearchResult
from .artifacts import ARTIFACT_SCHEMA, result_from_payload, result_to_payload


class CacheArtifactError(ValueError):
    """An on-disk artifact failed validation (bad JSON, wrong schema, key
    mismatch, or a payload the decoder rejects)."""


@dataclass
class CacheStats:
    hits: int = 0            # in-memory LRU hits
    disk_hits: int = 0       # artifacts loaded (and promoted) from disk
    misses: int = 0
    puts: int = 0
    evictions: int = 0       # LRU capacity evictions (disk copies survive)
    corrupt: int = 0         # artifacts rejected by validation

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in
                ("hits", "disk_hits", "misses", "puts", "evictions",
                 "corrupt")}


@dataclass
class FrontierCache:
    """LRU of synthesized frontiers, content-addressed, optionally persistent.

    ``capacity`` bounds the in-memory tier only; with a ``store_dir`` every
    put is also written through to disk, and an in-memory miss falls back to
    the artifact (promoting it back into the LRU)."""

    capacity: int = 256
    store_dir: str | os.PathLike | None = None
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self._lru: OrderedDict[str, SearchResult] = OrderedDict()
        if self.store_dir is not None:
            self.store_dir = Path(self.store_dir)
            self.store_dir.mkdir(parents=True, exist_ok=True)

    def __len__(self) -> int:
        return len(self._lru)

    # Deliberately no __contains__: the only truthful presence probe is
    # get(), which validates disk artifacts; a cheaper membership test would
    # report corrupted artifacts as present.

    # -- artifact layer ------------------------------------------------------

    def artifact_path(self, key: str) -> Path | None:
        return None if self.store_dir is None else self.store_dir / f"{key}.json"

    @staticmethod
    def load_artifact(path) -> tuple[str, SearchResult]:
        """Read and validate one artifact; returns ``(key, result)``.
        Raises :class:`CacheArtifactError` on any defect."""
        try:
            data = json.loads(Path(path).read_text())
        except (OSError, ValueError) as e:
            raise CacheArtifactError(f"{path}: unreadable artifact: {e}")
        if not isinstance(data, dict) or data.get("schema") != ARTIFACT_SCHEMA:
            raise CacheArtifactError(
                f"{path}: not a frontier artifact (schema="
                f"{data.get('schema') if isinstance(data, dict) else None!r}, "
                f"expected {ARTIFACT_SCHEMA!r})")
        key = data.get("key")
        if not isinstance(key, str) or not key:
            raise CacheArtifactError(f"{path}: missing content key")
        try:
            result = result_from_payload(data["result"])
        except (KeyError, TypeError, ValueError) as e:
            raise CacheArtifactError(f"{path}: undecodable payload: {e}")
        return key, result

    def save_artifact(self, key: str, result: SearchResult) -> Path:
        path = self.artifact_path(key)
        payload = {"schema": ARTIFACT_SCHEMA, "key": key,
                   "result": result_to_payload(result)}
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload))
        tmp.replace(path)           # atomic: readers never see partial writes
        return path

    # -- cache protocol ------------------------------------------------------

    def get(self, key: str) -> SearchResult | None:
        """The cached frontier for ``key``, or None.  Disk fallbacks are
        validated; a corrupted artifact counts as a miss (and is left for the
        next put to overwrite)."""
        if key in self._lru:
            self._lru.move_to_end(key)
            self.stats.hits += 1
            return self._lru[key]
        path = self.artifact_path(key)
        if path is not None and path.exists():
            try:
                stored_key, result = self.load_artifact(path)
                if stored_key != key:
                    raise CacheArtifactError(
                        f"{path}: content key mismatch "
                        f"(stored {stored_key[:12]}…, wanted {key[:12]}…)")
            except CacheArtifactError:
                self.stats.corrupt += 1
                self.stats.misses += 1
                return None
            self.stats.disk_hits += 1
            self._insert(key, result)
            return result
        self.stats.misses += 1
        return None

    def put(self, key: str, result: SearchResult) -> None:
        self.stats.puts += 1
        self._insert(key, result)
        if self.store_dir is not None:
            self.save_artifact(key, result)

    def _insert(self, key: str, result: SearchResult) -> None:
        self._lru[key] = result
        self._lru.move_to_end(key)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
            self.stats.evictions += 1
