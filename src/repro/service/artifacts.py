"""Lossless JSON encoding of synthesis results for the on-disk cache stores.

The on-disk tiers — the :class:`repro.service.cache.FrontierCache` local
store and the :class:`repro.service.registry.ArtifactRegistry` shared store —
persist one :class:`repro.core.searcher.SearchResult` per artifact.  The
encoding is bit-exact: every float field is written through Python's
shortest-round-trip float repr (IEEE-754 doubles survive a dump/load cycle
unchanged, including the ``inf`` TOPS/W of leakage-free corners), enums go
through their value strings, and tuples/dicts keep their order — so a
frontier loaded from disk satisfies the same bit-identity contract as an
in-memory hit (pinned by ``tests/test_service.py``).

Besides the codec this module owns the artifact *file discipline* both tiers
share: :func:`atomic_write_json` (unique temp name + fsync + atomic rename,
safe for concurrent writers of the same key on shared storage),
:func:`load_artifact` (read-and-validate, raising
:class:`CacheArtifactError` on any defect), and :func:`quarantine_artifact`
(rename a rejected artifact to ``<key>.corrupt`` at rejection time, so a
poisoned file can never warm-start another process).
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from ..core.csa import CSADesign, CSAReport
from ..core.macro import MacroDesign, MacroPPA, MacroSpec, PathReport
from ..core.searcher import SearchResult
from ..core.subcircuits import ApproxCellSpec, MemCellKind, MultMuxKind
from .keys import canonical_spec

#: Schema tag of one persisted frontier artifact.
ARTIFACT_SCHEMA = "syndcim-frontier-artifact/v1"


class CacheArtifactError(ValueError):
    """An on-disk artifact failed validation (bad JSON, wrong schema, key
    mismatch, or a payload the decoder rejects)."""


def atomic_write_json(path, payload: dict) -> Path:
    """Write ``payload`` as JSON at ``path`` atomically, safely for
    concurrent writers of the same path on shared storage.

    The temp name is unique per writer (pid + random token, same directory,
    so the final ``os.replace`` stays within one filesystem): two processes
    racing on one key each complete their own temp file and the rename is
    atomic, so readers see either a complete old artifact or a complete new
    one — never a partial write, never another writer's clobbered temp.  The
    file is fsynced before the rename so a crash cannot leave a renamed but
    empty artifact behind."""
    path = Path(path)
    tmp = path.with_name(f".{path.name}.{os.getpid()}."
                         f"{os.urandom(6).hex()}.tmp")
    data = json.dumps(payload)
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def artifact_payload(key: str, result: SearchResult) -> dict:
    """The persisted form of one frontier artifact."""
    return {"schema": ARTIFACT_SCHEMA, "key": key,
            "result": result_to_payload(result)}


def load_artifact(path) -> tuple[str, SearchResult]:
    """Read and validate one artifact; returns ``(key, result)``.
    Raises :class:`CacheArtifactError` on any defect."""
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, ValueError) as e:
        raise CacheArtifactError(f"{path}: unreadable artifact: {e}")
    if not isinstance(data, dict) or data.get("schema") != ARTIFACT_SCHEMA:
        raise CacheArtifactError(
            f"{path}: not a frontier artifact (schema="
            f"{data.get('schema') if isinstance(data, dict) else None!r}, "
            f"expected {ARTIFACT_SCHEMA!r})")
    key = data.get("key")
    if not isinstance(key, str) or not key:
        raise CacheArtifactError(f"{path}: missing content key")
    try:
        result = result_from_payload(data["result"])
    except (KeyError, TypeError, ValueError) as e:
        raise CacheArtifactError(f"{path}: undecodable payload: {e}")
    return key, result


def quarantine_artifact(path) -> Path | None:
    """Move a rejected artifact out of the serving path (``<key>.json`` →
    ``<key>.corrupt``), so it can never be re-read as a cache entry and the
    next put has a clean slot.  Racing quarantiners are benign: whoever
    renames first wins, the loser's rename fails on the missing source and
    is ignored.  Returns the quarantine path, or None if the artifact was
    already gone."""
    path = Path(path)
    dest = path.with_suffix(".corrupt")
    try:
        os.replace(path, dest)
    except OSError:
        return None
    return dest


def spec_from_payload(p: dict) -> MacroSpec:
    return MacroSpec(h=int(p["h"]), w=int(p["w"]), mcr=int(p["mcr"]),
                     int_precisions=tuple(int(b)
                                          for b in p["int_precisions"]),
                     fp_precisions=tuple(str(f) for f in p["fp_precisions"]),
                     f_mac_hz=float(p["f_mac_hz"]),
                     f_wupdate_hz=float(p["f_wupdate_hz"]),
                     vdd=float(p["vdd"]), w_power=float(p["w_power"]),
                     w_area=float(p["w_area"]),
                     w_throughput=float(p["w_throughput"]))


def _design_to_payload(d: MacroDesign) -> dict:
    return {
        "memcell": d.memcell.value,
        "multmux": d.multmux.value,
        "csa": {"rho": d.csa.rho, "reorder": d.csa.reorder,
                "retimed": d.csa.retimed, "split": d.csa.split},
        "ofu_pipe_stages": d.ofu_pipe_stages,
        "ofu_retimed_into_sa": d.ofu_retimed_into_sa,
        "fuse_tree_sa": d.fuse_tree_sa,
        "fuse_sa_ofu": d.fuse_sa_ofu,
        "audit": list(d.audit),
        # Optional-axis coordinates (absent for seed designs, so seed
        # artifacts keep their historical byte layout).
        **({"ofu_precisions": list(d.ofu_precisions)}
           if d.ofu_precisions is not None else {}),
        **({"align_fp": list(d.align_fp)}
           if d.align_fp is not None else {}),
        **({"approx_cell": {"name": d.approx_cell.name,
                            "k_delay": d.approx_cell.k_delay,
                            "k_energy": d.approx_cell.k_energy,
                            "k_area": d.approx_cell.k_area}}
           if d.approx_cell is not None else {}),
    }


def _design_from_payload(p: dict, spec: MacroSpec) -> MacroDesign:
    return MacroDesign(
        spec=spec, memcell=MemCellKind(p["memcell"]),
        multmux=MultMuxKind(p["multmux"]),
        csa=CSADesign(rho=float(p["csa"]["rho"]),
                      reorder=bool(p["csa"]["reorder"]),
                      retimed=bool(p["csa"]["retimed"]),
                      split=int(p["csa"]["split"])),
        ofu_pipe_stages=int(p["ofu_pipe_stages"]),
        ofu_retimed_into_sa=bool(p["ofu_retimed_into_sa"]),
        fuse_tree_sa=bool(p["fuse_tree_sa"]),
        fuse_sa_ofu=bool(p["fuse_sa_ofu"]),
        audit=tuple(p["audit"]),
        ofu_precisions=(tuple(int(b) for b in p["ofu_precisions"])
                        if "ofu_precisions" in p else None),
        align_fp=(tuple(str(f) for f in p["align_fp"])
                  if "align_fp" in p else None),
        approx_cell=(ApproxCellSpec(name=str(p["approx_cell"]["name"]),
                                    k_delay=float(p["approx_cell"]["k_delay"]),
                                    k_energy=float(
                                        p["approx_cell"]["k_energy"]),
                                    k_area=float(p["approx_cell"]["k_area"]))
                     if "approx_cell" in p else None))


_CSA_REPORT_FIELDS = ("crit_path_rel", "energy_rel", "area_um2", "n_fa",
                      "n_comp42", "n_ha", "n_reg_bits", "stages",
                      "latency_cycles", "acc_width", "rca_width")


def _ppa_to_payload(p: MacroPPA) -> dict:
    return {
        "design": _design_to_payload(p.design),
        "paths": {"mac_path_rel": p.paths.mac_path_rel,
                  "sa_path_rel": p.paths.sa_path_rel,
                  "ofu_path_rel": p.paths.ofu_path_rel,
                  "crit_rel": p.paths.crit_rel},
        "fmax_hz": p.fmax_hz,
        "area_um2": p.area_um2,
        "area_breakdown": dict(p.area_breakdown),
        "e_cycle_fj": dict(p.e_cycle_fj),
        "latency_cycles": int(p.latency_cycles),
        "tops_1b": p.tops_1b,
        "tops_per_w_1b": dict(p.tops_per_w_1b),
        "tops_per_mm2_1b": p.tops_per_mm2_1b,
        "meets_timing": bool(p.meets_timing),
        "csa_report": (None if p.csa_report is None else
                       {k: getattr(p.csa_report, k)
                        for k in _CSA_REPORT_FIELDS}),
    }


def _ppa_from_payload(p: dict, spec: MacroSpec) -> MacroPPA:
    csa_rep = p.get("csa_report")
    return MacroPPA(
        design=_design_from_payload(p["design"], spec),
        paths=PathReport(float(p["paths"]["mac_path_rel"]),
                         float(p["paths"]["sa_path_rel"]),
                         float(p["paths"]["ofu_path_rel"]),
                         float(p["paths"]["crit_rel"])),
        fmax_hz=float(p["fmax_hz"]), area_um2=float(p["area_um2"]),
        area_breakdown={k: float(v)
                        for k, v in p["area_breakdown"].items()},
        e_cycle_fj={k: float(v) for k, v in p["e_cycle_fj"].items()},
        latency_cycles=int(p["latency_cycles"]),
        tops_1b=float(p["tops_1b"]),
        tops_per_w_1b={k: float(v) for k, v in p["tops_per_w_1b"].items()},
        tops_per_mm2_1b=float(p["tops_per_mm2_1b"]),
        meets_timing=bool(p["meets_timing"]),
        csa_report=(None if csa_rep is None else
                    CSAReport(**{k: csa_rep[k]
                                 for k in _CSA_REPORT_FIELDS})))


def result_to_payload(r: SearchResult) -> dict:
    """Plain-data encoding of one SearchResult (JSON-serializable)."""
    return {
        "spec": canonical_spec(r.spec),
        "frontier": [_ppa_to_payload(p) for p in r.frontier],
        "explored": [_ppa_to_payload(p) for p in r.explored],
        "n_evaluated": int(r.n_evaluated),
    }


def result_from_payload(d: dict) -> SearchResult:
    """Inverse of :func:`result_to_payload`, bit-exact per field."""
    spec = spec_from_payload(d["spec"])
    return SearchResult(
        spec=spec,
        frontier=tuple(_ppa_from_payload(p, spec) for p in d["frontier"]),
        explored=tuple(_ppa_from_payload(p, spec) for p in d["explored"]),
        n_evaluated=int(d["n_evaluated"]))
