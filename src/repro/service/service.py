"""Online synthesis service: request coalescing over the execution engine.

`SynthesisService` is the serving front of the compiler: callers submit
single-spec synthesis requests and the service answers them from three tiers,
cheapest first —

  1. **cache** — the content-addressed :class:`repro.service.cache.
     FrontierCache` (in-memory LRU, optionally disk-persistent), hit when any
     earlier request synthesized the same ``(spec, tech, lattice, resolution,
     eps)`` address;
  2. **coalescing** — duplicate requests inside one batch collapse onto a
     single miss (they fan back out after the pass, every duplicate served
     the same result object);
  3. **one fused engine pass** — all remaining unique misses go through
     ``engine.plan`` (which micro-batches them into vmap groups by
     ``engine.group_key``) and ONE ``engine.execute`` call under the
     capability-probed strategy registry (vmap for small batches;
     sharded-jit / pmap / multihost across devices and hosts once the batch
     clears the sharding payoff threshold).

So N singleton requests cost one fused pass, not N — and a repeated request
costs zero engine executions (observable through
:func:`repro.core.engine.add_execute_hook`).  Results are bit-identical to
fresh unbatched engine runs in every tier: the engine's strategies are
bit-identical to each other by the differential oracle harness, in-memory
hits return the engine's own objects, and disk hits round-trip through the
lossless artifact encoding.

    from repro.service import SynthesisService
    svc = SynthesisService()
    results = svc.synthesize_many(specs)        # one fused pass
    again = svc.synthesize(specs[0])            # zero engine executions
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax

from ..core import batched as B
from ..core import engine as E
from ..core import subcircuits as sc
from ..core.macro import MacroSpec, calibrated_tech_for_reference
from ..core.searcher import SearchResult
from ..core.tech import TechModel
from .cache import FrontierCache
from .keys import cache_key

#: Request-side execution modes: "auto" picks vmap for small fused batches
#: and the capability-probed sharded pick once a batch is big enough to pay
#: for device placement; "sharded" forces the sharded auto pick; the public
#: sharded names select one strategy with the engine's fallback semantics
#: ("multihost" degrades to the single-host path when unavailable).
SERVICE_MODES = ("auto", "vmap", "sharded", "jit", "pmap", "multihost")

#: "auto" shards a fused miss batch only when it stacks at least this many
#: spec lanes per visible device — below that, padding the batch up to the
#: device count plus placement overhead beats the dispatch it saves (the
#: same payoff-point reasoning as ``pareto.SHARDED_EXTRACT_MIN_POINTS``).
SHARD_MIN_SPECS_PER_DEVICE = 2


def resolve_service_mode(mode: str = "auto",
                         n_specs: int | None = None) -> str:
    """Public service mode -> engine strategy name, by the same capability
    probes the sharded sweeps use (:func:`repro.core.engine.
    resolve_sharded_mode`).  ``n_specs`` (the fused batch size) lets "auto"
    apply the sharding payoff threshold; without it "auto" stays on the
    single-device vmap strategy."""
    if mode not in SERVICE_MODES:
        raise ValueError(f"unknown service mode: {mode!r}; "
                         f"pick from {SERVICE_MODES}")
    if mode == "auto":
        n_dev = len(jax.devices())
        big = (n_specs is not None
               and n_specs >= SHARD_MIN_SPECS_PER_DEVICE * n_dev)
        mode = "sharded" if (n_dev > 1 and big) else "vmap"
    if mode == "vmap":
        return "vmap"
    if mode == "sharded":
        mode = "auto"
    return E._SHARDED_STRATEGY[E.resolve_sharded_mode(mode)]


@dataclass
class ServiceStats:
    requests: int = 0
    cache_hits: int = 0      # answered from the FrontierCache (mem or disk)
    coalesced: int = 0       # duplicates folded onto an in-batch miss
    misses: int = 0          # unique specs that reached the engine
    fused_passes: int = 0    # engine.execute calls this service made

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in
                ("requests", "cache_hits", "coalesced", "misses",
                 "fused_passes")}


@dataclass
class SynthesisService:
    """The online synthesis front over the shared execution engine.

    ``tech``/``resolution``/``memcells`` are per-service defaults; both can
    be overridden per call, and the cache address always reflects the values
    a request actually ran under, so one service instance safely serves
    mixed tech models and resolutions.  ``mode`` picks the execution
    strategy for fused miss passes (see :data:`SERVICE_MODES`)."""

    tech: TechModel | None = None
    resolution: int = 4
    memcells: tuple[sc.MemCellKind, ...] = (sc.MemCellKind.SRAM_6T,)
    mode: str = "auto"
    cache: FrontierCache = field(default_factory=FrontierCache)
    stats: ServiceStats = field(default_factory=ServiceStats)

    def __post_init__(self):
        if self.tech is None:
            self.tech = calibrated_tech_for_reference()
        resolve_service_mode(self.mode)      # validate eagerly
        self.memcells = tuple(self.memcells)

    # -- keys ----------------------------------------------------------------

    def request_key(self, spec: MacroSpec, tech: TechModel | None = None,
                    resolution: int | None = None) -> str:
        """The content address a request is cached under."""
        return cache_key(spec, tech or self.tech, self.memcells,
                         self.resolution if resolution is None
                         else resolution)

    # -- the service protocol ------------------------------------------------

    def synthesize(self, spec: MacroSpec, tech: TechModel | None = None,
                   resolution: int | None = None) -> SearchResult:
        """Serve one single-spec request (the N=1 batch)."""
        return self.synthesize_many([spec], tech=tech,
                                    resolution=resolution)[0]

    def synthesize_many(self, specs: Sequence[MacroSpec],
                        tech: TechModel | None = None,
                        resolution: int | None = None) -> list[SearchResult]:
        """Serve a batch of single-spec requests: dedup against the cache
        and each other, one fused engine pass for the misses, fan results
        back out in request order.  Per-request results are bit-identical to
        a fresh ``mso_search_many([spec])`` run."""
        tech = tech or self.tech
        resolution = self.resolution if resolution is None else resolution
        keys = [self.request_key(s, tech, resolution) for s in specs]
        out: list[SearchResult | None] = [None] * len(specs)

        miss_specs: list[MacroSpec] = []
        miss_keys: list[str] = []
        in_batch: set[str] = set()
        for i, (s, k) in enumerate(zip(specs, keys)):
            self.stats.requests += 1
            hit = self.cache.get(k)
            if hit is not None:
                self.stats.cache_hits += 1
                out[i] = hit
                continue
            if k in in_batch:
                self.stats.coalesced += 1
                continue                     # fans out from the fused pass
            in_batch.add(k)
            miss_specs.append(s)
            miss_keys.append(k)

        fresh: dict[str, SearchResult] = {}
        if miss_specs:
            self.stats.misses += len(miss_specs)
            for k, r in zip(miss_keys, self._fused_pass(miss_specs, tech,
                                                        resolution)):
                fresh[k] = r
                self.cache.put(k, r)
        for i, k in enumerate(keys):
            if out[i] is None:
                out[i] = fresh[k]
        return out

    # -- the fused miss pass -------------------------------------------------

    def _fused_pass(self, specs: Sequence[MacroSpec], tech: TechModel,
                    resolution: int) -> list[SearchResult]:
        """All misses through one ``engine.execute`` call: ``engine.plan``
        micro-batches them into vmap groups by ``engine.group_key``, the
        placed strategy runs each group fused, and Algorithm 1 is replayed
        per spec against the evaluated lattices (exactly the
        ``mso_search_many`` contract, under whichever strategy the service
        resolved)."""
        plan = E.plan(list(specs), tech, self.memcells,
                      mode=resolve_service_mode(self.mode, len(specs)))
        evals = E.execute(plan)
        self.stats.fused_passes += 1
        return [B._alg1_replay(lat, tab, T, resolution)
                for lat, tab, T in evals]


_DEFAULT_SERVICE: SynthesisService | None = None


def get_service() -> SynthesisService:
    """The process-wide default service — what `serve.select.select_macros`
    memoizes through, so repeated selections in one process share warm
    frontiers."""
    global _DEFAULT_SERVICE
    if _DEFAULT_SERVICE is None:
        _DEFAULT_SERVICE = SynthesisService()
    return _DEFAULT_SERVICE


def reset_service() -> None:
    """Drop the process-wide default service (tests / tech recalibration)."""
    global _DEFAULT_SERVICE
    _DEFAULT_SERVICE = None
