"""Online synthesis service: request coalescing over the execution engine.

`SynthesisService` is the serving front of the compiler: callers submit
typed :class:`~repro.service.requests.SynthesisRequest`\\ s and the service
answers them from three tiers, cheapest first —

  1. **cache** — the content-addressed :class:`repro.service.cache.
     FrontierCache` (in-memory LRU, optionally disk-persistent), hit when any
     earlier request synthesized the same ``(spec, tech, lattice, resolution,
     eps)`` address;
  2. **coalescing** — duplicate requests inside one batch collapse onto a
     single miss (they fan back out after the pass, every duplicate served
     the same result object);
  3. **one fused engine pass** — all remaining unique misses go through
     ``engine.plan_for`` (which micro-batches them into vmap groups by
     ``engine.group_key``) and ONE ``engine.execute`` call per execution
     mode under the capability-probed strategy registry (vmap for small
     batches; sharded-jit / pmap / multihost across devices and hosts once
     the batch clears the sharding payoff threshold).

So N singleton requests cost one fused pass, not N — and a repeated request
costs zero engine executions (observable through
:func:`repro.core.engine.add_execute_hook`).  Results are bit-identical to
fresh unbatched engine runs in every tier: the engine's strategies are
bit-identical to each other by the differential oracle harness, in-memory
hits return the engine's own objects, and disk hits round-trip through the
lossless artifact encoding.

    from repro.service import SynthesisRequest, SynthesisService
    svc = SynthesisService()
    responses = svc.serve([SynthesisRequest(spec=s) for s in specs])
    again = svc.serve([SynthesisRequest(spec=specs[0])])   # zero executions

The kwarg-tuple entry points of earlier PRs — ``synthesize(spec, tech=,
resolution=)``, ``synthesize_many(...)``, ``request_key(...)`` — remain as
thin deprecation shims that construct requests internally and return bare
``SearchResult``\\ s, bit-identical to the typed path.  The *async* front
(admission queue, priority classes, backpressure, streaming) lives one
layer up in :mod:`repro.service.frontend`; this module stays synchronous
and thread-compatible (callers serialize on the frontend's scheduler).
"""

from __future__ import annotations

import contextlib
import threading
import warnings
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from ..core import batched as B
from ..core import engine as E
from ..core import subcircuits as sc
from ..core.axes import SLICEABLE_AXES, LatticeConfig, seed_config
from ..core.macro import MacroSpec, calibrated_tech_for_reference
from ..core.pareto import merged_pareto_indices, nondominated_mask_auto
from ..core.searcher import SearchResult
from ..core.tech import TechModel
from ..obs import install_engine_hooks, tracer
from ..obs.metrics import StatsView
from .cache import FrontierCache
from .keys import cache_key, key_scope, slice_key, sweep_key
from .requests import SynthesisRequest, SynthesisResponse, as_requests

#: Reusable no-op context for the untraced fast path (a shared
#: ``nullcontext`` instance is re-enterable and allocation-free).
_NULL_CTX = contextlib.nullcontext()

#: Request-side execution modes: "auto" picks vmap for small fused batches
#: and the capability-probed sharded pick once a batch is big enough to pay
#: for device placement; "sharded" forces the sharded auto pick; the public
#: sharded names select one strategy with the engine's fallback semantics
#: ("multihost" degrades to the single-host path when unavailable).
SERVICE_MODES = ("auto", "vmap", "sharded", "jit", "pmap", "multihost")

#: "auto" shards a fused miss batch only when it stacks at least this many
#: spec lanes per visible device — below that, padding the batch up to the
#: device count plus placement overhead beats the dispatch it saves (the
#: same payoff-point reasoning as ``pareto.SHARDED_EXTRACT_MIN_POINTS``).
SHARD_MIN_SPECS_PER_DEVICE = 2


def resolve_service_mode(mode: str = "auto",
                         n_specs: int | None = None) -> str:
    """Public service mode -> engine strategy name, by the same capability
    probes the sharded sweeps use (:func:`repro.core.engine.
    resolve_sharded_mode`).  ``n_specs`` (the fused batch size) lets "auto"
    apply the sharding payoff threshold; without it "auto" stays on the
    single-device vmap strategy."""
    if mode not in SERVICE_MODES:
        raise ValueError(f"unknown service mode: {mode!r}; "
                         f"pick from {SERVICE_MODES}")
    if mode == "auto":
        n_dev = len(jax.devices())
        big = (n_specs is not None
               and n_specs >= SHARD_MIN_SPECS_PER_DEVICE * n_dev)
        mode = "sharded" if (n_dev > 1 and big) else "vmap"
    if mode == "vmap":
        return "vmap"
    if mode == "sharded":
        mode = "auto"
    return E._SHARDED_STRATEGY[E.resolve_sharded_mode(mode)]


class ServiceStats(StatsView):
    """Per-service request counters, backed by a metrics registry
    (:class:`repro.obs.metrics.StatsView` — same attributes and
    ``as_dict()`` key set as the historical dataclass).

    - ``cache_hits``: answered from the FrontierCache (any tier)
    - ``coalesced``: duplicates folded onto an in-batch miss
    - ``misses``: unique specs that reached the engine
    - ``fused_passes``: engine.execute calls this service made
    - ``slice_hits``: per-axis slice frontiers reused by sweeps
    - ``incremental_passes``: sweeps answered by slice merge, not re-roll
    - claim counters (the shared-registry protocol; zero without a
      registry): ``claims_acquired`` misses this service claimed and
      synthesized, ``claim_waits`` misses another host was already
      synthesizing, ``claim_hits`` of those served by that host's publish,
      ``claim_timeouts`` of those synthesized here after the wait.
    """

    _NAMESPACE = "service"
    _FIELDS = ("requests", "cache_hits", "coalesced", "misses",
               "fused_passes", "slice_hits", "incremental_passes",
               "claims_acquired", "claim_waits", "claim_hits",
               "claim_timeouts")


def _deprecated(old: str) -> None:
    warnings.warn(
        f"SynthesisService.{old} with kwarg tuples is deprecated; build "
        "SynthesisRequest objects and call serve() (see README migration "
        "table)", DeprecationWarning, stacklevel=3)


@dataclass
class SynthesisService:
    """The online synthesis front over the shared execution engine.

    ``tech``/``resolution``/``memcells`` are per-service defaults; each can
    be overridden per request, and the cache address always reflects the
    values a request actually ran under, so one service instance safely
    serves mixed tech models and resolutions — even inside one fused pass
    (operands are packed per spec lane with that request's own tech).
    ``mode`` picks the execution strategy for fused miss passes (see
    :data:`SERVICE_MODES`); a request's ``mode`` overrides it per request.

    With a registry-backed cache the service speaks the fleet claim
    protocol: before synthesizing a registry miss it tries to claim the key
    (:meth:`repro.service.registry.ArtifactRegistry.claim`); on a lost claim
    it waits up to ``claim_wait_s`` seconds for the claiming host's publish
    (served as a cache hit) before synthesizing anyway — a claim is an
    optimization, never a correctness gate.
    """

    tech: TechModel | None = None
    resolution: int = 4
    memcells: tuple[sc.MemCellKind, ...] = (sc.MemCellKind.SRAM_6T,)
    mode: str = "auto"
    config: LatticeConfig | None = None
    cache: FrontierCache = field(default_factory=FrontierCache)
    stats: ServiceStats = field(default_factory=ServiceStats)
    claim_wait_s: float = 30.0

    def __post_init__(self):
        if self.tech is None:
            self.tech = calibrated_tech_for_reference()
        resolve_service_mode(self.mode)      # validate eagerly
        self.memcells = tuple(self.memcells)
        install_engine_hooks()               # idempotent observation hooks

    # -- effective per-request parameters -----------------------------------

    def _effective(self, req: SynthesisRequest
                   ) -> tuple[TechModel, int, str, LatticeConfig]:
        tech = req.tech if req.tech is not None else self.tech
        resolution = (self.resolution if req.resolution is None
                      else int(req.resolution))
        mode = req.mode if req.mode is not None else self.mode
        if req.config is not None:
            config = req.config
        elif self.config is not None:
            config = self.config
        else:
            config = seed_config(self.memcells)
        return tech, resolution, mode, config

    # -- keys ----------------------------------------------------------------

    def key_for(self, request: SynthesisRequest) -> str:
        """The content address a typed request is cached under: the search
        address for ``kind="search"``, the exhaustive-sweep address for
        ``kind="sweep"`` (see :mod:`repro.service.keys`)."""
        tech, resolution, _, config = self._effective(request)
        if request.kind == "sweep":
            return sweep_key(request.spec, tech, config)
        return cache_key(request.spec, tech, resolution=resolution,
                         config=config)

    def request_key(self, spec: MacroSpec, tech: TechModel | None = None,
                    resolution: int | None = None) -> str:
        """Deprecated kwarg-tuple shim for :meth:`key_for`."""
        _deprecated("request_key(spec, tech=, resolution=)")
        return self.key_for(SynthesisRequest(spec=spec, tech=tech,
                                             resolution=resolution))

    # -- the typed service protocol ------------------------------------------

    def serve(self, requests: Sequence[SynthesisRequest],
              on_partial: Optional[Callable[[int, SearchResult], None]]
              = None, contexts: Sequence | None = None
              ) -> list[SynthesisResponse]:
        """Serve a batch of typed requests: dedup against the cache and each
        other, one fused engine pass per execution mode for the misses, fan
        results back out in request order.  Per-request results are
        bit-identical to a fresh ``mso_search_many([spec])`` run.

        ``on_partial(index, result)`` streams each request's finished
        ``SearchResult`` the moment it exists — cache hits immediately,
        fused-pass lanes as each spec's Algorithm-1 replay completes — so a
        long sweep's frontier-so-far is observable before the batch returns.

        ``contexts`` (parallel to ``requests``) carries each request's
        :class:`repro.obs.SpanContext` across the thread boundary — the
        frontend passes its tickets' trace roots so per-tier cache spans and
        the engine-pass link attach to the right trace.  ``None`` entries
        (or no list at all) simply record no request-scoped spans.
        """
        reqs = list(requests)
        for r in reqs:
            if not isinstance(r, SynthesisRequest):
                raise TypeError("serve() takes SynthesisRequest objects; "
                                "use the synthesize_many shim for bare "
                                f"specs (got {type(r).__name__})")
        if contexts is not None and len(contexts) != len(reqs):
            raise ValueError(f"contexts must parallel requests: "
                             f"{len(contexts)} != {len(reqs)}")
        ctxs = list(contexts) if contexts is not None else [None] * len(reqs)
        eff = [self._effective(r) for r in reqs]
        keys = [self.key_for(r) for r in reqs]
        out: list[SynthesisResponse | None] = [None] * len(reqs)

        first_for_key: dict[str, int] = {}
        dups_of: dict[int, list[int]] = {}
        miss_by_mode: dict[str, list[int]] = {}
        sweep_misses: list[int] = []
        claims: dict[str, object] = {}       # key -> held RegistryClaim
        for i, (r, k) in enumerate(zip(reqs, keys)):
            self.stats.requests += 1
            with (tracer.activate(ctxs[i]) if ctxs[i] is not None
                  else _NULL_CTX):
                hit = self.cache.get(k)
                if hit is None and first_for_key.get(k) is None:
                    hit, claim = self._claim_or_wait(k)
                    if claim is not None:
                        claims[k] = claim
            if hit is not None:
                self.stats.cache_hits += 1
                out[i] = SynthesisResponse(request=r, result=hit,
                                           served_from="cache")
                if on_partial is not None:
                    on_partial(i, hit)
                continue
            j = first_for_key.get(k)
            if j is not None:
                self.stats.coalesced += 1
                dups_of.setdefault(j, []).append(i)
                continue                     # fans out from the fused pass
            first_for_key[k] = i
            if r.kind == "sweep":
                sweep_misses.append(i)
            else:
                miss_by_mode.setdefault(eff[i][2], []).append(i)

        def link_engine_span(i: int, pass_ref: dict | None,
                             coalesced: bool) -> None:
            """A per-request child span covering the shared fused pass —
            tagged with the pass's own trace/span ids so N coalesced
            requests all point at the ONE ``engine.pass`` timeline."""
            if not pass_ref or ctxs[i] is None:
                return
            span = tracer.start("request.engine", parent=ctxs[i],
                                start_s=pass_ref["start_s"],
                                tags={"engine_pass": pass_ref["span_id"],
                                      "engine_trace": pass_ref["trace_id"],
                                      "coalesced": coalesced})
            if span:
                span.finish()

        def finish(i: int, res: SearchResult,
                   pass_ref: dict | None = None) -> None:
            tech_i, _res_i, _mode_i, config_i = eff[i]
            self.cache.put(keys[i], res,
                           scope=key_scope(tech_i, config_i))
            claim = claims.pop(keys[i], None)
            if claim is not None:
                claim.release()
            out[i] = SynthesisResponse(request=reqs[i], result=res,
                                       served_from="engine")
            link_engine_span(i, pass_ref, coalesced=False)
            if on_partial is not None:
                on_partial(i, res)
            for d in dups_of.get(i, ()):
                out[d] = SynthesisResponse(request=reqs[d], result=res,
                                           served_from="coalesced")
                link_engine_span(d, pass_ref, coalesced=True)
                if on_partial is not None:
                    on_partial(d, res)

        for mode, members in miss_by_mode.items():
            self.stats.misses += len(members)
            pass_ref: dict = {}
            self._fused_pass([reqs[i] for i in members],
                             [eff[i] for i in members], mode,
                             lambda slot, res, _m=members, _p=pass_ref:
                                 finish(_m[slot], res, _p),
                             pass_ref=pass_ref)

        for i in sweep_misses:
            self.stats.misses += 1
            tech, _res, _mode, config = eff[i]
            with (tracer.activate(ctxs[i]) if ctxs[i] is not None
                  else _NULL_CTX):
                with tracer.span("service.sweep",
                                 tags={"kind": "sweep"}):
                    res = self._serve_sweep(reqs[i].spec, tech, config)
            finish(i, res)
        return out

    # -- the fleet claim protocol --------------------------------------------

    def _claim_or_wait(self, key: str):
        """One registry miss through the claim protocol.  Returns ``(hit,
        claim)``: a served result if another host's claimed synthesis
        published while we waited, else a held claim if this service won the
        key (released by ``finish`` after the put), else ``(None, None)`` —
        wait timed out or no registry, synthesize unsynchronized (safe:
        content addressing + atomic rename make duplicate writers
        harmless)."""
        registry = self.cache.registry
        if registry is None:
            return None, None
        with tracer.span("cache.claim") as span:
            claim = registry.claim(key)
            if claim is not None:
                self.stats.claims_acquired += 1
                span.set_tag("outcome", "acquired")
                return None, claim
            self.stats.claim_waits += 1
            if registry.wait(key, timeout_s=self.claim_wait_s):
                hit = self.cache.get(key)    # validated fetch + promotion
                if hit is not None:
                    self.stats.claim_hits += 1
                    span.set_tag("outcome", "claim-wait-hit")
                    return hit, None
            self.stats.claim_timeouts += 1
            span.set_tag("outcome", "claim-wait-timeout")
            return None, None

    def telemetry(self) -> dict:
        """Fleet-facing stats rollup: this service's request counters, its
        cache's per-tier counters, and — when fleet-shared — the registry
        handle's hit/miss/fill/claim counters plus store size.  What
        ``launch.serve`` and ``scripts/warm_cache.py`` print."""
        out = {"service": self.stats.as_dict(),
               "cache": self.cache.stats.as_dict()}
        if self.cache.registry is not None:
            out["registry"] = self.cache.registry.telemetry()
        return out

    # -- deprecated kwarg-tuple shims ----------------------------------------

    def synthesize(self, spec: MacroSpec, tech: TechModel | None = None,
                   resolution: int | None = None) -> SearchResult:
        """Deprecated shim: one single-spec request (the N=1 batch)."""
        _deprecated("synthesize(spec, tech=, resolution=)")
        return self.serve(as_requests([spec], tech=tech,
                                      resolution=resolution))[0].result

    def synthesize_many(self, specs: Sequence[MacroSpec],
                        tech: TechModel | None = None,
                        resolution: int | None = None) -> list[SearchResult]:
        """Deprecated shim: bare specs in, bare ``SearchResult``\\ s out —
        constructs typed requests internally; bit-identical to
        :meth:`serve`."""
        _deprecated("synthesize_many(specs, tech=, resolution=)")
        return [r.result for r in
                self.serve(as_requests(specs, tech=tech,
                                       resolution=resolution))]

    # -- the fused miss pass -------------------------------------------------

    def _fused_pass(self, requests: Sequence[SynthesisRequest],
                    eff: Sequence[tuple[TechModel, int, str, LatticeConfig]],
                    mode: str,
                    on_result: Callable[[int, SearchResult], None],
                    pass_ref: dict | None = None) -> None:
        """All same-mode misses through one ``engine.execute`` call:
        ``engine.plan_for`` micro-batches them into vmap groups by
        ``engine.group_key`` (operands packed with each request's own tech,
        so mixed-tech batches still fuse), the placed strategy runs each
        group fused, and Algorithm 1 is replayed per spec at that request's
        resolution (exactly the ``mso_search_many`` contract, under
        whichever strategy the service resolved).  ``on_result(slot,
        result)`` fires as each spec lane finishes — the streaming hook.

        When tracing is on, the pass runs under an ``engine.pass`` trace of
        its own (a fused pass is shared by N requests, so it cannot live
        inside any single request's trace) with ``engine.plan`` /
        ``engine.place`` / ``engine.execute`` / per-lane ``engine.extract``
        phase children; ``pass_ref`` (when given) is filled with the pass
        span's ids so the caller can cross-link each request's trace to it.
        """
        with tracer.start_trace("engine.pass",
                                tags={"mode": mode,
                                      "n_requests": len(requests)}) as root:
            if pass_ref is not None and root:
                pass_ref.update(trace_id=root.trace_id,
                                span_id=root.span_id,
                                start_s=root.span.start_s)
            with tracer.span("engine.plan"):
                lattices = [B.DesignLattice.enumerate(r.spec, config=cfg)
                            for r, (_, _, _, cfg) in zip(requests, eff)]
                tables = [B.SpecTables(r.spec, tech, config=cfg)
                          for r, (tech, _, _, cfg) in zip(requests, eff)]
            with tracer.span("engine.place") as pspan:
                placement = E.place(resolve_service_mode(mode,
                                                         len(requests)))
                pspan.set_tag("mode", placement.mode)
                pspan.set_tag("n_dev", placement.n_dev)
            plan = E.plan_for(lattices, tables, placement=placement)
            evals = E.execute(plan)          # engine.execute span: obs hooks
            self.stats.fused_passes += 1
            for slot, (lat, tab, T) in enumerate(evals):
                with tracer.span("engine.extract", tags={"slot": slot}):
                    res = B._alg1_replay(lat, tab, T, eff[slot][1])
                on_result(slot, res)

    # -- exhaustive sweeps: slice caching + incremental re-synthesis ---------

    def _serve_sweep(self, spec: MacroSpec, tech: TechModel,
                     config: LatticeConfig) -> SearchResult:
        """One exhaustive-sweep miss.

        Probes the per-axis-value *slice* caches first: if some sliceable
        axis has cached frontiers for a subset of its values (the shape left
        behind by a scoped tech recalibration or a single-axis growth — see
        :mod:`repro.service.keys`), only the sublattice of the missing
        values is evaluated and its slice frontiers are merged with the
        cached ones (:func:`repro.core.pareto.merged_pareto_indices`), never
        re-rolling the full axis product.  A fully cold sweep evaluates the
        whole lattice once and leaves slice records behind for every
        sliceable axis, so the *next* single-axis change is incremental."""
        lattice = B.DesignLattice.enumerate(spec, config=config)
        best: tuple[str, dict[int, SearchResult], list[str]] | None = None
        for axis in SLICEABLE_AXES:
            ax = lattice.axis(axis)
            if ax is None:
                continue
            skeys = [slice_key(spec, tech, axis, v, config=config)
                     for v in range(ax.size)]
            cached = {}
            for v, sk in enumerate(skeys):
                rec = self.cache.get(sk)
                if rec is not None:
                    cached[v] = rec
            if cached and (best is None or len(cached) > len(best[1])):
                best = (axis, cached, skeys)

        if best is None:
            return self._cold_sweep(spec, tech, config, lattice)

        axis, cached, skeys = best
        self.stats.incremental_passes += 1
        self.stats.slice_hits += len(cached)
        missing = [v for v in range(lattice.axis(axis).size)
                   if v not in cached]
        fresh: dict[int, SearchResult] = {}
        if missing:
            sub, _parent = lattice.sublattice(axis, tuple(missing))
            subtab = B.SpecTables(spec, tech, axes=sub.axes)
            sweep = B.BatchedSweep(lattice=sub, tables=subtab,
                                   ppa=B.evaluate(sub, subtab))
            local = sub.coord(axis)
            for li, v in enumerate(missing):
                rec = _slice_record(sweep, local == li)
                fresh[v] = rec
                self.cache.put(skeys[v], rec,
                               scope=key_scope(tech, config, axis=axis,
                                               value_index=v))
        records = [cached[v] if v in cached else fresh[v]
                   for v in range(lattice.axis(axis).size)]
        return _merge_slice_results(lattice, records)

    def _cold_sweep(self, spec: MacroSpec, tech: TechModel,
                    config: LatticeConfig,
                    lattice: B.DesignLattice) -> SearchResult:
        sweep = B.design_space_sweep(spec, tech, config=config)
        for axis in SLICEABLE_AXES:
            ax = sweep.lattice.axis(axis)
            if ax is None:
                continue
            coord = sweep.lattice.coord(axis)
            for v in range(ax.size):
                self.cache.put(slice_key(spec, tech, axis, v, config=config),
                               _slice_record(sweep, coord == v),
                               scope=key_scope(tech, config, axis=axis,
                                               value_index=v))
        return _sweep_result(sweep)


# -- sweep-result helpers (shared by the cold and incremental paths) --------


def _sweep_objectives(points) -> list[tuple[float, float, float]]:
    """The searcher's objective tuple (energy/cycle INT-lo, area, period)
    recomputed from materialized points — the same float64 values the
    batched sweep's objective matrix holds, so merged extraction compares
    exactly what a full-lattice extraction would."""
    return [(p.e_cycle_fj["int_lo"], p.area_um2, 1.0 / p.fmax_hz)
            for p in points]


def _extract_sweep_indices(sweep: B.BatchedSweep,
                           cand: np.ndarray) -> list[int]:
    """Frontier flat indices over an explicit candidate set (no feasibility
    fallback — the slice records need the raw feasible/valid split)."""
    if cand.size == 0:
        return []
    objs = sweep.objectives()[cand]
    return [int(cand[j])
            for j in E.extract_frontier(objs, nondominated_mask_auto)]


def _slice_record(sweep: B.BatchedSweep, sel: np.ndarray) -> SearchResult:
    """The cacheable frontier record of one axis-value slice of a sweep.

    Encoded as a :class:`SearchResult` so it rides the existing artifact
    codec: ``frontier`` is the slice's *feasible* frontier (empty when no
    point meets timing — deliberately no fallback, so "any feasible point in
    the full lattice" is recoverable as "any slice frontier non-empty"),
    ``explored`` is the slice's frontier over all valid points regardless of
    feasibility (the fallback pool), and ``n_evaluated`` is the slice's
    valid-point count (slices partition the lattice along one axis, so these
    sum to the full count)."""
    valid = sweep.lattice.valid & sel
    feas = valid & sweep.ppa.meets
    f_idx = _extract_sweep_indices(sweep, np.flatnonzero(feas))
    e_idx = _extract_sweep_indices(sweep, np.flatnonzero(valid))
    return SearchResult(
        spec=sweep.lattice.spec,
        frontier=tuple(sweep.materialize(i) for i in f_idx),
        explored=tuple(sweep.materialize(i) for i in e_idx),
        n_evaluated=int(valid.sum()))


def _sweep_result(sweep: B.BatchedSweep) -> SearchResult:
    """The full-sweep :class:`SearchResult`: ``frontier`` under the public
    sweep semantics (feasible, falling back to all valid points when nothing
    meets timing), ``explored`` the feasibility-blind frontier, and
    ``n_evaluated`` the valid-point count."""
    f_idx = sweep.frontier_indices()
    e_idx = _extract_sweep_indices(sweep, np.flatnonzero(sweep.lattice.valid))
    return SearchResult(
        spec=sweep.lattice.spec,
        frontier=tuple(sweep.materialize(i) for i in f_idx),
        explored=tuple(sweep.materialize(i) for i in e_idx),
        n_evaluated=int(sweep.lattice.valid.sum()))


def _merge_slice_results(lattice: B.DesignLattice,
                         records: Sequence[SearchResult]) -> SearchResult:
    """Merge one record per axis value into the full-sweep result.

    Soundness: a point on the full-lattice frontier is on its own slice's
    frontier (dominance over a subset is implied by dominance over the set),
    so the union of slice frontiers is a superset of the true frontier and
    one pooled extraction recovers it.  Candidates are re-anchored at their
    parent flat index (:meth:`repro.core.batched.DesignLattice.
    index_of_design`) so duplicate collapse picks the same representatives a
    cold full pass would (:func:`repro.core.pareto.merged_pareto_indices`).
    The feasibility fallback composes across slices because slice records
    keep the feasible/valid split explicit (see :func:`_slice_record`)."""
    any_feasible = any(len(r.frontier) for r in records)

    def pool(points_lists) -> tuple:
        pts = [p for ps in points_lists for p in ps]
        parent = [lattice.index_of_design(p.design) for p in pts]
        keep = merged_pareto_indices(parent, _sweep_objectives(pts))
        return tuple(pts[i] for i in keep)

    return SearchResult(
        spec=lattice.spec,
        frontier=pool([r.frontier if any_feasible else r.explored
                       for r in records]),
        explored=pool([r.explored for r in records]),
        n_evaluated=sum(int(r.n_evaluated) for r in records))


_DEFAULT_SERVICE: SynthesisService | None = None
#: Guards the process-wide singleton: the async front makes `get_service`
#: reachable from scheduler threads concurrently with the main thread, and
#: an unlocked check-then-create could hand two callers two different
#: services (split caches, double synthesis).
_SERVICE_LOCK = threading.Lock()


def get_service() -> SynthesisService:
    """The process-wide default service — what `serve.select.select_macros`
    memoizes through, so repeated selections in one process share warm
    frontiers.  Thread-safe: concurrent callers always observe the same
    instance."""
    global _DEFAULT_SERVICE
    with _SERVICE_LOCK:
        if _DEFAULT_SERVICE is None:
            _DEFAULT_SERVICE = SynthesisService()
        return _DEFAULT_SERVICE


def reset_service() -> None:
    """Drop the process-wide default service (tests / tech recalibration)."""
    global _DEFAULT_SERVICE
    with _SERVICE_LOCK:
        _DEFAULT_SERVICE = None
