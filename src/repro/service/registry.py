"""Shared artifact registry: the cross-process / cross-host cache tier.

The :class:`repro.service.cache.FrontierCache` makes one *process* warm; this
module makes a *fleet* warm.  :class:`ArtifactRegistry` is a
content-addressed store on shared storage (NFS mount, shared volume, one
host's exported directory) that any number of serving hosts read and write
concurrently, layered under the per-process cache as its third tier:

    memory LRU  →  local disk store  →  shared registry

Concurrent writers are safe **by construction**, not by locking:

  * content addressing — two hosts that synthesize the same
    :func:`repro.service.keys.cache_key` produce bit-identical payloads
    (pinned by the differential suites), so whichever write lands last
    changes nothing;
  * unique-temp-then-atomic-rename (:func:`repro.service.artifacts.
    atomic_write_json`) — readers see complete artifacts or nothing, never a
    partial write, even while N writers race on one key.

On top of that safety floor, *claim files* make the fleet cheap: before
synthesizing a registry miss, a host tries to :meth:`~ArtifactRegistry.claim`
the key — an ``O_CREAT | O_EXCL`` create of ``claims/<key>.claim``, which
exactly one host wins.  The winner synthesizes and publishes; the others
:meth:`~ArtifactRegistry.wait` for the artifact to appear (or time out and
synthesize anyway — a claim is an optimization, never a correctness gate, so
a crashed claim holder can only cost duplicated work; stale claims past
``claim_ttl_s`` are broken outright).

Every artifact carries a sidecar scope record (``objects/<key>.meta.json``):
the named content digests the entry depends on — per-axis signatures, the
per-value digest of its own slice, the ``__global__`` tech digest, the full
``lattice_signature`` (see :func:`repro.service.keys.key_scope`).  A tech
recalibration then evicts *exactly* the stale entries fleet-wide:
:func:`repro.service.keys.stale_digests` names the digests the change
retired, and :meth:`~ArtifactRegistry.invalidate_digests` drops every entry
that references one, leaving every other key warm (slice records of
untouched axis values survive a scoped recalibration — the PR-7 semantics,
now fleet-wide).

Layout under ``root``::

    objects/<key>.json        the frontier artifact (shared codec)
    objects/<key>.meta.json   scope digests for scoped invalidation
    claims/<key>.claim        CAS claim file (owner host/pid/time)
"""

from __future__ import annotations

import json
import os
import socket
import time
from dataclasses import dataclass, field
from pathlib import Path

from ..core.searcher import SearchResult
from ..obs.metrics import StatsView
from .artifacts import (CacheArtifactError, artifact_payload,
                        atomic_write_json, load_artifact,
                        quarantine_artifact)

#: Schema tag of one artifact's sidecar scope record.
META_SCHEMA = "syndcim-registry-meta/v1"

#: Schema tag of one claim file's owner record.
CLAIM_SCHEMA = "syndcim-registry-claim/v1"

#: Default age past which a claim is considered abandoned (the holder
#: crashed or was partitioned) and may be broken by another host.  Generous:
#: a full exhaustive sweep finishes well inside this on one host.
CLAIM_TTL_S = 600.0


class RegistryStats(StatsView):
    """Fleet-facing telemetry of one registry handle (per process),
    backed by a metrics registry (:class:`repro.obs.metrics.StatsView` —
    same attributes and ``as_dict()`` key set as the historical
    dataclass).

    - ``hits``: artifacts fetched (validated) from the store
    - ``misses``: ``fetch()`` found no artifact
    - ``fills``: artifacts this process published
    - ``fill_noops``: publishes skipped, artifact already present
    - ``corrupt``: artifacts rejected (and quarantined)
    - ``claims_acquired`` / ``claims_lost`` / ``claims_broken`` /
      ``claims_released``: the claim-file protocol from this process's
      point of view
    - ``evictions``: entries dropped by scoped invalidation
    """

    _NAMESPACE = "registry"
    _FIELDS = ("hits", "misses", "fills", "fill_noops", "corrupt",
               "claims_acquired", "claims_lost", "claims_broken",
               "claims_released", "evictions")


class RegistryClaim:
    """One held claim on a registry key.  Release it once the artifact is
    published (or the attempt is abandoned); also a context manager."""

    def __init__(self, registry: "ArtifactRegistry", key: str, path: Path):
        self._registry = registry
        self.key = key
        self.path = path
        self.released = False

    def release(self) -> None:
        if self.released:
            return
        self.released = True
        self._registry.stats.claims_released += 1
        try:
            os.unlink(self.path)
        except OSError:
            pass            # broken by another host after our TTL expired

    def __enter__(self) -> "RegistryClaim":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclass
class ArtifactRegistry:
    """A shared frontier-artifact store under one root directory.

    Every method is safe to call concurrently from any number of processes
    and hosts sharing ``root``.  ``claim_ttl_s`` bounds how long a missing
    claim holder can block the fleet's claim optimization (never its
    correctness)."""

    root: str | os.PathLike
    claim_ttl_s: float = CLAIM_TTL_S
    stats: RegistryStats = field(default_factory=RegistryStats)

    def __post_init__(self):
        self.root = Path(self.root)
        self._objects = self.root / "objects"
        self._claims = self.root / "claims"
        self._objects.mkdir(parents=True, exist_ok=True)
        self._claims.mkdir(parents=True, exist_ok=True)

    # -- paths ---------------------------------------------------------------

    def object_path(self, key: str) -> Path:
        return self._objects / f"{key}.json"

    def meta_path(self, key: str) -> Path:
        return self._objects / f"{key}.meta.json"

    def claim_path(self, key: str) -> Path:
        return self._claims / f"{key}.claim"

    # -- the artifact protocol ----------------------------------------------

    def has(self, key: str) -> bool:
        """Cheap existence probe (no validation, no stats) — the poll the
        claim-wait loop spins on.  Serving always goes through
        :meth:`fetch`, which validates."""
        return self.object_path(key).exists()

    def fetch(self, key: str) -> SearchResult | None:
        """The validated artifact for ``key``, or None.  A corrupted or
        mis-keyed artifact is quarantined at rejection time (it can never be
        served, and the slot is clean for the next publish) and counts as a
        miss."""
        path = self.object_path(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        try:
            stored_key, result = load_artifact(path)
            if stored_key != key:
                raise CacheArtifactError(
                    f"{path}: content key mismatch "
                    f"(stored {stored_key[:12]}…, wanted {key[:12]}…)")
        except CacheArtifactError:
            self.stats.corrupt += 1
            self.stats.misses += 1
            quarantine_artifact(path)
            return None
        self.stats.hits += 1
        return result

    def publish(self, key: str, result: SearchResult,
                scope: dict[str, str] | None = None) -> Path:
        """Write one artifact (and its scope record) into the shared store.

        Publishing an already-present key is a no-op for the object file
        (content addressing: the bytes would be identical; skipping saves
        shared-filesystem traffic when a claim loser synthesized anyway) —
        the scope record is still written if missing."""
        path = self.object_path(key)
        if path.exists():
            self.stats.fill_noops += 1
        else:
            atomic_write_json(path, artifact_payload(key, result))
            self.stats.fills += 1
        meta = self.meta_path(key)
        if scope is not None and not meta.exists():
            atomic_write_json(meta, {"schema": META_SCHEMA, "key": key,
                                     "scope": dict(scope)})
        return path

    # -- generic JSON payloads (non-frontier artifacts) -----------------------

    def publish_payload(self, key: str, payload: dict, *, schema: str,
                        scope: dict[str, str] | None = None) -> Path:
        """Publish an arbitrary JSON payload under ``key`` with an explicit
        ``schema`` tag — the registry's store/claim/invalidation machinery
        for artifacts that are not frontier :class:`SearchResult`\\ s (e.g.
        the kernel autotuner's tile winners).  Same no-op-if-present and
        scope-record semantics as :meth:`publish`."""
        path = self.object_path(key)
        if path.exists():
            self.stats.fill_noops += 1
        else:
            atomic_write_json(path, {"schema": schema, "key": key,
                                     "payload": payload})
            self.stats.fills += 1
        meta = self.meta_path(key)
        if scope is not None and not meta.exists():
            atomic_write_json(meta, {"schema": META_SCHEMA, "key": key,
                                     "scope": dict(scope)})
        return path

    def fetch_payload(self, key: str, *, schema: str) -> dict | None:
        """The validated payload stored under ``key``, or None.  An artifact
        with the wrong schema tag, a mismatched key, or unparseable bytes is
        quarantined (same policy as :meth:`fetch`) and counts as a miss."""
        path = self.object_path(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        try:
            data = json.loads(path.read_text())
        except (OSError, ValueError):
            data = None
        if (not isinstance(data, dict) or data.get("schema") != schema
                or data.get("key") != key
                or not isinstance(data.get("payload"), dict)):
            self.stats.corrupt += 1
            self.stats.misses += 1
            quarantine_artifact(path)
            return None
        self.stats.hits += 1
        return data["payload"]

    # -- the claim protocol --------------------------------------------------

    def claim(self, key: str) -> RegistryClaim | None:
        """Try to become the one host that synthesizes ``key``.

        Returns a held :class:`RegistryClaim` if this process won the
        ``O_CREAT | O_EXCL`` race (release it after publishing), or None if
        another holder owns a live claim.  A stale claim (older than
        ``claim_ttl_s``) is broken and the attempt retried once."""
        path = self.claim_path(key)
        for attempt in (0, 1):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                if attempt == 0 and self._break_stale_claim(path):
                    continue
                self.stats.claims_lost += 1
                return None
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": CLAIM_SCHEMA, "key": key,
                           "host": socket.gethostname(),
                           "pid": os.getpid(), "time": time.time()}, f)
                f.flush()
                os.fsync(f.fileno())
            self.stats.claims_acquired += 1
            return RegistryClaim(self, key, path)
        return None

    def _break_stale_claim(self, path: Path) -> bool:
        """Remove a claim whose holder has been gone past the TTL.  Age is
        judged by the claim file's mtime (wall-clock inside the file is
        advisory only — hosts' clocks need not agree)."""
        try:
            age = time.time() - path.stat().st_mtime
        except OSError:
            return True          # holder released it between our two looks
        if age < self.claim_ttl_s:
            return False
        try:
            os.unlink(path)
        except OSError:
            pass                 # another waiter broke it first — still free
        self.stats.claims_broken += 1
        return True

    def wait(self, key: str, timeout_s: float,
             poll_s: float = 0.02) -> bool:
        """Wait for another host's claimed synthesis of ``key`` to publish.
        True as soon as the artifact exists; False on timeout (the caller
        then synthesizes itself — duplicated work, never a wrong answer)."""
        deadline = time.monotonic() + timeout_s
        while True:
            if self.has(key):
                return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(min(poll_s, max(0.0,
                                       deadline - time.monotonic())))

    # -- enumeration + scoped invalidation ------------------------------------

    def keys(self) -> list[str]:
        """Every key with a (non-quarantined) artifact in the store."""
        return sorted(p.name[:-len(".json")] for p in
                      self._objects.glob("*.json")
                      if not p.name.endswith(".meta.json"))

    def scope_of(self, key: str) -> dict[str, str] | None:
        """The stored scope-digest record of one entry (None if the entry
        was published without one — such entries only leave by
        :meth:`invalidate_key`)."""
        meta = self.meta_path(key)
        try:
            data = json.loads(meta.read_text())
        except (OSError, ValueError):
            return None
        if (not isinstance(data, dict)
                or data.get("schema") != META_SCHEMA):
            return None
        scope = data.get("scope")
        return dict(scope) if isinstance(scope, dict) else None

    def invalidate_key(self, key: str) -> bool:
        """Drop one entry (artifact + scope record) fleet-wide."""
        removed = False
        for path in (self.object_path(key), self.meta_path(key)):
            try:
                os.unlink(path)
                removed = True
            except OSError:
                pass
        if removed:
            self.stats.evictions += 1
        return removed

    def invalidate_digests(self, stale: set[str]) -> list[str]:
        """Scoped eviction: drop every entry whose scope record references
        any digest in ``stale`` (the set :func:`repro.service.keys.
        stale_digests` computes for a recalibration).  Entries none of whose
        digests were retired stay warm — a scoped tech recalibration
        invalidates exactly the affected axis-value's entries, fleet-wide.
        Returns the evicted keys."""
        stale = set(stale)
        evicted = []
        for key in self.keys():
            scope = self.scope_of(key)
            if scope is not None and stale & set(scope.values()):
                if self.invalidate_key(key):
                    evicted.append(key)
        return evicted

    def telemetry(self) -> dict:
        """This handle's stats plus the store-wide entry count."""
        out = self.stats.as_dict()
        out["entries"] = len(self.keys())
        return out
