"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.asarray(peak * (final_frac + (1 - final_frac) * cos),
                           jnp.float32)
    return f


def linear_warmup_cosine(peak: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(peak, max(1, total_steps - warmup), final_frac)

    def f(step):
        warm = peak * jnp.minimum(1.0, step / max(1, warmup))
        return jnp.where(step < warmup, warm, cos(step - warmup)) \
            .astype(jnp.float32)
    return f
