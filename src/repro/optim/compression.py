"""Gradient compression for cross-pod data parallelism.

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links; the
standard mitigation is int8 quantization with error feedback (EF-SGD lineage):

    q = int8(round((g + e) / s)),  s = max|g + e| / 127
    e' = (g + e) - s * q                      # residual kept locally
    all-reduce in int32 over the pod axis, dequantize, proceed with AdamW.

``shard_map``-based: the train step runs the compressed all-reduce explicitly
over the 'pod' mesh axis (the within-pod reduction stays dense/implicit).
8x fewer bytes on the pod links at <0.1% accuracy cost in practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_error f32)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q_sum: jnp.ndarray, scale_sum: jnp.ndarray,
                    n: int) -> jnp.ndarray:
    """Inverse of a summed compressed all-reduce: the scales are averaged and
    applied to the int32 sum (per-replica scales are close after clipping)."""
    return q_sum.astype(jnp.float32) * (scale_sum / n)


def ef_state_init(params):
    """Error-feedback residual buffers (f32, zero-initialized)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, err_state, axis_name: str):
    """Inside shard_map: all-reduce ``grads`` over ``axis_name`` in int8 with
    error feedback.  Returns (mean_grads, new_err_state).

    Two rounds: (1) agree on a global scale (a single-scalar max-reduce per
    tensor — negligible traffic), (2) int8-quantize against it, sum in int32,
    dequantize exactly.  A per-replica-scale variant would save round 1 but
    introduces scale-mismatch error (~127·Δs) that error feedback cannot see;
    measured 3.1e-3 vs 1.4e-4 max error on N(0, 0.01) gradients."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        s_global = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name) / 127.0
        s_global = jnp.maximum(s_global, 1e-12)
        q = jnp.clip(jnp.round(gf / s_global), -127, 127).astype(jnp.int8)
        e2 = gf - q.astype(jnp.float32) * s_global
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return q_sum.astype(jnp.float32) * s_global / n, e2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def compressed_allreduce_spec() -> str:
    return ("int8 + error feedback over the 'pod' axis; dense implicit "
            "reduce within pods")
