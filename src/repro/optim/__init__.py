from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedules import constant_lr, cosine_schedule, linear_warmup_cosine
from .compression import (compress_int8, decompress_int8,
                          compressed_allreduce_spec, ef_state_init)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "constant_lr", "cosine_schedule", "linear_warmup_cosine",
           "compress_int8", "decompress_int8", "compressed_allreduce_spec",
           "ef_state_init"]
