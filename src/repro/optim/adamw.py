"""AdamW, hand-rolled (no optax in this environment).

Moments are f32 regardless of param dtype (bf16 params + f32 m/v is the
standard large-scale recipe; see DESIGN.md §6 memory budget).  Under the
FSDP sharding rules the moment trees inherit the param PartitionSpecs, so
optimizer state is ZeRO-sharded automatically.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt_state, params, lr: jnp.ndarray,
                 cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / (1 - cfg.b1 ** count)
        vhat = v2 / (1 - cfg.b2 ** count)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/embedded scalars exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_specs_like(param_specs):
    """PartitionSpecs for the optimizer state mirroring the param specs."""
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "count": P()}
