from .select import MacroSelection, apply_profile, select_macros
from .step import make_decode_step, make_prefill, greedy_generate

__all__ = ["MacroSelection", "apply_profile", "select_macros",
           "make_decode_step", "make_prefill", "greedy_generate"]
