from .select import MacroSelection, select_macros
from .step import make_decode_step, make_prefill, greedy_generate

__all__ = ["MacroSelection", "select_macros",
           "make_decode_step", "make_prefill", "greedy_generate"]
