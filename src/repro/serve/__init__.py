from .step import make_decode_step, make_prefill, greedy_generate

__all__ = ["make_decode_step", "make_prefill", "greedy_generate"]
