from .config import (SERVE_CONFIG_SCHEMA, ServeConfig, load_serve_config,
                     parse_pref, save_serve_config, serve_config_from_args)
from .select import MacroSelection, apply_profile, select_macros
from .step import make_decode_step, make_prefill, greedy_generate

__all__ = ["MacroSelection", "SERVE_CONFIG_SCHEMA", "ServeConfig",
           "apply_profile", "load_serve_config", "parse_pref",
           "save_serve_config", "select_macros", "serve_config_from_args",
           "make_decode_step", "make_prefill", "greedy_generate"]
