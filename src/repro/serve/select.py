"""Serving-time DCIM macro selection over the multi-spec synthesized frontier.

The compiler side synthesizes N scenario specs in one fused pass
(:func:`repro.core.multispec.mso_search_many`); the serving side must then
answer "which synthesized macro runs *this* deployed workload best?".  This
module is that bridge: it pools the per-spec Pareto frontiers, batch-maps
every deployed workload's GEMM inventory onto every candidate
(:func:`repro.core.dse.cross_workload_codesign` — which applies the same
timing-clamp as the scalar reports), and assigns each workload the
lowest-wallclock design.

    from repro.configs import get_config
    from repro.core.dse import gemm_inventory
    from repro.serve.select import select_macros

    sel = select_macros({"qwen3-4b": gemm_inventory(get_config("qwen3-4b"))})
    sel.assignment["qwen3-4b"]        # -> label of the chosen macro
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from ..core.dse import CodesignReport, GemmShape, cross_workload_codesign
from ..core.macro import MacroSpec, calibrated_tech_for_reference
from ..core.multispec import frontier_union, mso_search_many, scenario_specs
from ..core.tech import TechModel


@dataclass(frozen=True)
class MacroSelection:
    """Result of serving-time selection: one macro per deployed workload."""

    workloads: tuple[str, ...]
    scenarios: tuple[str, ...]           # synthesized spec names
    pool_labels: tuple[str, ...]         # "<scenario>/<design name>" per pool entry
    pool: tuple                          # candidate MacroPPAs (frontier union)
    assignment: dict                     # workload name -> pool index
    codesign: CodesignReport

    def label_for(self, workload: str) -> str:
        return self.pool_labels[self.assignment[workload]]

    def ppa_for(self, workload: str):
        return self.pool[self.assignment[workload]]

    def summary(self) -> dict:
        return {
            "scenarios": list(self.scenarios),
            "candidates": len(self.pool),
            "codesign_frontier": len(self.codesign.frontier),
            "assignment": {w: self.label_for(w) for w in self.workloads},
        }


def select_macros(workloads: Mapping[str, Sequence[GemmShape]],
                  specs: Mapping[str, MacroSpec] | None = None,
                  tech: TechModel | None = None, resolution: int = 4,
                  n_macros: int = 256, ib: int = 8,
                  wb: int = 8) -> MacroSelection:
    """Synthesize the multi-spec frontier and pick a macro per workload.

    ``workloads`` maps deployed-workload names to GEMM inventories (see
    :func:`repro.core.dse.gemm_inventory` for the model zoo); ``specs``
    defaults to the §I scenario set.  Selection is lowest total wallclock on
    the cross-workload co-design matrix, so a timing-missing candidate is
    judged at its down-clocked reporting frequency exactly as the scalar
    accelerator reports would."""
    if not workloads:
        raise ValueError("need at least one deployed workload")
    if tech is None:
        tech = calibrated_tech_for_reference()
    if specs is None:
        specs = scenario_specs()
    names = tuple(specs)
    results = mso_search_many([specs[n] for n in names], None, tech,
                              resolution)
    pool, labels = frontier_union(results, names)
    report = cross_workload_codesign(workloads, pool, n_macros=n_macros,
                                     ib=ib, wb=wb)
    assignment = {w: report.best_for(w) for w in report.workloads}
    return MacroSelection(workloads=report.workloads, scenarios=names,
                          pool_labels=tuple(labels), pool=tuple(pool),
                          assignment=assignment, codesign=report)
