"""Serving-time DCIM macro selection over the multi-spec synthesized frontier.

The compiler side synthesizes N scenario specs in one fused pass
(:func:`repro.core.multispec.mso_search_many`); the serving side must then
answer "which synthesized macro runs *this* deployed workload best?".  This
module is that bridge: it pools the per-spec Pareto frontiers, batch-maps
every deployed workload's GEMM inventory onto every candidate
(:func:`repro.core.dse.cross_workload_codesign` — which applies the same
timing-clamp as the scalar reports), and assigns each workload a macro.

Selection is preference-aware: a ``preference`` weight vector over
(wallclock, energy, area) scalarizes the candidates *restricted to the
pooled per-workload Pareto frontier* (the shared
:data:`repro.core.pareto.PARETO_EPS` dominance band — an eps-dominated
candidate is never selected).  Without a preference the legacy behaviour is
kept: lowest wallclock over all candidates.  Each workload's selected macro
PPA is then fed back into the serving roofline
(:func:`repro.roofline.dcim.dcim_serving_bound`), so the selection reports
roofline-bounded tokens/s, not just macro wallclock.

    from repro.configs import get_config
    from repro.core.dse import gemm_inventory
    from repro.serve.select import select_macros

    sel = select_macros({"qwen3-4b": gemm_inventory(get_config("qwen3-4b"))},
                        preference=(0.2, 0.6, 0.2))     # energy-leaning
    sel.assignment["qwen3-4b"]        # -> pool index of the chosen macro
    sel.serving["qwen3-4b"].tokens_per_s

Preference weights persist per deployment config as a small JSON artifact
(:class:`PreferenceProfile`, :func:`load_preference_profile` /
:func:`save_preference_profile`), wired into the serving launcher as
``repro.launch.serve --dcim-profile PATH`` — the read-then-update round trip
is one shared helper, :func:`apply_profile`, used by the CLI and service
paths alike.

Frontier synthesis is memoized through the online synthesis service
(:mod:`repro.service`): ``select_macros`` routes the multi-spec pass through
a :class:`repro.service.SynthesisService` (the process-wide default unless
one is passed), so a second selection against the same scenario set performs
zero engine executions and a ``--dcim-cache`` directory makes the second
*launch* warm too.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Mapping, Sequence

import numpy as np

from ..core.axes import LatticeConfig
from ..core.dse import CodesignReport, GemmShape, cross_workload_codesign
from ..core.macro import MacroSpec, calibrated_tech_for_reference
from ..core.multispec import frontier_union, scenario_specs
from ..core.pareto import nondominated_mask_auto, scalarize
from ..core.tech import TechModel
from ..roofline.dcim import DcimServingEstimate, dcim_serving_bound

#: Objective order of a selection preference vector.
PREFERENCE_OBJECTIVES = ("wallclock", "energy", "area")

#: Schema tag of the persisted preference-profile artifact.
PROFILE_SCHEMA = "syndcim-preference-profile/v1"


def _check_weights(weights, where: str) -> tuple[float, float, float]:
    w = tuple(float(x) for x in weights)
    if len(w) != len(PREFERENCE_OBJECTIVES):
        raise ValueError(f"{where}: need {len(PREFERENCE_OBJECTIVES)} "
                         f"weights {PREFERENCE_OBJECTIVES}, got {len(w)}")
    if any(x < 0 or not np.isfinite(x) for x in w):
        raise ValueError(f"{where}: preference weights must be finite "
                         f"and >= 0, got {w}")
    return w


@dataclass(frozen=True)
class PreferenceProfile:
    """Persisted per-deployment-config preference weights.

    Maps workload names to (wallclock, energy, area) weight vectors — the
    artifact a deployment config carries so serving-time selection keeps
    applying the same PPA posture across restarts.  ``None`` weights mean
    the legacy pure-wallclock selection (explicitly recorded, so a profile
    distinguishes "never configured" from "configured as wallclock-only").
    ``default`` applies to workloads the profile does not name."""

    workloads: Mapping[str, tuple[float, float, float] | None] = field(
        default_factory=dict)
    default: tuple[float, float, float] | None = None

    def weights_for(self, workload: str
                    ) -> tuple[float, float, float] | None:
        if workload in self.workloads:
            return self.workloads[workload]
        return self.default

    def with_workload(self, workload: str,
                      weights: Sequence[float] | None) -> "PreferenceProfile":
        """A copy recording ``weights`` (or explicit wallclock-only ``None``)
        for ``workload`` — the write half of the round trip."""
        merged = dict(self.workloads)
        merged[workload] = (None if weights is None
                            else _check_weights(weights, workload))
        return PreferenceProfile(workloads=merged, default=self.default)


def load_preference_profile(path) -> PreferenceProfile:
    """Read a profile artifact; a missing file is an empty profile (so the
    first serve run of a fresh deployment config can seed it)."""
    p = Path(path)
    if not p.exists():
        return PreferenceProfile()
    data = json.loads(p.read_text())
    if data.get("schema") != PROFILE_SCHEMA:
        raise ValueError(f"{p}: not a preference profile "
                         f"(schema={data.get('schema')!r}, "
                         f"expected {PROFILE_SCHEMA!r})")
    workloads = {
        name: None if w is None else _check_weights(w, f"{p}:{name}")
        for name, w in (data.get("workloads") or {}).items()}
    default = data.get("default")
    if default is not None:
        default = _check_weights(default, f"{p}:default")
    return PreferenceProfile(workloads=workloads, default=default)


def save_preference_profile(path, profile: PreferenceProfile) -> None:
    """Write a profile artifact (deterministic layout: sorted workloads)."""
    payload = {
        "schema": PROFILE_SCHEMA,
        "default": (None if profile.default is None
                    else list(profile.default)),
        "workloads": {
            name: (None if w is None else list(w))
            for name, w in sorted(profile.workloads.items())},
    }
    Path(path).write_text(json.dumps(payload, indent=2, sort_keys=True)
                          + "\n")


def apply_profile(path, select: Callable[[PreferenceProfile | None],
                                         "MacroSelection"]
                  ) -> tuple["MacroSelection", PreferenceProfile | None]:
    """The one read-then-update implementation of the ``--dcim-profile``
    round trip, shared by the CLI launcher and the service path.

    ``select`` is called with the profile loaded from ``path`` (or ``None``
    when ``path`` is ``None`` — selection then runs unprofiled and nothing
    is written).  Afterwards the artifact is re-written with the weights
    each workload was *actually* selected under
    (``MacroSelection.preferences_applied``), so the persisted posture
    converges to what the deployment serves with.  Returns
    ``(selection, updated profile or None)``."""
    if path is None:
        return select(None), None
    profile = load_preference_profile(path)
    sel = select(profile)
    updated = profile
    for w, weights in sorted(sel.preferences_applied.items()):
        updated = updated.with_workload(w, weights)
    save_preference_profile(path, updated)
    return sel, updated


def preference_select(objs, weights) -> int:
    """Index of the preferred candidate in an (n, 3) objective matrix
    (minimization; columns ordered as :data:`PREFERENCE_OBJECTIVES`).

    Semantics, pinned by ``tests/test_preference_selection.py``:

      * candidates are first restricted to the pooled Pareto frontier under
        the shared :data:`repro.core.pareto.PARETO_EPS` band — an
        eps-dominated candidate is never selected;
      * the survivors are scalarized with :func:`repro.core.pareto.scalarize`
        against per-objective frontier minima, so weights are scale-free
        (rescaling all weights by c > 0 cannot change the winner);
      * a degenerate all-zero weight vector falls back to pure wallclock;
      * ties break deterministically on (score, objective tuple, index).
    """
    objs = np.asarray(objs, dtype=np.float64)
    if objs.ndim != 2 or objs.shape[0] == 0:
        raise ValueError("need a non-empty (n, k) objective matrix")
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (objs.shape[1],):
        raise ValueError(f"need {objs.shape[1]} preference weights "
                         f"{PREFERENCE_OBJECTIVES}, got {w.shape}")
    if (w < 0).any() or not np.isfinite(w).all():
        raise ValueError("preference weights must be finite and >= 0")
    if not (w > 0).any():
        w = np.zeros_like(w)
        w[0] = 1.0                       # degenerate -> wallclock
    # The pooled-frontier restriction; at lattice-scale pools the mask runs
    # device-sharded (same bits as the host pass, see pareto module docs).
    cand = np.flatnonzero(nondominated_mask_auto(objs))
    refs = [max(float(objs[cand, j].min()), 1e-30)
            for j in range(objs.shape[1])]
    scored = sorted((scalarize(w, objs[i], refs), tuple(objs[i]), int(i))
                    for i in cand)
    return scored[0][2]


def preferred_macro(report: CodesignReport, workload: str,
                    preference: Sequence[float]) -> int:
    """Preference-weighted pick for one workload over the co-design matrix:
    objectives are (wallclock on this workload, energy on this workload,
    macro-array area)."""
    wi = report.workloads.index(workload)
    objs = np.stack([report.wallclock_s[wi], report.energy_pj[wi],
                     report.area_mm2], axis=1)
    return preference_select(objs, preference)


@dataclass(frozen=True)
class MacroSelection:
    """Result of serving-time selection: one macro per deployed workload."""

    workloads: tuple[str, ...]
    scenarios: tuple[str, ...]           # synthesized spec names
    pool_labels: tuple[str, ...]         # "<scenario>/<design name>" per pool entry
    pool: tuple                          # candidate MacroPPAs (frontier union)
    assignment: dict                     # workload name -> pool index
    codesign: CodesignReport
    preference: tuple[float, ...] | None = None
    serving: dict = field(default_factory=dict)  # workload -> DcimServingEstimate
    #: The weights each workload was actually selected with (profile entry,
    #: profile default, or the global ``preference``; None = pure wallclock)
    #: — what `--dcim-profile` persists back.
    preferences_applied: dict = field(default_factory=dict)

    def label_for(self, workload: str) -> str:
        return self.pool_labels[self.assignment[workload]]

    def ppa_for(self, workload: str):
        return self.pool[self.assignment[workload]]

    def serving_for(self, workload: str) -> DcimServingEstimate:
        return self.serving[workload]

    def summary(self) -> dict:
        return {
            "scenarios": list(self.scenarios),
            "candidates": len(self.pool),
            "codesign_frontier": len(self.codesign.frontier),
            "assignment": {w: self.label_for(w) for w in self.workloads},
            "preference": (list(self.preference)
                           if self.preference is not None else None),
            "preferences_applied": {
                w: (list(p) if p is not None else None)
                for w, p in self.preferences_applied.items()},
            "serving_tokens_per_s": {
                w: round(self.serving[w].tokens_per_s, 1)
                for w in self.workloads if w in self.serving},
        }


def select_macros(workloads: Mapping[str, Sequence[GemmShape]],
                  specs: Mapping[str, MacroSpec] | None = None,
                  tech: TechModel | None = None, resolution: int = 4,
                  n_macros: int = 256, ib: int = 8, wb: int = 8,
                  preference: Sequence[float] | None = None,
                  profile: PreferenceProfile | None = None,
                  service=None,
                  config: LatticeConfig | None = None,
                  kernel_fraction: float = 1.0) -> MacroSelection:
    """Synthesize the multi-spec frontier and pick a macro per workload.

    ``workloads`` maps deployed-workload names to GEMM inventories (see
    :func:`repro.core.dse.gemm_inventory` for the model zoo); ``specs``
    defaults to the §I scenario set.  Without ``preference``, selection is
    lowest total wallclock on the cross-workload co-design matrix (a
    timing-missing candidate is judged at its down-clocked reporting
    frequency exactly as the scalar accelerator reports would); with a
    ``preference`` (wallclock, energy, area) the pick is the scalarized best
    of the workload's pooled Pareto frontier (:func:`preference_select`).
    A ``profile`` (:class:`PreferenceProfile`, the persisted per-deployment
    artifact) overrides ``preference`` per workload it names — an explicit
    ``None`` entry keeps that workload on pure wallclock.  Either way, each
    workload's selected macro is fed through the serving roofline so the
    selection carries tokens/s bounds, not just wallclock.

    The multi-spec synthesis pass is served by ``service`` — a
    :class:`repro.service.SynthesisService` (default: the process-wide
    instance) or a :class:`repro.service.ServiceFrontend`; either way the
    scenario set goes in as typed INTERACTIVE
    :class:`~repro.service.SynthesisRequest` objects (selection is the
    user-facing ``--dcim-select`` shape of traffic, served ahead of bulk
    sweeps), the frontier is synthesized once per process (or once per
    persistent cache directory) and every later selection is a cache hit
    with zero engine executions.

    ``config`` selects the lattice axis set candidates are drawn from
    (:class:`repro.core.axes.LatticeConfig` — e.g. extra precision-headroom
    plans or approximate adder-tree cells); the seed axes when unset, so
    existing selections are untouched.

    ``kernel_fraction`` derates the serving roofline with a *measured*
    pipeline efficiency (see
    :func:`repro.kernels.profile.fraction_from_profiles` and the
    ``--dcim-kernel-profile`` launcher flag); 1.0 keeps the analytic
    bound."""
    if not workloads:
        raise ValueError("need at least one deployed workload")
    if tech is None:
        tech = calibrated_tech_for_reference()
    if specs is None:
        specs = scenario_specs()
    names = tuple(specs)
    if service is None:
        from ..service import get_service
        service = get_service()
    from ..service import Priority, SynthesisRequest
    responses = service.serve(
        [SynthesisRequest(spec=specs[n], tech=tech, resolution=resolution,
                          config=config, priority=Priority.INTERACTIVE)
         for n in names])
    results = [r.result for r in responses]
    pool, labels = frontier_union(results, names)
    report = cross_workload_codesign(workloads, pool, n_macros=n_macros,
                                     ib=ib, wb=wb)
    if preference is not None:
        preference = _check_weights(preference, "preference")
    applied = {}
    for w in report.workloads:
        weights = preference
        if profile is not None and (w in profile.workloads
                                    or profile.default is not None):
            weights = profile.weights_for(w)
        applied[w] = weights
    assignment = {w: (report.best_for(w) if applied[w] is None
                      else preferred_macro(report, w, applied[w]))
                  for w in report.workloads}
    serving = {}
    for w in report.workloads:
        wi = report.workloads.index(w)
        di = assignment[w]
        serving[w] = dcim_serving_bound(
            workloads[w], float(report.wallclock_s[wi, di]), ib=ib, wb=wb,
            workload=w, macro=labels[di], kernel_fraction=kernel_fraction)
    return MacroSelection(workloads=report.workloads, scenarios=names,
                          pool_labels=tuple(labels), pool=tuple(pool),
                          assignment=assignment, codesign=report,
                          preference=preference, serving=serving,
                          preferences_applied=applied)
