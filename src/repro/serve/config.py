"""Serving-launcher DCIM configuration: one typed dataclass for the flag
cluster the launcher grew across PRs 2-5.

``repro.launch.serve`` accumulated parallel ``--dcim-*`` flags
(``--dcim-select``, ``--dcim-pref``, ``--dcim-profile``, ``--dcim-cache``,
``--dcim-macros``); deployment tooling had no way to version that posture
as an artifact.  :class:`ServeConfig` consolidates them, and
``--dcim-config PATH`` loads one from JSON — **explicit CLI flags override
the file**, so an ops-managed config can be locally overridden per launch:

    {"schema": "syndcim-serve-config/v1",
     "select": true,
     "pref": [0.2, 0.6, 0.2],
     "profile": "deploy/profile.json",
     "cache": "deploy/frontiers",
     "registry": "/mnt/shared/syndcim-registry",
     "macros": 256,
     "trace": "deploy/trace.json",
     "trace_sample": 1.0,
     "kernel_profile": "deploy/kernel_profile.json"}

Unknown keys are rejected (a typo'd posture must fail loudly, not silently
serve defaults).

``trace`` enables :mod:`repro.obs` request tracing for the launch and
names the Chrome-trace output path (load it at ``ui.perfetto.dev``);
``trace_sample`` is the head-based trace sampling rate in (0, 1];
``kernel_profile`` points at a ``scripts/profile_kernels.py --json``
artifact whose measured pipeline efficiency derates the serving roofline.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, fields, replace
from pathlib import Path
from typing import Optional

#: Schema tag of the persisted serve-config artifact.
SERVE_CONFIG_SCHEMA = "syndcim-serve-config/v1"


@dataclass(frozen=True)
class ServeConfig:
    """The DCIM serving posture of one launch.

    ``select`` turns macro selection on; ``pref`` is the (wallclock,
    energy, area) preference vector; ``profile`` / ``cache`` are the
    preference-profile and frontier-cache artifact paths; ``registry`` is
    the fleet-shared artifact-registry root (shared storage — any spec
    synthesized by any host is a cache hit on every host); ``macros`` the
    macro-array size assumed by co-design; ``trace`` the Chrome-trace
    output path (None = tracing off) with ``trace_sample`` the head
    sampling rate in (0, 1]; ``kernel_profile`` a measured kernel-profile
    artifact derating the serving roofline."""

    select: bool = False
    pref: Optional[tuple[float, float, float]] = None
    profile: Optional[str] = None
    cache: Optional[str] = None
    registry: Optional[str] = None
    macros: int = 256
    trace: Optional[str] = None
    trace_sample: float = 1.0
    kernel_profile: Optional[str] = None

    def __post_init__(self):
        if self.pref is not None:
            p = tuple(float(x) for x in self.pref)
            if len(p) != 3:
                raise ValueError(f"pref needs 3 weights "
                                 f"(wallclock, energy, area), got {p}")
            object.__setattr__(self, "pref", p)
        if self.macros < 1:
            raise ValueError("macros must be >= 1")
        s = float(self.trace_sample)
        if not (0.0 < s <= 1.0):
            raise ValueError(f"trace_sample must be in (0, 1], got {s}")
        object.__setattr__(self, "trace_sample", s)


def parse_pref(text: str) -> tuple[float, float, float]:
    """Parse the ``--dcim-pref W,E,A`` flag value."""
    parts = tuple(float(x) for x in text.split(","))
    if len(parts) != 3:
        raise ValueError(f"--dcim-pref needs 3 comma-separated weights "
                         f"wallclock,energy,area, got {text!r}")
    return parts


def load_serve_config(path) -> ServeConfig:
    """Read a serve-config artifact; a missing file is an error (a config
    the launch was pointed at must exist — unlike preference profiles,
    there is no seed-on-first-run story here)."""
    p = Path(path)
    data = json.loads(p.read_text())
    if not isinstance(data, dict):
        raise ValueError(f"{p}: serve config must be a JSON object")
    if data.get("schema") != SERVE_CONFIG_SCHEMA:
        raise ValueError(f"{p}: not a serve config "
                         f"(schema={data.get('schema')!r}, "
                         f"expected {SERVE_CONFIG_SCHEMA!r})")
    known = {f.name for f in fields(ServeConfig)}
    body = {k: v for k, v in data.items() if k != "schema"}
    unknown = sorted(set(body) - known)
    if unknown:
        raise ValueError(f"{p}: unknown serve-config keys {unknown}; "
                         f"known: {sorted(known)}")
    if body.get("pref") is not None:
        body["pref"] = tuple(body["pref"])
    return ServeConfig(**body)


def save_serve_config(path, config: ServeConfig) -> None:
    """Write a serve-config artifact (deterministic layout)."""
    payload = {
        "schema": SERVE_CONFIG_SCHEMA,
        "select": config.select,
        "pref": None if config.pref is None else list(config.pref),
        "profile": config.profile,
        "cache": config.cache,
        "registry": config.registry,
        "macros": config.macros,
        "trace": config.trace,
        "trace_sample": config.trace_sample,
        "kernel_profile": config.kernel_profile,
    }
    Path(path).write_text(json.dumps(payload, indent=2, sort_keys=True)
                          + "\n")


def serve_config_from_args(args) -> ServeConfig:
    """Resolve the launch posture: start from ``--dcim-config`` (or
    defaults), then apply every explicitly-passed CLI flag on top —
    existing flags keep working and override the file.  ``args`` is the
    launcher's parsed namespace (``dcim_select`` et al.; flag defaults are
    ``False``/``None`` so "explicitly passed" is detectable)."""
    cfg = (load_serve_config(args.dcim_config)
           if getattr(args, "dcim_config", None) else ServeConfig())
    overrides: dict = {}
    if getattr(args, "dcim_select", False):
        overrides["select"] = True
    if getattr(args, "dcim_pref", None) is not None:
        overrides["pref"] = parse_pref(args.dcim_pref)
    if getattr(args, "dcim_profile", None) is not None:
        overrides["profile"] = args.dcim_profile
    if getattr(args, "dcim_cache", None) is not None:
        overrides["cache"] = args.dcim_cache
    if getattr(args, "dcim_registry", None) is not None:
        overrides["registry"] = args.dcim_registry
    if getattr(args, "dcim_macros", None) is not None:
        overrides["macros"] = int(args.dcim_macros)
    if getattr(args, "dcim_trace", None) is not None:
        overrides["trace"] = args.dcim_trace
    if getattr(args, "dcim_trace_sample", None) is not None:
        overrides["trace_sample"] = float(args.dcim_trace_sample)
    if getattr(args, "dcim_kernel_profile", None) is not None:
        overrides["kernel_profile"] = args.dcim_kernel_profile
    return replace(cfg, **overrides) if overrides else cfg
