"""Serving steps: prefill + cached decode, mesh-agnostic.

decode donates the state buffers (in-place KV-cache update on device — at
32k x 128 batch the cache is the dominant resident tensor and must not be
double-buffered).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.registry import ModelApi


def make_prefill(api: ModelApi, cache_len: int) -> Callable:
    def prefill(params, tokens, frontend=None):
        return api.prefill(params, tokens, cache_len, frontend=frontend)
    return prefill


def make_decode_step(api: ModelApi) -> Callable:
    def decode_step(params, state, tokens):
        return api.decode_step(params, state, tokens)
    return decode_step


def greedy_generate(api: ModelApi, params, prompt_tokens, n_steps: int,
                    cache_len: int | None = None, frontend=None):
    """Host-side loop: prefill the prompt then greedy-decode ``n_steps``."""
    b, s = prompt_tokens.shape
    cache_len = cache_len or (s + n_steps)
    prefill = jax.jit(make_prefill(api, cache_len))
    decode = jax.jit(make_decode_step(api), donate_argnums=(1,))
    logits, state = prefill(params, prompt_tokens, frontend)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(n_steps - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
