"""Mixture-of-Experts decoder (granite-3.0-moe family): GQA attention + top-k
routed SwiGLU experts with GShard/Switch capacity-based dispatch.

Dispatch is the einsum formulation proven at pod scale (GShard lineage): token
groups of ``MOE_GROUP`` tokens build (group, S, E, C) dispatch/combine
tensors; under the expert-parallel sharding rules (experts -> 'model' axis,
groups -> 'data' axis) GSPMD lowers the two einsums into all-to-alls.  Group
size bounds both the dispatch-tensor memory and its FLOPs overhead
(E*C ≈ S*k*cf per token — keep S small).

The MCR/weight-update angle of the paper (DESIGN.md §5): expert weights cycle
per token group, so a DCIM mapping of MoE experts exercises the macro's
weight-update frequency spec; benchmarks/bench_dse.py reports it.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..parallel.logical import param
from . import layers as L
from .transformer import (_logits, init_decode_state, scan_layers, stack_init)

MOE_GROUP = 256      # tokens per dispatch group


def moe_mlp_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    e, ff = cfg.moe.n_experts, cfg.moe.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": param(ks[0], (d, e), ("embed", "experts"), dtype),
        "w_gate": param(ks[1], (e, d, ff), ("experts", "embed", "expert_ff"), dtype),
        "w_up": param(ks[2], (e, d, ff), ("experts", "embed", "expert_ff"), dtype),
        "w_down": param(ks[3], (e, ff, d), ("experts", "expert_ff", "embed"), dtype),
    }


def _top_k_dispatch(gates: jnp.ndarray, k: int, capacity: int):
    """gates: (G, S, E) softmax router probs.  Returns (dispatch (G,S,E,C)
    bool, combine (G,S,E,C) f32, aux_loss) via the Switch/GShard slot
    assignment: iterate the k choices, positions within an expert given by a
    cumulative count over the group; overflow tokens drop (capacity factor)."""
    g, s, e = gates.shape
    topv, topi = jax.lax.top_k(gates, k)                       # (G,S,k)
    prio_used = jnp.zeros((g, e), jnp.int32)
    dispatch = jnp.zeros((g, s, e, capacity), bool)
    combine = jnp.zeros((g, s, e, capacity), jnp.float32)
    for slot in range(k):
        idx = topi[..., slot]                                  # (G,S)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)       # (G,S,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + prio_used[:, None, :]
        prio_used = prio_used + onehot.sum(axis=1)
        mypos = jnp.take_along_axis(pos, idx[..., None], -1)[..., 0]  # (G,S)
        keep = mypos < capacity
        posoh = jax.nn.one_hot(jnp.where(keep, mypos, capacity), capacity + 1,
                               dtype=jnp.float32)[..., :capacity]  # (G,S,C)
        d_slot = onehot.astype(jnp.float32)[..., None] * posoh[..., None, :]
        dispatch = dispatch | (d_slot > 0)
        combine = combine + d_slot * topv[..., slot][..., None, None]
    # load-balance aux (Switch): E * sum_e f_e * p_e
    density = dispatch.any(-1).astype(jnp.float32).mean(axis=(0, 1))  # (E,)
    p_mean = gates.mean(axis=(0, 1))
    aux = e * jnp.sum(density * p_mean)
    return dispatch, combine, aux


def moe_mlp_apply(p, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    cd = x.dtype
    b, s, d = x.shape
    e, k, ffe = cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_expert
    lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=cd)
    # group tokens: (G, Sg, d)
    toks = b * s
    sg = min(MOE_GROUP, toks)
    gcount = toks // sg
    xg = x.reshape(gcount, sg, d)
    logits = jnp.matmul(xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    cap = max(1, int(math.ceil(sg * k / e * cfg.moe.capacity_factor)))
    dispatch, combine, aux = _top_k_dispatch(gates, k, cap)

    # dispatch: (G,Sg,E,C) x (G,Sg,d) -> (E,G,C,d)   [all-to-all under EP]
    from ..parallel.sharding import constrain_act
    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(cd), xg)
    xin = constrain_act(xin, ("experts", "batch", None, None))
    wg = p["w_gate"].astype(cd)
    wu = p["w_up"].astype(cd)
    wd = p["w_down"].astype(cd)
    if cfg.dcim_enabled:
        from ..quant import fake_quant
        wg = fake_quant(wg, cfg.dcim_w_bits, 1)
        wu = fake_quant(wu, cfg.dcim_w_bits, 1)
        wd = fake_quant(wd, cfg.dcim_w_bits, 1)
        xin = fake_quant(xin, cfg.dcim_a_bits, -1)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, wg)) \
        * jnp.einsum("egcd,edf->egcf", xin, wu)
    hout = jnp.einsum("egcf,efd->egcd", h, wd)
    hout = constrain_act(hout, ("experts", "batch", None, None))
    # combine back: (G,Sg,E,C) x (E,G,C,d) -> (G,Sg,d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(cd), hout)
    y = constrain_act(y, ("batch", None, None))
    return y.reshape(b, s, d), aux


def block_init(key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "ln_mlp": L.rmsnorm_init(cfg.d_model),
        "moe": moe_mlp_init(ks[1], cfg, dtype),
    }


def block_apply(p, x, cfg, *, positions, kv_cache=None, cache_pos=None,
                prefill_fill=False):
    h, new_cache = L.attention_apply(p["attn"],
                                     L.rmsnorm_apply(p["ln_attn"], x), cfg,
                                     positions=positions, kv_cache=kv_cache,
                                     cache_pos=cache_pos,
                                     prefill_fill=prefill_fill)
    x = x + h
    y, aux = moe_mlp_apply(p["moe"], L.rmsnorm_apply(p["ln_mlp"], x), cfg)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init_params(key, cfg):
    dtype = L.dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    p = {
        "embed": L.embedding_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": stack_init(partial(block_init, cfg=cfg, dtype=dtype),
                             layer_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": param(ks[2], (cfg.d_model, cfg.vocab_padded),
                                   ("embed", "vocab"), dtype)}
    return p


def forward_train(p, cfg, batch):
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], batch["tokens"], cd)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def blk(h, bp):
        h2, _, aux = block_apply(bp, h, cfg, positions=pos)
        return h2, aux

    x, auxes = scan_layers(blk, p["blocks"], x, remat=cfg.remat)
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    return logits, jnp.mean(auxes)


def decode_step(p, cfg, state, tokens, frontend=None):
    """``state`` is a PLAIN array tree."""
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    b, s, _ = x.shape
    pos_idx = state["pos"]
    positions = jnp.broadcast_to(pos_idx + jnp.arange(s), (b, s))

    def blk(h, xs):
        bp, (kc, vc) = xs
        h2, cache, _aux = block_apply(bp, h, cfg, positions=positions,
                                      kv_cache={"k": kc, "v": vc},
                                      cache_pos=pos_idx)
        return h2, (cache["k"], cache["v"])

    x, (k_new, v_new) = scan_layers(blk, p["blocks"], x, remat=False,
                                    extra=(state["k"], state["v"]))
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    new_state = dict(state)
    new_state["k"] = k_new
    new_state["v"] = v_new
    new_state["pos"] = pos_idx + s
    return logits, new_state


def prefill(p, cfg, tokens, cache_len: int, frontend=None):
    from ..parallel.logical import values_of
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    state = values_of(init_decode_state(cfg, b, cache_len))

    def blk(h, xs):
        bp, (kc, vc) = xs
        h2, cache, _aux = block_apply(bp, h, cfg, positions=positions,
                                      kv_cache={"k": kc, "v": vc},
                                      cache_pos=jnp.zeros((), jnp.int32),
                                      prefill_fill=True)
        return h2, (cache["k"], cache["v"])

    x, (k_new, v_new) = scan_layers(blk, p["blocks"], x, remat=cfg.remat,
                                    extra=(state["k"], state["v"]))
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    state["k"] = k_new
    state["v"] = v_new
    state["pos"] = jnp.asarray(s, jnp.int32)
    return logits, state
