from .registry import ModelApi, cross_entropy_loss, get_model

__all__ = ["ModelApi", "cross_entropy_loss", "get_model"]
