"""Mamba2 (SSD) blocks + Zamba2 hybrid (shared attention block every N Mamba
blocks, weights shared across invocations) — arXiv:2411.15242.

Mamba2 block: in_proj -> (z, x, B, C, dt); depthwise causal conv on (x,B,C);
SSD recurrence with scalar per-head decay a_t = exp(-softplus(dt + bias) *
exp(A_log)) executed on the shared chunked-GLA path (inclusive diagonal);
gated rmsnorm + out_proj.

Decode state per mamba layer: conv cache (K-1 last inputs) + SSD state
(B, H, N, P).  The shared attention block keeps a standard KV cache.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.logical import Logical, param
from . import layers as L
from .ssm import causal_conv1d, chunked_gla, gla_decode_step
from .transformer import block_apply as attn_block_apply
from .transformer import block_init as attn_block_init
from .transformer import scan_layers, stack_init


def mamba_block_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    nh = di // s.head_dim                  # ssd heads
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * s.state
    return {
        "ln": L.rmsnorm_init(d),
        "in_proj": param(ks[0], (d, 2 * di + 2 * s.state + nh),
                         ("embed", "ff"), dtype),
        "conv_w": Logical(jnp.zeros((s.conv_kernel, conv_dim), jnp.float32)
                          .at[-1].set(1.0), ("conv", "act_ff")),
        "A_log": Logical(jnp.zeros((nh,), jnp.float32), ("act_heads",)),
        "dt_bias": Logical(jnp.full((nh,), -2.0, jnp.float32), ("act_heads",)),
        "D": Logical(jnp.ones((nh,), jnp.float32), ("act_heads",)),
        "ln_y": L.rmsnorm_init(di, axis="act_ff"),
        "out_proj": param(ks[1], (di, d), ("ff", "embed"), dtype),
    }


def _split_in_proj(cfg, proj):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    z, xbc, dtp = jnp.split(proj, [di, 2 * di + 2 * s.state], axis=-1)
    return z, xbc, dtp, di, nh


def mamba_block_apply(p, xin, cfg, *, state=None):
    """xin: (B, T, d).  state: {'conv': (B,K-1,conv_dim), 'ssd': (B,H,N,P)}."""
    s = cfg.ssm
    cd = xin.dtype
    b, t, d = xin.shape
    lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=cd)
    x = L.rmsnorm_apply(p["ln"], xin)
    proj = lin(p["in_proj"], x, out_ax="ff")
    z, xbc, dtp, di, nh = _split_in_proj(cfg, proj)

    conv_cache = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv1d(jax.nn.silu(xbc), p["conv_w"].value
                                  if isinstance(p["conv_w"], Logical)
                                  else p["conv_w"], conv_cache)
    xs, B, C = jnp.split(xbc, [di, di + s.state], axis=-1)

    # SSD parameters: scalar decay per head, B/C shared across heads (ngroups=1)
    a_log = p["A_log"].value if isinstance(p["A_log"], Logical) else p["A_log"]
    dt_b = p["dt_bias"].value if isinstance(p["dt_bias"], Logical) else p["dt_bias"]
    dparm = p["D"].value if isinstance(p["D"], Logical) else p["D"]
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + dt_b)      # (B,T,nh)
    decay = -jnp.exp(a_log)[None, None, :] * dt               # log a_t <= 0

    v = xs.reshape(b, t, nh, s.head_dim)                      # values
    k = jnp.broadcast_to(B[:, :, None, :], (b, t, nh, s.state))
    q = jnp.broadcast_to(C[:, :, None, :], (b, t, nh, s.state))
    # dt scales the input (ZOH discretization of B x_t):
    v_in = (v.astype(jnp.float32) * dt[..., None]).astype(cd)
    log_w = jnp.broadcast_to(decay[..., None], (b, t, nh, s.state))

    if state is None or t > 1:
        # train / prefill: chunked scan (optionally continuing from a state)
        s0 = state["ssd"] if state is not None else None
        y, ssd_fin = chunked_gla(q, k, v_in, log_w, inclusive=True,
                                 chunk=s.chunk, s0=s0, remat=cfg.remat)
    else:
        yv, ssd_fin = gla_decode_step(q[:, 0], k[:, 0], v_in[:, 0],
                                      log_w[:, 0], state["ssd"],
                                      inclusive=True)
        y = yv[:, None]
    y = y + v.astype(y.dtype) * dparm[None, None, :, None]    # skip (D term)
    y = y.reshape(b, t, di)
    y = L.rmsnorm_apply(p["ln_y"], y * jax.nn.silu(z))
    out = lin(p["out_proj"], y, out_ax="embed")
    new_state = {"conv": new_conv, "ssd": ssd_fin}
    return xin + out, new_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid stack
# ---------------------------------------------------------------------------


def _segments(cfg) -> list[int]:
    """Mamba-layer counts between shared-attention invocations."""
    k = cfg.attn_every or cfg.n_layers
    full, rem = divmod(cfg.n_layers, k)
    return [k] * full + ([rem] if rem else [])


def init_params(key, cfg):
    dtype = L.dt(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.embedding_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "mamba": stack_init(partial(mamba_block_init, cfg=cfg, dtype=dtype),
                            layer_keys),
        "shared_attn": attn_block_init(ks[2], cfg, dtype),   # ONE shared block
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "unembed": {"w": param(ks[3], (cfg.d_model, cfg.vocab_padded),
                               ("embed", "vocab"), dtype)},
    }


def _slice_stack(tree, lo: int, hi: int):
    from ..parallel.logical import is_logical
    return jax.tree.map(
        lambda l: Logical(lax.slice_in_dim(l.value, lo, hi, axis=0), l.axes)
        if isinstance(l, Logical) else lax.slice_in_dim(l, lo, hi, axis=0),
        tree, is_leaf=is_logical)


def forward_train(p, cfg, batch):
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], batch["tokens"], cd)
    b, t, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))

    def mblk(h, bp):
        h2, _ = mamba_block_apply(bp, h, cfg)
        return h2, 0

    lo = 0
    for seg in _segments(cfg):
        seg_params = _slice_stack(p["mamba"], lo, lo + seg)
        x, _ = scan_layers(mblk, seg_params, x, remat=cfg.remat)
        lo += seg
        # shared attention block after every segment (weights shared)
        x, _ = attn_block_apply(p["shared_attn"], x, cfg, positions=pos)
    x = L.rmsnorm_apply(p["ln_f"], x)
    return L.mask_padded_vocab(L.constrain_logits(jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd))), cfg.vocab)


def init_decode_state(cfg, batch: int, cache_len: int):
    cd = L.dt(cfg.compute_dtype)
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_dim = di + 2 * s.state
    n_attn = len(_segments(cfg))
    return {
        "conv": Logical(jnp.zeros((cfg.n_layers, batch, s.conv_kernel - 1,
                                   conv_dim), cd),
                        ("layer", "batch", None, "act_ff")),
        "ssd": Logical(jnp.zeros((cfg.n_layers, batch, nh, s.state,
                                  s.head_dim), jnp.float32),
                       ("layer", "batch", "act_heads", None, None)),
        # shared attention block: one KV cache per invocation site
        "k": Logical(jnp.zeros((n_attn, batch, cache_len, cfg.n_kv_heads,
                                cfg.hd), cd),
                     ("layer", "batch", "kv_seq", "cache_heads", None)),
        "v": Logical(jnp.zeros((n_attn, batch, cache_len, cfg.n_kv_heads,
                                cfg.hd), cd),
                     ("layer", "batch", "kv_seq", "cache_heads", None)),
        "pos": Logical(jnp.zeros((), jnp.int32), ()),
    }


def _run_stack(p, cfg, x, state, *, prefill_mode: bool):
    """Shared serve path: mamba segments with state + shared attn w/ caches.
    ``state`` is a PLAIN array tree."""
    b, t, _ = x.shape
    pos0 = state["pos"]
    positions = jnp.broadcast_to(pos0 + jnp.arange(t), (b, t))
    conv_all, ssd_all = state["conv"], state["ssd"]
    k_all, v_all = state["k"], state["v"]

    def mblk(h, xs):
        bp, (cv, sd) = xs
        h2, ns = mamba_block_apply(bp, h, cfg, state={"conv": cv, "ssd": sd})
        return h2, (ns["conv"].astype(cv.dtype), ns["ssd"])

    new_conv, new_ssd, new_k, new_v = [], [], [], []
    lo = 0
    for i, seg in enumerate(_segments(cfg)):
        seg_params = _slice_stack(p["mamba"], lo, lo + seg)
        seg_state = (lax.slice_in_dim(conv_all, lo, lo + seg, axis=0),
                     lax.slice_in_dim(ssd_all, lo, lo + seg, axis=0))
        x, (nc, nsd) = scan_layers(mblk, seg_params, x,
                                   remat=cfg.remat and prefill_mode,
                                   extra=seg_state)
        new_conv.append(nc)
        new_ssd.append(nsd)
        lo += seg
        kc = k_all[i]
        vc = v_all[i]
        x, cache = attn_block_apply(
            p["shared_attn"], x, cfg, positions=positions,
            kv_cache={"k": kc, "v": vc},
            cache_pos=jnp.zeros((), jnp.int32) if prefill_mode else pos0,
            prefill_fill=prefill_mode)
        new_k.append(cache["k"])
        new_v.append(cache["v"])

    new_state = dict(state)
    new_state["conv"] = jnp.concatenate(new_conv, 0)
    new_state["ssd"] = jnp.concatenate(new_ssd, 0)
    new_state["k"] = jnp.stack(new_k, 0)
    new_state["v"] = jnp.stack(new_v, 0)
    new_state["pos"] = pos0 + t
    return x, new_state


def decode_step(p, cfg, state, tokens, frontend=None):
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    x, new_state = _run_stack(p, cfg, x, state, prefill_mode=False)
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = L.mask_padded_vocab(jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd)), cfg.vocab)
    return logits, new_state


def prefill(p, cfg, tokens, cache_len: int, frontend=None):
    from ..parallel.logical import values_of
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    state = values_of(init_decode_state(cfg, tokens.shape[0], cache_len))
    x, new_state = _run_stack(p, cfg, x, state, prefill_mode=True)
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = L.mask_padded_vocab(jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd)), cfg.vocab)
    new_state["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, new_state
