"""Chunked decayed linear attention — the shared recurrence under RWKV6
("Finch", vector decay per key channel) and Mamba2 (SSD, scalar decay per
head).

Semantics per head with state S in R^{dk x dv}:

    S_t = Diag(w_t) S_{t-1} + k_t v_t^T
    y_t = q_t^T (S_{t-1} + Diag(u) k_t v_t^T)     [rwkv: bonus u, exclusive]
    y_t = q_t^T S_t                               [mamba: inclusive, no bonus]

Chunked evaluation (GLA-style): within a chunk of C tokens the decay factors
telescope into per-token exponentials of the cumulative log-decay, giving an
exact O(C^2) intra-chunk term plus an O(dk x dv) inter-chunk state carried by
``lax.scan``.  Backward memory stays O(T/C x state) via remat of the chunk
body — this is what makes 4k-token training and 500k-token decode of the SSM
archs feasible (DESIGN.md §5).

Decode (T=1) uses the plain recurrence; the Pallas ``ssm_scan`` kernel covers
the diagonal case on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def chunked_gla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                log_w: jnp.ndarray, *, u: jnp.ndarray | None = None,
                inclusive: bool = False, chunk: int = 256,
                s0: jnp.ndarray | None = None, remat: bool = True
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q, k: (B, T, H, dk); v: (B, T, H, dv); log_w: (B, T, H, dk) (<= 0).

    u: (H, dk) bonus (rwkv) — applied to the diagonal (current token) term.
    inclusive: diagonal uses decayed state *including* k_t v_t (mamba2).
    s0: (B, H, dk, dv) initial state.
    Returns (y (B, T, H, dv), final_state (B, H, dk, dv)).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = t + pad
    nc = tp // c

    def to_chunks(x):
        # (B, T, H, D) -> (NC, B, H, C, D)
        return x.reshape(b, nc, c, h, -1).transpose(1, 0, 3, 2, 4)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lwc = to_chunks(log_w).astype(jnp.float32)

    ccum = jnp.cumsum(lwc, axis=-2)                 # inclusive over chunk
    ccum_ex = ccum - lwc                            # exclusive
    wtot = ccum[..., -1:, :]                        # (NC,B,H,1,dk)

    # Factored decay weights (exact; bounded within a chunk).  Convention:
    #   exclusive (rwkv):  y_i reads S_{i-1} -> q scales by exp(ccum_ex_i),
    #                      strictly-causal mask, diagonal via the u bonus;
    #   inclusive (mamba): y_i reads S_i     -> q scales by exp(ccum_i),
    #                      mask includes the diagonal (coefficient
    #                      exp(ccum_i - ccum_i) = 1, i.e. k_i v_i undecayed).
    q_cum = ccum if inclusive else ccum_ex
    q_dec = (qc.astype(jnp.float32) * jnp.exp(q_cum))            # q~
    k_dec = (kc.astype(jnp.float32) * jnp.exp(-ccum))            # k~ (1/G_j)
    k_rem = (kc.astype(jnp.float32) * jnp.exp(wtot - ccum))      # for state upd

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    tri = jnp.tril(jnp.ones((c, c), bool), -1)      # strictly lower (j < i)
    if inclusive:
        tri = jnp.tril(jnp.ones((c, c), bool), 0)   # j <= i

    def chunk_step(s, xs):
        qd, kd, kr, vv, wt, qraw, kraw = xs
        # inter-chunk: y_i += (q_i ⊙ E_i) S
        y_inter = jnp.einsum("bhcd,bhde->bhce", qd, s)
        # intra-chunk: scores_ij = q~_i · k~_j  (masked causal)
        scores = jnp.einsum("bhcd,bhkd->bhck", qd, kd)
        scores = jnp.where(tri, scores, 0.0)
        y_intra = jnp.einsum("bhck,bhke->bhce", scores, vv.astype(jnp.float32))
        y = y_inter + y_intra
        if u is not None and not inclusive:
            bonus = jnp.einsum("bhcd,hd,bhcd->bhc",
                               qraw.astype(jnp.float32), u.astype(jnp.float32),
                               kraw.astype(jnp.float32))
            y = y + bonus[..., None] * vv.astype(jnp.float32)
        # state: S' = Diag(exp(wtot)) S + k_rem^T v
        s_new = jnp.exp(wt[..., 0, :])[..., None] * s \
            + jnp.einsum("bhck,bhce->bhke", kr, vv.astype(jnp.float32))
        return s_new, y

    step = jax.checkpoint(chunk_step) if remat else chunk_step
    s_fin, ys = lax.scan(step, s0, (q_dec, k_dec, k_rem, vc, wtot, qc, kc))
    # ys: (NC, B, H, C, dv) -> (B, T, H, dv)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, tp, h, dv)[:, :t]
    return y.astype(v.dtype), s_fin


def gla_decode_step(q, k, v, log_w, s, *, u=None, inclusive=False):
    """Single-token recurrence.  q,k: (B,H,dk); v: (B,H,dv); s: (B,H,dk,dv)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(log_w.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]
    if inclusive:
        s_new = w[..., None] * s + kv
        y = jnp.einsum("bhd,bhde->bhe", qf, s_new)
    else:
        eff = s + (u.astype(jnp.float32)[None, :, :, None] * kv
                   if u is not None else 0.0)
        y = jnp.einsum("bhd,bhde->bhe", qf, eff)
        s_new = w[..., None] * s + kv
    return y.astype(v.dtype), s_new


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, cache: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv: x (B, T, D); w (K, D).  Returns (y, new_cache)
    with cache (B, K-1, D) carrying the last K-1 inputs for decode."""
    ksz = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], ksz - 1, x.shape[-1]), x.dtype)
    xc = jnp.concatenate([cache, x], axis=1)
    y = sum(xc[:, i:i + x.shape[1], :] * w[i] for i in range(ksz))
    new_cache = xc[:, -(ksz - 1):, :] if ksz > 1 else cache
    return y.astype(x.dtype), new_cache
