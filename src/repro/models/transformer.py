"""Dense GQA transformer family: llama3.2 / qwen3 / mistral-large / phi3,
plus the VLM (internvl2: stub patch embeddings + projector) and the audio
enc-dec (whisper: stub frame embeddings + encoder + cross-attending decoder).

Layers are scanned (stacked params + ``lax.scan``) with optional remat — one
compiled layer body regardless of depth (88-layer Mistral compiles as one).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.logical import Logical, is_logical, param
from . import layers as L


# ---------------------------------------------------------------------------
# stacked-layer helpers
# ---------------------------------------------------------------------------


def stack_init(init_fn, keys):
    """vmap ``init_fn`` over ``keys`` and prepend a 'layer' logical axis."""
    proto = init_fn(keys[0])
    vals = jax.vmap(
        lambda k: jax.tree.map(lambda l: l.value, init_fn(k),
                               is_leaf=is_logical))(keys)
    return jax.tree.map(
        lambda l, v: Logical(v, ("layer",) + l.axes), proto, vals,
        is_leaf=is_logical)


def scan_layers(block_fn, params_stacked, x, *, remat: bool, extra=None,
                length: int | None = None):
    """Run x through stacked layers.  ``extra`` is scanned alongside params
    (e.g. per-layer KV caches); returns (x, stacked outputs)."""
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def step(carry, xs):
        y, out = fn(carry, xs)
        return y, out

    xs = (params_stacked, extra) if extra is not None else params_stacked
    return lax.scan(step, x, xs, length=length)


# ---------------------------------------------------------------------------
# Decoder block
# ---------------------------------------------------------------------------


def block_init(key, cfg, dtype, cross: bool = False) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "ln_attn": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg, dtype),
        "ln_mlp": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg, dtype),
    }
    if cross:
        p["ln_xattn"] = L.rmsnorm_init(cfg.d_model)
        p["xattn"] = L.attention_init(ks[2], cfg, dtype)
    return p


def block_apply(p, x, cfg, *, positions, causal=True, kv_cache=None,
                cache_pos=None, enc_kv=None, prefill_fill=False):
    h, new_cache = L.attention_apply(p["attn"], L.rmsnorm_apply(p["ln_attn"], x),
                                     cfg, positions=positions, causal=causal,
                                     kv_cache=kv_cache, cache_pos=cache_pos,
                                     prefill_fill=prefill_fill)
    x = x + h
    if enc_kv is not None:
        hx, _ = L.attention_apply(p["xattn"],
                                  L.rmsnorm_apply(p["ln_xattn"], x), cfg,
                                  positions=None, causal=False,
                                  kv_override=enc_kv)
        x = x + hx
    x = x + L.mlp_apply(p["mlp"], L.rmsnorm_apply(p["ln_mlp"], x), cfg)
    return x, new_cache


# ---------------------------------------------------------------------------
# Model: init
# ---------------------------------------------------------------------------


def init_params(key, cfg):
    dtype = L.dt(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    cross = cfg.encoder_layers > 0
    p = {
        "embed": L.embedding_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": stack_init(partial(block_init, cfg=cfg, dtype=dtype,
                                     cross=cross), layer_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": param(ks[2], (cfg.d_model, cfg.vocab_padded),
                                   ("embed", "vocab"), dtype)}
    if cfg.frontend is not None:
        p["frontend_proj"] = {"w": param(ks[3], (cfg.frontend.d_frontend,
                                                 cfg.d_model),
                                         ("embed_no_fsdp", "embed"), dtype)}
    if cfg.encoder_layers > 0:
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        p["enc_blocks"] = stack_init(partial(block_init, cfg=cfg, dtype=dtype),
                                     enc_keys)
        p["enc_ln_f"] = L.rmsnorm_init(cfg.d_model)
    return p


def _logits(p, cfg, x):
    cd = L.dt(cfg.compute_dtype)
    if cfg.tie_embeddings:
        out = L.unembed_apply(p["embed"], x, cd)
    else:
        out = jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd))
        from ..parallel.sharding import constrain_act
        out = constrain_act(out, ("batch", "seq", "act_vocab"))
    return L.mask_padded_vocab(out, cfg.vocab)


def _encode(p, cfg, frames):
    """Whisper encoder over (stubbed) frame embeddings (B, F, d_frontend)."""
    cd = L.dt(cfg.compute_dtype)
    x = frames.astype(cd)
    if cfg.frontend is not None and cfg.frontend.d_frontend != cfg.d_model:
        x = jnp.matmul(x, p["frontend_proj"]["w"].astype(cd))
    elif "frontend_proj" in p:
        x = jnp.matmul(x, p["frontend_proj"]["w"].astype(cd))
    b, f, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(f), (b, f))

    def enc_block(h, blk):
        h2, _ = block_apply(blk, h, cfg, positions=pos, causal=False)
        return h2, 0

    x, _ = scan_layers(enc_block, p["enc_blocks"], x, remat=cfg.remat)
    return L.rmsnorm_apply(p["enc_ln_f"], x)


# ---------------------------------------------------------------------------
# Model: training forward
# ---------------------------------------------------------------------------


def forward_train(p, cfg, batch) -> jnp.ndarray:
    """batch: {'tokens': (B,S)} (+ 'frontend': (B,F,d_frontend) for vlm/audio).
    Returns logits (B, S(+P for vlm prefix), vocab) — callers slice."""
    cd = L.dt(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = L.embedding_apply(p["embed"], tokens, cd)
    enc_kv = None
    prefix = 0
    if cfg.family == "vlm":
        img = batch["frontend"].astype(cd)
        img = jnp.matmul(img, p["frontend_proj"]["w"].astype(cd))
        x = jnp.concatenate([img, x], axis=1)
        prefix = img.shape[1]
    if cfg.encoder_layers > 0:
        enc = _encode(p, cfg, batch["frontend"])
        enc_kv = enc  # per-block K/V projections computed inside the block

    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def dec_block(h, blk):
        ekv = None
        if enc_kv is not None:
            # Cross-attention K/V from encoder output using this block's
            # wk/wv (no rope).
            lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                          w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                          compute_dtype=cd)
            eb, ef, _ = enc_kv.shape
            kx = lin(blk["xattn"]["wk"], enc_kv, out_ax="kv_heads") \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            vx = lin(blk["xattn"]["wv"], enc_kv, out_ax="kv_heads") \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            ekv = (kx, vx)
        h2, _ = block_apply(blk, h, cfg, positions=pos, enc_kv=ekv)
        return h2, 0

    x, _ = scan_layers(dec_block, p["blocks"], x, remat=cfg.remat)
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    if prefix:
        logits = logits[:, prefix:]
    return logits


# ---------------------------------------------------------------------------
# Model: serving (prefill / decode with per-layer KV caches)
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch: int, cache_len: int):
    """Per-layer KV caches stacked on the layer axis."""
    cd = L.dt(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.hd)
    state = {
        "k": Logical(jnp.zeros(shape, cd),
                     ("layer", "batch", "kv_seq", "cache_heads", None)),
        "v": Logical(jnp.zeros(shape, cd),
                     ("layer", "batch", "kv_seq", "cache_heads", None)),
        "pos": Logical(jnp.zeros((), jnp.int32), ()),
    }
    if cfg.encoder_layers > 0:
        state["enc_out"] = Logical(
            jnp.zeros((batch, cfg.frontend.n_tokens, cfg.d_model), cd),
            ("batch", None, "act_embed"))
    return state


def decode_step(p, cfg, state, tokens, frontend=None):
    """One decode step: tokens (B, 1) -> logits (B, 1, V); updates caches.

    ``state`` is a PLAIN array tree (see init_decode_state + values_of);
    ``state['pos']`` is the number of tokens already cached.
    """
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    b, s, _ = x.shape
    pos_idx = state["pos"]
    positions = jnp.broadcast_to(pos_idx + jnp.arange(s), (b, s))
    k_all, v_all = state["k"], state["v"]
    enc_out = state.get("enc_out") if cfg.encoder_layers > 0 else None

    def dec_block(h, xs):
        blk, (kc, vc) = xs
        ekv = None
        if enc_out is not None:
            lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                          w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                          compute_dtype=cd)
            eb, ef, _ = enc_out.shape
            kx = lin(blk["xattn"]["wk"], enc_out) \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            vx = lin(blk["xattn"]["wv"], enc_out) \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            ekv = (kx, vx)
        h2, new_cache = block_apply(blk, h, cfg, positions=positions,
                                    kv_cache={"k": kc, "v": vc},
                                    cache_pos=pos_idx, enc_kv=ekv)
        return h2, (new_cache["k"], new_cache["v"])

    x, (k_new, v_new) = scan_layers(dec_block, p["blocks"], x,
                                    remat=False, extra=(k_all, v_all))
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    new_state = dict(state)
    new_state["k"] = k_new
    new_state["v"] = v_new
    new_state["pos"] = pos_idx + s
    return logits, new_state


def prefill(p, cfg, tokens, cache_len: int, frontend=None):
    """Run the prompt through the model, filling the KV caches.  Returns a
    PLAIN state tree."""
    from ..parallel.logical import values_of
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    state = values_of(init_decode_state(cfg, b, cache_len))
    k_all = state["k"]
    v_all = state["v"]
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _encode(p, cfg, frontend)

    def dec_block(h, xs):
        blk, (kc, vc) = xs
        ekv = None
        if enc_out is not None:
            lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                          w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                          compute_dtype=cd)
            eb, ef, _ = enc_out.shape
            kx = lin(blk["xattn"]["wk"], enc_out) \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            vx = lin(blk["xattn"]["wv"], enc_out) \
                .reshape(eb, ef, cfg.n_kv_heads, cfg.hd)
            ekv = (kx, vx)
        h2, new_cache = block_apply(blk, h, cfg, positions=positions,
                                    kv_cache={"k": kc, "v": vc},
                                    cache_pos=jnp.zeros((), jnp.int32),
                                    enc_kv=ekv, prefill_fill=True)
        return h2, (new_cache["k"], new_cache["v"])

    x, (k_new, v_new) = scan_layers(dec_block, p["blocks"], x,
                                    remat=cfg.remat, extra=(k_all, v_all))
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = _logits(p, cfg, x)
    state["k"] = k_new
    state["v"] = v_new
    state["pos"] = jnp.asarray(s, jnp.int32)
    if enc_out is not None:
        state["enc_out"] = enc_out
    return logits, state
