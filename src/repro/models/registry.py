"""Uniform model API over the zoo.

``get_model(cfg)`` returns a :class:`ModelApi` whose functions all take PLAIN
array trees (init_params returns a Logical tree for sharding-spec
derivation; strip with ``values_of`` / ``split_logical``).

forward_train returns (logits, aux_loss) uniformly (aux = 0 for non-MoE).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from . import transformer as tfm_mod
from . import zamba2 as zamba_mod


@dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init_params: Callable          # key -> Logical tree
    forward_train: Callable        # (params, batch) -> (logits, aux)
    prefill: Callable              # (params, tokens, cache_len, frontend) -> (logits, state)
    decode_step: Callable          # (params, state, tokens, frontend) -> (logits, state)
    init_decode_state: Callable    # (batch, cache_len) -> Logical tree


def _wrap_aux(fn):
    def f(p, cfg, batch):
        out = fn(p, cfg, batch)
        if isinstance(out, tuple):
            return out
        return out, jnp.zeros((), jnp.float32)
    return f


def get_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family in ("dense", "vlm", "audio"):
        m = tfm_mod
    elif cfg.family == "moe":
        m = moe_mod
    elif cfg.family == "ssm":
        m = rwkv_mod
    elif cfg.family == "hybrid":
        m = zamba_mod
    else:  # pragma: no cover
        raise ValueError(f"unknown family {cfg.family}")
    fwd = _wrap_aux(m.forward_train)
    return ModelApi(
        cfg=cfg,
        init_params=lambda key: m.init_params(key, cfg),
        forward_train=lambda p, batch: fwd(p, cfg, batch),
        prefill=lambda p, tokens, cache_len, frontend=None:
            m.prefill(p, cfg, tokens, cache_len, frontend=frontend),
        decode_step=lambda p, state, tokens, frontend=None:
            m.decode_step(p, cfg, state, tokens, frontend=frontend),
        init_decode_state=lambda batch, cache_len:
            m.init_decode_state(cfg, batch, cache_len),
    )


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token-mean CE in f32 (logits may be bf16)."""
    lf = logits.astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(lf - lf.max(-1, keepdims=True)), -1)) \
        + lf.max(-1)
    gold = jnp.take_along_axis(lf, labels[..., None], -1)[..., 0]
    return jnp.mean(logz - gold)
