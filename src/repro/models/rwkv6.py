"""RWKV6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

Per block: Time-Mix (token-shift lerp, r/k/v/g projections, LoRA-produced
per-token decay w, WKV recurrence with bonus u) + Channel-Mix (token-shift,
squared-ReLU FFN gated by sigmoid(r)).  The WKV recurrence runs on the shared
chunked-GLA path (``repro.models.ssm``); decode carries per-layer
(shift_tmix, shift_cmix, wkv_state).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..parallel.logical import Logical, param
from . import layers as L
from .ssm import chunked_gla, gla_decode_step
from .transformer import scan_layers, stack_init

LORA_R = 64


def block_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    h = cfg.n_heads
    dk = cfg.hd
    return {
        "ln_t": L.rmsnorm_init(d),
        "ln_c": L.rmsnorm_init(d),
        # time-mix
        "mu_r": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "mu_k": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "mu_v": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "mu_g": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "mu_w": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "wr": param(ks[0], (d, d), ("embed", "heads"), dtype),
        "wk": param(ks[1], (d, d), ("embed", "heads"), dtype),
        "wv": param(ks[2], (d, d), ("embed", "heads"), dtype),
        "wg": param(ks[3], (d, d), ("embed", "heads"), dtype),
        "wo": param(ks[4], (d, d), ("heads", "embed"), dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": Logical(jnp.full((d,), -1.0, jnp.float32), ("act_embed",)),
        "wA": param(ks[5], (d, LORA_R), ("embed", None), dtype, scale=0.01),
        "wB": param(ks[6], (LORA_R, d), (None, "heads"), dtype, scale=0.01),
        "u": Logical(jnp.full((h, dk), 0.5, jnp.float32), ("act_heads", None)),
        "ln_x": L.rmsnorm_init(d),
        # channel-mix
        "mu_ck": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "mu_cr": Logical(jnp.full((d,), 0.5, jnp.float32), ("act_embed",)),
        "ck": param(ks[7], (d, cfg.d_ff), ("embed", "ff"), dtype),
        "cv": param(ks[8], (cfg.d_ff, d), ("ff", "embed"), dtype),
        "cr": param(ks[9], (d, d), ("embed", "heads"), dtype),
    }


def _shift(x: jnp.ndarray, last: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token shift: previous token's features ((B,T,D) -> shifted)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1) \
        if x.shape[1] > 1 else last[:, None, :]


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def time_mix(p, x, cfg, *, state=None):
    """x: (B,T,d).  state: {'shift': (B,d), 'wkv': (B,H,dk,dv)} for decode."""
    b, t, d = x.shape
    h, dk = cfg.n_heads, cfg.hd
    cd = x.dtype
    lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=cd)
    last = state["shift_t"] if state is not None else None
    xs = _shift(x, last)
    r = lin(p["wr"], _lerp(x, xs, p["mu_r"]).astype(cd), out_ax="heads")
    k = lin(p["wk"], _lerp(x, xs, p["mu_k"]).astype(cd), out_ax="heads")
    v = lin(p["wv"], _lerp(x, xs, p["mu_v"]).astype(cd), out_ax="heads")
    g = lin(p["wg"], _lerp(x, xs, p["mu_g"]).astype(cd), out_ax="heads")
    xw = _lerp(x, xs, p["mu_w"]).astype(cd)
    lora = jnp.matmul(jnp.tanh(jnp.matmul(xw, p["wA"].astype(cd))),
                      p["wB"].astype(cd))
    log_w = -jnp.exp(jnp.clip(p["w0"] + lora.astype(jnp.float32), -8.0, 4.0))

    rq = r.reshape(b, t, h, dk)
    kq = k.reshape(b, t, h, dk)
    vq = v.reshape(b, t, h, dk)
    lw = log_w.reshape(b, t, h, dk)
    u = p["u"].value if isinstance(p["u"], Logical) else p["u"]

    if state is None:
        y, s_fin = chunked_gla(rq, kq, vq, lw, u=u, inclusive=False,
                               chunk=cfg.ssm.chunk, remat=cfg.remat)
    else:
        yv, s_fin = gla_decode_step(rq[:, 0], kq[:, 0], vq[:, 0], lw[:, 0],
                                    state["wkv"], u=u, inclusive=False)
        y = yv[:, None]
    new_state = {"shift_t": x[:, -1, :], "wkv": s_fin}
    y = y.reshape(b, t, d)
    y = L.rmsnorm_apply(p["ln_x"], y)
    y = y * jax.nn.silu(g)
    return lin(p["wo"], y, out_ax="embed"), new_state


def channel_mix(p, x, cfg, *, state=None):
    cd = x.dtype
    lin = partial(L.dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=cd)
    last = state["shift_c"] if state is not None else None
    xs = _shift(x, last)
    xk = _lerp(x, xs, p["mu_ck"]).astype(cd)
    xr = _lerp(x, xs, p["mu_cr"]).astype(cd)
    kk = jnp.square(jax.nn.relu(lin(p["ck"], xk, out_ax="ff")))
    out = lin(p["cv"], kk, out_ax="embed")
    out = out * jax.nn.sigmoid(lin(p["cr"], xr, out_ax="embed"))
    return out, {"shift_c": x[:, -1, :]}


def block_apply(p, x, cfg, *, state=None):
    """Returns (x, state') — state' always carries the block's final
    recurrent state (shift_t, shift_c, wkv), so prefill hands exact state to
    decode."""
    t_in = L.rmsnorm_apply(p["ln_t"], x)
    h, st_t = time_mix(p, t_in, cfg, state=state)
    x = x + h
    c_in = L.rmsnorm_apply(p["ln_c"], x)
    h2, st_c = channel_mix(p, c_in, cfg, state=state)
    x = x + h2
    return x, {**st_t, **st_c}


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_params(key, cfg):
    dtype = L.dt(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": L.embedding_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": stack_init(partial(block_init, cfg=cfg, dtype=dtype),
                             layer_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "unembed": {"w": param(ks[2], (cfg.d_model, cfg.vocab_padded),
                               ("embed", "vocab"), dtype)},
    }


def forward_train(p, cfg, batch):
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], batch["tokens"], cd)

    def blk(h, bp):
        h2, _ = block_apply(bp, h, cfg)
        return h2, 0

    x, _ = scan_layers(blk, p["blocks"], x, remat=cfg.remat)
    x = L.rmsnorm_apply(p["ln_f"], x)
    return L.mask_padded_vocab(L.constrain_logits(jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd))), cfg.vocab)


def init_decode_state(cfg, batch: int, cache_len: int = 0):
    """O(1) recurrent state — cache_len is irrelevant (attention-free)."""
    cd = L.dt(cfg.compute_dtype)
    lyr = cfg.n_layers
    d, h, dk = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "shift_t": Logical(jnp.zeros((lyr, batch, d), cd),
                           ("layer", "batch", "act_embed")),
        "shift_c": Logical(jnp.zeros((lyr, batch, d), cd),
                           ("layer", "batch", "act_embed")),
        "wkv": Logical(jnp.zeros((lyr, batch, h, dk, dk), jnp.float32),
                       ("layer", "batch", "act_heads", None, None)),
        "pos": Logical(jnp.zeros((), jnp.int32), ()),
    }


def decode_step(p, cfg, state, tokens, frontend=None):
    """``state`` is a PLAIN array tree."""
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    extra = (state["shift_t"], state["shift_c"], state["wkv"])

    def blk(h, xs):
        bp, (sht, shc, wkv) = xs
        h2, ns = block_apply(bp, h, cfg,
                             state={"shift_t": sht, "shift_c": shc, "wkv": wkv})
        return h2, (ns["shift_t"].astype(sht.dtype), ns["shift_c"], ns["wkv"])

    x, (nst, nsc, nwkv) = scan_layers(blk, p["blocks"], x, remat=False,
                                      extra=extra)
    x = L.rmsnorm_apply(p["ln_f"], x)
    logits = L.mask_padded_vocab(jnp.matmul(x.astype(cd), p["unembed"]["w"].astype(cd)), cfg.vocab)
    new_state = dict(state)
    new_state["shift_t"] = nst
    new_state["shift_c"] = nsc
    new_state["wkv"] = nwkv
    new_state["pos"] = state["pos"] + tokens.shape[1]
    return logits, new_state


def prefill(p, cfg, tokens, cache_len: int = 0, frontend=None):
    """Prefill = chunked-GLA forward; block states (token shifts + final WKV
    state) hand off exactly into decode."""
    from ..parallel.logical import values_of
    cd = L.dt(cfg.compute_dtype)
    x = L.embedding_apply(p["embed"], tokens, cd)
    b = tokens.shape[0]
    state = values_of(init_decode_state(cfg, b))

    def blk(h, bp):
        h2, st = block_apply(bp, h, cfg)
        return h2, (st["shift_t"], st["shift_c"], st["wkv"])

    x_out, (sht, shc, wkv) = scan_layers(blk, p["blocks"], x, remat=cfg.remat)
    x_f = L.rmsnorm_apply(p["ln_f"], x_out)
    logits = L.mask_padded_vocab(jnp.matmul(x_f.astype(cd), p["unembed"]["w"].astype(cd)), cfg.vocab)
    state["shift_t"] = sht.astype(cd)
    state["shift_c"] = shc.astype(cd)
    state["wkv"] = wkv
    state["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, state
