"""Shared neural-net layers (pure JAX, logical-axis params).

Every linear layer routes through the DCIM execution semantics
(``DcimLinear``): at train time weights/activations pass through
straight-through fake-quant at the macro's INT precision (QAT — what you
train is what the macro computes); at serve time the same layer can execute
the true integer path (``repro.kernels.dcim_mac``).

Attention is blockwise (FlashAttention-style online softmax, pure jnp +
lax.scan) so long-context shapes compile with O(q_block x kv_block) live
memory instead of O(S^2): python-unrolled query blocks with *exact* static
causal KV ranges (no wasted quadratic FLOPs — the roofline reads HLO FLOPs).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.logical import Logical, param
from ..parallel.sharding import constrain_act
from ..quant import fake_quant

# weight out-axis -> activation logical axis (for constrain_act)
_ACT_OF = {"heads": "act_heads", "kv_heads": "act_heads", "ff": "act_ff",
           "embed": "act_embed", "vocab": "act_vocab"}

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# DCIM linear
# ---------------------------------------------------------------------------


def dcim_linear_init(key, d_in: int, d_out: int, in_axis: str, out_axis: str,
                     dtype, scale: float | None = None) -> dict:
    return {"w": param(key, (d_in, d_out), (in_axis, out_axis), dtype,
                       scale=scale)}


def dcim_linear_apply(w: jnp.ndarray, x: jnp.ndarray, *, a_bits: int = 8,
                      w_bits: int = 8, enabled: bool = True,
                      compute_dtype=jnp.bfloat16,
                      out_ax: str | None = None) -> jnp.ndarray:
    """y = x @ W under DCIM QAT semantics.

    Weights fake-quantized per-output-channel (columns live in macro columns),
    activations per-token (rows stream bit-serially) — gradients pass straight
    through.  ``enabled=False`` gives the plain (non-paper baseline) linear.
    ``out_ax``: logical axis of the output features — drives the activation
    sharding constraint (no-op unless cfg.act_shard armed the context).
    """
    x = x.astype(compute_dtype)
    w = w.astype(compute_dtype)
    if enabled:
        w = fake_quant(w, w_bits, 0)      # per-out-channel (axis 0 = d_in dim reduced)
        x = fake_quant(x, a_bits, -1)     # per-token
    y = jnp.matmul(x, w)
    if out_ax is not None and y.ndim == 3:
        y = constrain_act(y, ("batch", "seq", _ACT_OF.get(out_ax, out_ax)))
    return y


# ---------------------------------------------------------------------------
# Norms / embeddings / rotary
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, axis: str = "act_embed") -> dict:
    return {"g": Logical(jnp.ones((d,), jnp.float32), (axis,))}


def rmsnorm_apply(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["g"]).astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    # d^-1/2 init keeps tied-embedding logits ~N(0,1) at init (CE ~= ln V).
    return {"emb": param(key, (vocab, d), ("vocab", "embed"), dtype,
                         scale=d ** -0.5)}


def embedding_apply(p: dict, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    out = p["emb"].astype(compute_dtype)[tokens]
    return constrain_act(out, ("batch", "seq", "act_embed"))


def constrain_logits(logits: jnp.ndarray) -> jnp.ndarray:
    return constrain_act(logits, ("batch", "seq", "act_vocab"))


def mask_padded_vocab(logits: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Neutralize sharding-padding columns WITHOUT slicing (a slice on the
    model-sharded vocab dim would force an all-gather of the logits)."""
    vp = logits.shape[-1]
    if vp == vocab:
        return logits
    mask = jnp.arange(vp) < vocab
    neg = jnp.asarray(jnp.finfo(jnp.float32).min, logits.dtype)
    return jnp.where(mask, logits, neg)


def unembed_apply(p: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    out = jnp.matmul(x.astype(compute_dtype),
                     p["emb"].astype(compute_dtype).T)
    return constrain_act(out, ("batch", "seq", "act_vocab"))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, half)
    ang = ang[..., None, :]                                     # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (memory-efficient, causal-exact)
# ---------------------------------------------------------------------------


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D)."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)) \
        .reshape(b, s, h * groups, d)


def _attn_block(q, k, v, mask) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One (qb x kvb) tile: returns (m, l, acc) online-softmax stats.

    q: (B, H, qb, D); k, v: (B, H, kvb, D); mask: (qb, kvb) or None.
    """
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                            # (B,H,qb)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
    return m, l, acc


def _merge_stats(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    l = l1 * e1 + l2 * e2
    a = a1 * e1[..., None].astype(a1.dtype) + a2 * e2[..., None].astype(a2.dtype)
    return m, l, a


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool, q_block: int, kv_block: int,
                        q_offset: int = 0) -> jnp.ndarray:
    """FlashAttention-style attention in pure jnp.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    Causal semantics: query position i (+q_offset) attends keys <= i+q_offset.
    Query blocks unroll in python with exact static causal KV extents; KV
    blocks run under lax.scan with online-softmax merging.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scale = 1.0 / math.sqrt(d)
    qt = jnp.swapaxes(q, 1, 2) * scale       # (B,H,Sq,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    n_q = (sq + q_block - 1) // q_block
    outs = []
    for qi in range(n_q):
        q0 = qi * q_block
        qb = min(q_block, sq - q0)
        q_tile = lax.slice_in_dim(qt, q0, q0 + qb, axis=2)
        if causal:
            hi = min(skv, q0 + qb + q_offset)      # last key visible
        else:
            hi = skv
        n_kv = (hi + kv_block - 1) // kv_block
        if n_kv == 0:
            outs.append(jnp.zeros_like(q_tile))
            continue

        def kv_step(carry, ki, q_tile=q_tile, q0=q0, qb=qb, hi=hi):
            m, l, acc = carry
            k0 = ki * kv_block
            k_tile = lax.dynamic_slice_in_dim(kt, k0, kv_block, axis=2)
            v_tile = lax.dynamic_slice_in_dim(vt, k0, kv_block, axis=2)
            kpos = k0 + jnp.arange(kv_block)
            valid = kpos < hi
            if causal:
                qpos = q0 + q_offset + jnp.arange(qb)
                mask = valid[None, :] & (kpos[None, :] <= qpos[:, None])
            else:
                mask = jnp.broadcast_to(valid[None, :], (qb, kv_block))
            m2, l2, a2 = _attn_block(q_tile, k_tile, v_tile, mask)
            return _merge_stats(m, l, acc, m2, l2, a2), None

        init = (jnp.full((b, hq, qb), -1e30, jnp.float32),
                jnp.zeros((b, hq, qb), jnp.float32),
                jnp.zeros((b, hq, qb, d), qt.dtype))
        (m, l, acc), _ = lax.scan(kv_step, init, jnp.arange(n_kv))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype))
    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    return jnp.swapaxes(out, 1, 2)            # (B,Sq,Hq,D)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": param(ks[0], (d, cfg.n_heads * hd), ("embed", "heads"), dtype),
        "wk": param(ks[1], (d, cfg.n_kv_heads * hd), ("embed", "kv_heads"), dtype),
        "wv": param(ks[2], (d, cfg.n_kv_heads * hd), ("embed", "kv_heads"), dtype),
        "wo": param(ks[3], (cfg.n_heads * hd, d), ("heads", "embed"), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def attention_apply(p: dict, x: jnp.ndarray, cfg, *, positions: jnp.ndarray,
                    causal: bool = True, kv_cache: dict | None = None,
                    cache_pos: jnp.ndarray | None = None,
                    kv_override: tuple | None = None,
                    prefill_fill: bool = False) -> tuple[jnp.ndarray, dict | None]:
    """x: (B, S, d).

    Modes:
      * plain (kv_cache=None): blockwise attention over local K/V.
      * prefill (kv_cache + prefill_fill): blockwise attention *and* the
        computed K/V written into the cache at position 0.
      * decode (kv_cache, prefill_fill=False): append K/V at ``cache_pos``,
        attend over the cache (GQA-grouped einsum — no repeated-KV tensor).
    ``kv_override`` supplies external K/V (cross-attention).
    """
    b, s, d = x.shape
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    lin = partial(dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=x.dtype)
    q = lin(p["wq"], x, out_ax="heads").reshape(b, s, hq, hd)
    if kv_override is None:
        k = lin(p["wk"], x, out_ax="kv_heads").reshape(b, s, hkv, hd)
        v = lin(p["wv"], x, out_ax="kv_heads").reshape(b, s, hkv, hd)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    if kv_override is None and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    elif positions is not None:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and prefill_fill:
        ck = lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                      (0, 0, 0, 0))
        cv = lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                      (0, 0, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = blockwise_attention(q, k, v, causal=causal,
                                  q_block=cfg.attn_q_block,
                                  kv_block=cfg.attn_kv_block)
    elif kv_cache is not None:
        # Decode: write the new K/V into the cache at cache_pos, attend over
        # everything written so far (mask handles the tail).  GQA einsum keeps
        # KV un-repeated: q regrouped to (b, s, hkv, g, hd).
        ck, cv = kv_cache["k"], kv_cache["v"]
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        qg = q.reshape(b, s, hkv, g, hd) / math.sqrt(hd)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck.astype(x.dtype))
        tpos = jnp.arange(ck.shape[1])
        mask = tpos[None, :] <= (cache_pos + jnp.arange(s))[:, None]
        scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                           -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", w, cv.astype(x.dtype))
        out = out.reshape(b, s, hq, hd)
    else:
        # Gather K/V across any sequence sharding ONCE, before the q-block
        # loop (otherwise every q block re-gathers them — measured 316 GiB
        # vs 14 GiB per step on seq-parallel 32k prefill).
        k = constrain_act(k, ("batch", "attn_kv_seq", "act_heads", None))
        v = constrain_act(v, ("batch", "attn_kv_seq", "act_heads", None))
        out = blockwise_attention(q, k, v, causal=causal,
                                  q_block=cfg.attn_q_block,
                                  kv_block=cfg.attn_kv_block)
    out = out.reshape(b, s, hq * hd)
    y = lin(p["wo"], out, out_ax="embed")
    return y, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": param(ks[0], (d, ff), ("embed", "ff"), dtype),
        "w_up": param(ks[1], (d, ff), ("embed", "ff"), dtype),
        "w_down": param(ks[2], (ff, d), ("ff", "embed"), dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    lin = partial(dcim_linear_apply, a_bits=cfg.dcim_a_bits,
                  w_bits=cfg.dcim_w_bits, enabled=cfg.dcim_enabled,
                  compute_dtype=x.dtype)
    g = lin(p["w_gate"], x, out_ax="ff")
    u = lin(p["w_up"], x, out_ax="ff")
    return lin(p["w_down"], jax.nn.silu(g) * u, out_ax="embed")
