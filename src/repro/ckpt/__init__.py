from .manager import CheckpointManager, load_checkpoint, save_checkpoint
from .elastic import reshard_tree
from .failures import FailureInjector, run_with_restarts

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint",
           "reshard_tree", "FailureInjector", "run_with_restarts"]
