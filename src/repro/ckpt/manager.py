"""Checkpointing: atomic, sharded, async-capable, keep-k.

Layout (one directory per step):

    <root>/step_000100/
        manifest.json            # tree structure, shapes, dtypes, step meta
        shard_00000.npz          # flat-index -> array chunks for this host

Writes go to ``<dir>.tmp`` then ``os.rename`` (atomic on POSIX) so a crash
mid-write never corrupts the latest checkpoint — the restart scan only
considers directories with a valid manifest.  ``async_save`` runs the
serialize+rename on a background thread (training continues; ``wait()``
fences — the fence doubles as the straggler-mitigation point: a host that
cannot finish its shard within the fence timeout is declared failed and the
job restarts elastically from the previous step, see failures.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(root: str | Path, step: int, tree, host_id: int = 0,
                    meta: dict | None = None) -> Path:
    root = Path(root)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / f"shard_{host_id:05d}.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "time": time.time(),
        "meta": meta or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def load_checkpoint(root: str | Path, tree_like, step: int | None = None,
                    host_id: int = 0):
    """Restore into the structure of ``tree_like``; returns (tree, step)."""
    root = Path(root)
    if step is None:
        steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                       if (p / "manifest.json").exists())
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {root}")
        step = steps[-1]
    d = root / f"step_{step:08d}"
    data = np.load(d / f"shard_{host_id:05d}.npz")
    leaves, treedef = _flatten(tree_like)
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    return treedef.unflatten(new_leaves), step


class CheckpointManager:
    """keep-k GC + async save + restart discovery."""

    def __init__(self, root: str | Path, keep: int = 3, host_id: int = 0):
        self.root = Path(root)
        self.keep = keep
        self.host_id = host_id
        self._thread: threading.Thread | None = None
        self.root.mkdir(parents=True, exist_ok=True)

    # -------------------------------------------------------------- sync api
    def save(self, step: int, tree, meta: dict | None = None) -> Path:
        p = save_checkpoint(self.root, step, tree, self.host_id, meta)
        self._gc()
        return p

    def async_save(self, step: int, tree, meta: dict | None = None):
        # snapshot to host memory NOW so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=lambda: (save_checkpoint(self.root, step, host_tree,
                                            self.host_id, meta), self._gc()),
            daemon=True)
        self._thread.start()

    def wait(self, timeout: float | None = None) -> bool:
        """Fence: returns False if the save straggled past ``timeout``."""
        if self._thread is not None:
            self._thread.join(timeout)
            alive = self._thread.is_alive()
            if not alive:
                self._thread = None
            return not alive
        return True

    def restore(self, tree_like, step: int | None = None):
        return load_checkpoint(self.root, tree_like, step, self.host_id)

    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.root.glob("step_*")
                       if (p / "manifest.json").exists())
        return steps[-1] if steps else None

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.root.glob("step_*")
                       if (p / "manifest.json").exists())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
