"""Elastic re-meshing: move a checkpoint onto a different mesh.

When nodes fail (or capacity grows), the job restarts with a different device
count; parameters saved under one sharding must load under another.  Because
checkpoints here store *global* arrays (np.savez of the full tree) and
shardings are recomputed from the logical rules for whatever mesh exists at
restore time, resharding is a pure placement operation:

    tree' = jax.device_put(tree, NamedSharding(new_mesh, spec))

`reshard_tree` performs exactly that, per-leaf.  The elasticity drill in
tests/test_distributed.py saves from an 8-device mesh and restores onto
4- and 2-device meshes, verifying bit-identical values.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding


def reshard_tree(tree, mesh: Mesh, spec_tree):
    """Place (host or device) arrays onto ``mesh`` with per-leaf specs."""
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        tree, spec_tree)
