"""Failure injection + restart-driver: the fault-tolerance drill.

``run_with_restarts`` is the production control loop in miniature: run the
step function; on (injected or real) failure, tear down, restore the latest
checkpoint, and continue — bounded by ``max_restarts``.  Determinism of the
data pipeline (counter-based batches) makes the restart exactly-once.

Straggler mitigation: the checkpoint fence (CheckpointManager.wait with
timeout) bounds how long a slow host can hold the job; on fence timeout the
driver treats it as a failure and restarts on the surviving capacity
(elastic re-mesh).  DCN-scale notes in DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given steps (once each)."""

    fail_at: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_restarts(step_fn: Callable[[int, dict], dict], state: dict,
                      n_steps: int, manager, save_every: int = 10,
                      injector: FailureInjector | None = None,
                      max_restarts: int = 3) -> dict:
    """Drive ``step_fn`` with checkpoint/restart.

    step_fn(step, state) -> state.  ``state`` must be a checkpointable pytree
    with an integer ``state['step']``.
    """
    restarts = 0
    step = int(state["step"])
    while step < n_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            state = step_fn(step, state)
            step += 1
            state["step"] = step
            if step % save_every == 0 or step == n_steps:
                manager.save(step, state)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            last = manager.latest_step()
            if last is None:
                step = 0
                state["step"] = 0
                continue
            state, step = manager.restore(state, last)
            step = int(state["step"])
    state["restarts"] = restarts
    return state
