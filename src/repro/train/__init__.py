from .step import TrainState, make_train_step, make_loss_fn

__all__ = ["TrainState", "make_train_step", "make_loss_fn"]
