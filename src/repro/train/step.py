"""Distributed train step: loss -> grad -> AdamW, with optional gradient
microbatching (accumulation) and optional int8 error-feedback gradient
compression across the 'pod' axis.

The step is a pure function (params, opt_state, batch) -> (params, opt_state,
metrics); sharding comes entirely from pjit in/out shardings derived from the
logical axis rules — the same step runs on 1 CPU device or a 512-chip
multi-pod mesh.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.registry import ModelApi, cross_entropy_loss
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

AUX_COEF = 0.01     # MoE load-balance loss weight


class TrainState(dict):
    """params + opt_state + step, as a plain dict pytree."""


def make_loss_fn(api: ModelApi) -> Callable:
    def loss_fn(params, batch):
        logits, aux = api.forward_train(params, batch)
        ce = cross_entropy_loss(logits, batch["labels"])
        return ce + AUX_COEF * aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(api: ModelApi, lr_schedule: Callable,
                    adamw_cfg: AdamWConfig = AdamWConfig(),
                    microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatches > 1``: the global batch splits on the leading axis and
    grads accumulate under a lax.scan (activation memory / HBM trade)."""
    loss_fn = make_loss_fn(api)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads

        def split(x):
            b = x.shape[0]
            mb = b // microbatches
            return x.reshape(microbatches, mb, *x.shape[1:])

        mbatch = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc_step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, aux), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                grads_acc, grads)
            return (loss_acc + loss / microbatches, grads_acc), aux

        (loss, grads), auxes = jax.lax.scan(acc_step, (0.0, zero), mbatch)
        aux = jax.tree.map(lambda a: a[-1], auxes)
        return loss, aux, grads

    def train_step(params, opt_state, batch):
        loss, aux, grads = compute_grads(params, batch)
        lr = lr_schedule(opt_state["count"])
        params, opt_state, om = adamw_update(grads, opt_state, params, lr,
                                             adamw_cfg)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **om}
        return params, opt_state, metrics

    return train_step


def init_train_state(api: ModelApi, key) -> tuple[Any, Any]:
    from ..parallel.logical import values_of
    params = values_of(api.init_params(key))
    return params, adamw_init(params)
