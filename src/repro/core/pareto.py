"""Pareto-frontier utilities (paper §III-C / Fig. 8).

The MSO searcher emits a *set* of design points; the compiler returns those on
the Pareto frontier of (power, area, latency) under the throughput constraint,
"to be finally chosen based on defined PPA preferences or user selection".

Extraction scales in three tiers, all computing the exact same eps-band
verdicts (bit-identical masks, same output order):

  :func:`nondominated_mask`          host numpy, two-phase exact (block-local
                                     prefilter, then every local survivor is
                                     refined against *all* rows);
  :func:`repro.core.batched.pareto_mask`
                                     the same chunked predicate on one device;
  :func:`nondominated_mask_sharded`  jitted map-reduce across every visible
                                     device — per-shard local frontier,
                                     gather survivors, cross-shard refinement
                                     (lattice-scale frontiers).

This module stays importable without jax (the scalar compiler layer is
numpy-only); the sharded path imports jax lazily on first use.
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

import numpy as np

T = TypeVar("T")

#: Shared tie/epsilon band for dominance comparisons and duplicate collapse.
#: Every frontier in the repo — the scalar :func:`dominates` /
#: :func:`pareto_indices` path, the batched engine's chunked
#: ``pareto_mask``, and the multi-spec extraction — compares through this one
#: constant, so near-tie objectives land on the *same* frontier no matter
#: which path evaluated them.  The band is absolute: an objective whose scale
#: approaches it (e.g. period in seconds, ~1e-9) effectively gets a relative
#: tolerance.
PARETO_EPS = 1e-12


def dominates(a: Sequence[float], b: Sequence[float],
              eps: float = PARETO_EPS) -> bool:
    """True if objective vector ``a`` Pareto-dominates ``b`` (all <=, one <,
    with the shared ``eps`` tie band).  Objectives are minimized."""
    le = all(x <= y + eps for x, y in zip(a, b))
    lt = any(x < y - eps for x, y in zip(a, b))
    return le and lt


def chunk_dominated(all_o, blk, eps, xp=np):
    """Eps-band dominance verdicts for one chunk: entry ``i`` is True iff
    some row of ``all_o`` dominates ``blk[i]`` under exactly the
    :func:`dominates` semantics.  This is the *single* implementation of the
    vectorized predicate — :func:`nondominated_mask` runs it on numpy, the
    batched engine's ``pareto_mask`` passes ``xp=jax.numpy`` to run the same
    comparisons on device, and :func:`nondominated_mask_sharded` vmaps it
    across device shards."""
    c, k = blk.shape
    n = all_o.shape[0]
    le = xp.ones((c, n), dtype=bool)
    lt = xp.zeros((c, n), dtype=bool)
    for d in range(k):
        le = le & (all_o[None, :, d] <= blk[:, None, d] + eps)
        lt = lt | (all_o[None, :, d] < blk[:, None, d] - eps)
    return (le & lt).any(axis=1)


def _as_matrix(objs) -> np.ndarray:
    objs = np.asarray(objs, dtype=np.float64)
    if objs.ndim == 1:
        objs = objs[:, None]
    return objs


def nondominated_mask(objs, eps: float = PARETO_EPS,
                      chunk: int = 1024) -> np.ndarray:
    """Boolean non-dominated mask over an (n, k) objective matrix
    (minimization), vectorized and chunked.  Entry ``i`` is True iff no row
    dominates row ``i`` under exactly the :func:`dominates` semantics — this
    is the single dominance predicate :func:`pareto_indices` and the batched
    engine's ``pareto_mask`` both reduce to.

    Runs as a two-phase exact pass: phase 1 tests each block only against
    itself (a point dominated inside its own block is dominated, full stop —
    the witness is a real row), phase 2 refines every local survivor against
    *all* rows.  Because eps-band dominance is not transitive, the refinement
    deliberately compares against every row, not just other survivors; the
    resulting mask is identical to the naive all-pairs pass at a fraction of
    the cost (frontiers are small, so few points reach phase 2)."""
    objs = _as_matrix(objs)
    n = objs.shape[0]
    keep = np.ones(n, dtype=bool)
    if n == 0:
        return keep
    for start in range(0, n, chunk):
        blk = objs[start:start + chunk]                 # (c, k)
        keep[start:start + blk.shape[0]] = ~chunk_dominated(blk, blk, eps)
    survivors = np.flatnonzero(keep)
    for start in range(0, survivors.size, chunk):
        idx = survivors[start:start + chunk]
        keep[idx] = ~chunk_dominated(objs, objs[idx], eps)
    return keep


#: Default device-memory budget for one Pareto chunk's comparison masks.
DEFAULT_PARETO_BUDGET_BYTES = 256 * 1024 * 1024


def pareto_chunk_size(n_points: int, n_objectives: int = 3,
                      budget_bytes: int = DEFAULT_PARETO_BUDGET_BYTES) -> int:
    """Chunk size for the chunked Pareto masks such that the peak comparison
    footprint fits the accelerator budget.

    One chunk row holds the ``le``/``lt`` masks plus one comparison temp per
    objective against all ``n_points`` columns (~1 byte each), so a chunk
    costs about ``chunk * n_points * (2 + n_objectives)`` bytes."""
    per_row = max(1, n_points) * (2 + max(1, n_objectives))
    chunk = budget_bytes // per_row
    return int(min(max(chunk, 64), max(n_points, 64)))


# ---------------------------------------------------------------------------
# Device-sharded extraction (lattice-scale frontiers)
# ---------------------------------------------------------------------------

#: Below this point count the host pass wins on dispatch overhead; the auto
#: dispatcher only reaches for devices at or above it.
SHARDED_EXTRACT_MIN_POINTS = 8192

_SHARDED_FNS: dict | None = None


def _sharded_fns() -> dict:
    """Lazily built jax closures for the sharded extraction (this module
    stays importable without jax)."""
    global _SHARDED_FNS
    if _SHARDED_FNS is None:
        import jax
        import jax.numpy as jnp

        def _chunk(all_o, blk, eps):
            return chunk_dominated(all_o, blk, eps, xp=jnp)

        _SHARDED_FNS = {
            "jax": jax,
            "jnp": jnp,
            # per-shard local pass (one block per shard vs itself), vmapped
            # over the shard axis; under a NamedSharding placement XLA
            # partitions the shards across devices
            "local": jax.jit(jax.vmap(_chunk, in_axes=(0, 0, None))),
            # cross-shard refinement: per-device survivor blocks vs ALL rows
            # (the row matrix is replicated, the survivor axis is sharded)
            "refine": jax.jit(jax.vmap(_chunk, in_axes=(None, 0, None))),
            # the same passes as explicit pmaps for runtimes whose
            # jax.sharding surface is incomplete
            "local_pmap": jax.pmap(_chunk, in_axes=(0, 0, None)),
            "refine_pmap": jax.pmap(_chunk, in_axes=(None, 0, None)),
        }
    return _SHARDED_FNS


def nondominated_mask_sharded(objs, eps: float = PARETO_EPS,
                              chunk: int | None = None,
                              mode: str = "auto", mesh=None) -> np.ndarray:
    """Device-sharded :func:`nondominated_mask`: the jitted map-reduce
    dominance pass for lattice-scale frontiers.

    The rows are split into one contiguous shard per visible device and the
    verdict is computed in two exact phases, both running the shared
    :func:`chunk_dominated` predicate with ``xp=jax.numpy``:

      1. *per-shard local prefilter* — each shard walks its rows in blocks,
         testing each block against itself; a row dominated inside its own
         block is dominated, full stop (the witness is a real row).  The
         shard axis is placed with a ``Mesh``/``NamedSharding`` (``mode=
         "jit"``) or ``jax.pmap`` (``mode="pmap"``; ``"auto"`` resolves
         through the engine's capability-probed dispatcher), so the
         quadratic work parallelizes across devices;
      2. *cross-shard refinement* — the gathered local survivors are
         re-tested against **all** rows, survivor axis sharded over the same
         devices, row matrix replicated.  Eps-band dominance is not
         transitive, so testing survivors only against other survivors would
         not be exact; testing against every row is, because every point a
         shard eliminated locally already has a real dominating witness.

    The result is bit-identical to the host :func:`nondominated_mask` — same
    :data:`PARETO_EPS` band, same verdict per row, same output order — on 1
    device and on N devices; only the wall-clock changes.

    ``mesh`` (``"jit"`` mode) is the 1-D device mesh to place the shard axis
    over — pass the mesh a sweep evaluated on so extraction honors the same
    device subset; default is the repo's shared sweep mesh over every
    visible device (:func:`repro.parallel.sharding.spec_sweep_mesh`)."""
    objs = _as_matrix(objs)
    n, k = objs.shape
    if n == 0:
        return np.ones(0, dtype=bool)
    from . import engine as E          # lazy: the one mode dispatcher
    mode = E.resolve_sharded_mode(mode)
    fns = _sharded_fns()
    jax, jnp = fns["jax"], fns["jnp"]
    from jax.experimental import enable_x64

    if mode == "jit" and mesh is None:
        # the shared 1-D placement the sharded sweeps use, not an ad-hoc one
        from ..parallel.sharding import spec_sweep_mesh
        mesh = spec_sweep_mesh()
    n_dev = (int(mesh.devices.size) if mesh is not None
             else len(jax.devices()))
    m = -(-n // n_dev)                               # rows per shard
    c = int(chunk) if chunk else max(64, min(1024, m))
    m_p = -(-m // c) * c                             # fixed block shapes
    pad_rows = m_p * n_dev - n
    # +inf padding is inert under the eps band: an inf row never dominates a
    # finite row, and pad verdicts are sliced off before they are read.
    padded = (np.concatenate([objs, np.full((pad_rows, k), np.inf)])
              if pad_rows else objs)
    shards = padded.reshape(n_dev, m_p, k)

    dominated = np.empty(n_dev * m_p, dtype=bool)
    with enable_x64():
        eps_j = jnp.asarray(eps, dtype=jnp.float64)
        if mode == "jit":
            from jax.sharding import NamedSharding, PartitionSpec
            row_sharded = NamedSharding(mesh,
                                        PartitionSpec(mesh.axis_names[0]))
            shards_dev = jax.device_put(jnp.asarray(shards), row_sharded)
            local = fns["local"]
            refine = fns["refine"]
            blocks = [shards_dev[:, s:s + c] for s in range(0, m_p, c)]
        else:
            local = fns["local_pmap"]
            refine = fns["refine_pmap"]
            blocks = [shards[:, s:s + c] for s in range(0, m_p, c)]
        parts = [local(blk, blk, eps_j) for blk in blocks]
        dominated[:] = np.concatenate(
            [np.asarray(p) for p in parts], axis=1).reshape(n_dev * m_p)
        dominated = dominated[:n]

        survivors = np.flatnonzero(~dominated)
        all_rows = jnp.asarray(objs)
        if mode == "jit":
            all_rows = jax.device_put(
                all_rows, NamedSharding(mesh, PartitionSpec()))  # replicated
        # survivor blocks sized so each device's comparison masks fit its
        # slice of the memory budget
        cr = max(64, pareto_chunk_size(
            n, k, DEFAULT_PARETO_BUDGET_BYTES // n_dev))
        stride = n_dev * cr
        for start in range(0, survivors.size, stride):
            idx = survivors[start:start + stride]
            blk = objs[idx]
            if blk.shape[0] < stride:                # keep one traced shape
                blk = np.concatenate(
                    [blk, np.full((stride - blk.shape[0], k), np.inf)])
            blk = blk.reshape(n_dev, cr, k)
            if mode == "jit":
                blk = jax.device_put(jnp.asarray(blk), row_sharded)
            verdict = np.asarray(refine(all_rows, blk, eps_j))
            dominated[idx] = verdict.reshape(stride)[:idx.size]
    return ~dominated


def nondominated_mask_auto(objs, eps: float = PARETO_EPS) -> np.ndarray:
    """Host mask below the sharding payoff point (or on a single device /
    without jax), the device-sharded map-reduce above it.  Both produce the
    same bits, so callers may switch freely on scale."""
    objs = _as_matrix(objs)
    if objs.shape[0] >= SHARDED_EXTRACT_MIN_POINTS:
        # Only the jax probe is guarded: a failure *inside* the sharded pass
        # (device OOM, a sharding regression) must surface, not silently
        # degrade to the slow host walk.
        try:
            import jax
            n_dev = len(jax.devices())
        except Exception:
            n_dev = 1
        if n_dev > 1:
            return nondominated_mask_sharded(objs, eps)
    return nondominated_mask(objs, eps)


def pareto_indices(objs: Sequence[Sequence[float]],
                   mask_fn: Callable[[np.ndarray], np.ndarray] | None = None
                   ) -> list[int]:
    """Indices of the non-dominated, deduplicated members of ``objs``, sorted
    by objective tuple.  This is the single source of truth for frontier
    semantics: :func:`pareto_front` and the batched engine's vectorized
    extraction both reduce to it, so scalar and batched sweeps agree exactly.

    Dominance testing delegates to the vectorized :func:`nondominated_mask`
    (the per-pair Python walk was O(N^2) and hung at lattice scale); callers
    at lattice scale may pass ``mask_fn=nondominated_mask_auto`` (or the
    sharded mask directly) — every mask implementation returns the same bits.
    The documented output order is preserved exactly: near-duplicates (all
    coordinates within :data:`PARETO_EPS`) keep their first occurrence in
    input order, and the surviving set is sorted by objective tuple."""
    objs = list(objs)
    if not objs:
        return []
    arr = np.asarray([[float(x) for x in o] for o in objs], dtype=np.float64)
    survivors = np.flatnonzero((mask_fn or nondominated_mask)(arr))
    # Dedup in input order against the accepted set (vectorized per survivor,
    # matching the incremental semantics of the original Python walk).
    acc = np.empty((survivors.size, arr.shape[1]), dtype=np.float64)
    n_acc = 0
    front: list[tuple[Sequence[float], int]] = []
    for i in survivors:
        o = arr[i]
        if n_acc and (np.abs(acc[:n_acc] - o) < PARETO_EPS).all(axis=1).any():
            continue
        acc[n_acc] = o
        n_acc += 1
        front.append((objs[i], int(i)))
    front.sort(key=lambda oi: tuple(oi[0]))
    return [i for _, i in front]


def merged_pareto_indices(parent_idx: Sequence[int],
                          objs: Sequence[Sequence[float]],
                          mask_fn: Callable[[np.ndarray], np.ndarray]
                          | None = None) -> list[int]:
    """:func:`pareto_indices` over a pool assembled from several lattice
    *slices* (the incremental re-synthesis merge): candidate ``i`` carries the
    flat index ``parent_idx[i]`` of the design point in the parent lattice.

    Rows are visited in ascending parent-flat-index order before extraction,
    so the near-duplicate collapse keeps the *same representative* a cold
    full-lattice pass would keep (that pass visits points in flat order) — no
    matter how the pool was partitioned into slices or in which order the
    slices arrived.  Returns positions into the pool as given, frontier
    sorted by objective tuple, exactly like :func:`pareto_indices`.  A pool
    whose slices are disjoint in parent index (the incremental contract)
    therefore merges bit-identically to extracting the union in one pass."""
    parent_idx = np.asarray(parent_idx, dtype=np.int64)
    objs = list(objs)
    if len(parent_idx) != len(objs):
        raise ValueError("parent_idx must match objs one-to-one")
    order = np.argsort(parent_idx, kind="stable")
    picked = pareto_indices([objs[int(j)] for j in order], mask_fn=mask_fn)
    return [int(order[p]) for p in picked]


def pareto_front(items: Iterable[T], objectives: Callable[[T], Sequence[float]]
                 ) -> list[T]:
    """Filter ``items`` to the non-dominated set, stably ordered by the first
    objective."""
    items = list(items)
    objs = [objectives(it) for it in items]
    return [items[i] for i in pareto_indices(objs)]


def scalarize(weights: Sequence[float], objectives: Sequence[float],
              refs: Sequence[float]) -> float:
    """Weighted-sum scalarization with reference normalization (used to pick a
    single design for a PPA preference)."""
    return sum(w * (o / max(r, 1e-30))
               for w, o, r in zip(weights, objectives, refs))


def preference_grid(resolution: int = 4) -> list[tuple[float, float, float]]:
    """Deterministic simplex grid over (power, area, throughput) preference
    weights — the multi-spec sweep driving the searcher.

    ``resolution`` must be >= 1: a 0-resolution grid would be empty and every
    sweep built on it would silently synthesize nothing."""
    if resolution < 1:
        raise ValueError(
            f"preference_grid needs resolution >= 1, got {resolution}: an "
            "empty grid silently yields empty sweeps downstream")
    out = []
    for a in range(resolution + 1):
        for b in range(resolution + 1 - a):
            c = resolution - a - b
            if a == b == c == 0:
                continue
            out.append((a / resolution, b / resolution, c / resolution))
    return out
