"""Pareto-frontier utilities (paper §III-C / Fig. 8).

The MSO searcher emits a *set* of design points; the compiler returns those on
the Pareto frontier of (power, area, latency) under the throughput constraint,
"to be finally chosen based on defined PPA preferences or user selection".
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

T = TypeVar("T")


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True if objective vector ``a`` Pareto-dominates ``b`` (all <=, one <).
    Objectives are minimized."""
    le = all(x <= y + 1e-12 for x, y in zip(a, b))
    lt = any(x < y - 1e-12 for x, y in zip(a, b))
    return le and lt


def pareto_indices(objs: Sequence[Sequence[float]]) -> list[int]:
    """Indices of the non-dominated, deduplicated members of ``objs``, sorted
    by objective tuple.  This is the single source of truth for frontier
    semantics: :func:`pareto_front` and the batched engine's vectorized
    extraction both reduce to it, so scalar and batched sweeps agree exactly."""
    pts = list(enumerate(objs))
    front: list[tuple[Sequence[float], int]] = []
    for i, obj in pts:
        if any(dominates(o2, obj) for _, o2 in pts):
            continue
        # drop exact duplicates
        if any(all(abs(x - y) < 1e-12 for x, y in zip(obj, o2))
               for o2, _ in front):
            continue
        front.append((obj, i))
    front.sort(key=lambda oi: tuple(oi[0]))
    return [i for _, i in front]


def pareto_front(items: Iterable[T], objectives: Callable[[T], Sequence[float]]
                 ) -> list[T]:
    """Filter ``items`` to the non-dominated set, stably ordered by the first
    objective."""
    items = list(items)
    objs = [objectives(it) for it in items]
    return [items[i] for i in pareto_indices(objs)]


def scalarize(weights: Sequence[float], objectives: Sequence[float],
              refs: Sequence[float]) -> float:
    """Weighted-sum scalarization with reference normalization (used to pick a
    single design for a PPA preference)."""
    return sum(w * (o / max(r, 1e-30))
               for w, o, r in zip(weights, objectives, refs))


def preference_grid(resolution: int = 4) -> list[tuple[float, float, float]]:
    """Deterministic simplex grid over (power, area, throughput) preference
    weights — the multi-spec sweep driving the searcher."""
    out = []
    for a in range(resolution + 1):
        for b in range(resolution + 1 - a):
            c = resolution - a - b
            if a == b == c == 0:
                continue
            out.append((a / resolution, b / resolution, c / resolution))
    return out
