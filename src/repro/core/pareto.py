"""Pareto-frontier utilities (paper §III-C / Fig. 8).

The MSO searcher emits a *set* of design points; the compiler returns those on
the Pareto frontier of (power, area, latency) under the throughput constraint,
"to be finally chosen based on defined PPA preferences or user selection".
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

import numpy as np

T = TypeVar("T")

#: Shared tie/epsilon band for dominance comparisons and duplicate collapse.
#: Every frontier in the repo — the scalar :func:`dominates` /
#: :func:`pareto_indices` path, the batched engine's chunked
#: ``pareto_mask``, and the multi-spec extraction — compares through this one
#: constant, so near-tie objectives land on the *same* frontier no matter
#: which path evaluated them.  The band is absolute: an objective whose scale
#: approaches it (e.g. period in seconds, ~1e-9) effectively gets a relative
#: tolerance.
PARETO_EPS = 1e-12


def dominates(a: Sequence[float], b: Sequence[float],
              eps: float = PARETO_EPS) -> bool:
    """True if objective vector ``a`` Pareto-dominates ``b`` (all <=, one <,
    with the shared ``eps`` tie band).  Objectives are minimized."""
    le = all(x <= y + eps for x, y in zip(a, b))
    lt = any(x < y - eps for x, y in zip(a, b))
    return le and lt


def chunk_dominated(all_o, blk, eps, xp=np):
    """Eps-band dominance verdicts for one chunk: entry ``i`` is True iff
    some row of ``all_o`` dominates ``blk[i]`` under exactly the
    :func:`dominates` semantics.  This is the *single* implementation of the
    vectorized predicate — :func:`nondominated_mask` runs it on numpy and the
    batched engine's ``pareto_mask`` passes ``xp=jax.numpy`` to run the same
    comparisons on device."""
    c, k = blk.shape
    n = all_o.shape[0]
    le = xp.ones((c, n), dtype=bool)
    lt = xp.zeros((c, n), dtype=bool)
    for d in range(k):
        le = le & (all_o[None, :, d] <= blk[:, None, d] + eps)
        lt = lt | (all_o[None, :, d] < blk[:, None, d] - eps)
    return (le & lt).any(axis=1)


def nondominated_mask(objs, eps: float = PARETO_EPS,
                      chunk: int = 1024) -> np.ndarray:
    """Boolean non-dominated mask over an (n, k) objective matrix
    (minimization), vectorized and chunked.  Entry ``i`` is True iff no row
    dominates row ``i`` under exactly the :func:`dominates` semantics — this
    is the single dominance predicate :func:`pareto_indices` and the batched
    engine's ``pareto_mask`` both reduce to."""
    objs = np.asarray(objs, dtype=np.float64)
    if objs.ndim == 1:
        objs = objs[:, None]
    n = objs.shape[0]
    keep = np.ones(n, dtype=bool)
    for start in range(0, n, chunk):
        blk = objs[start:start + chunk]                 # (c, k)
        dominated = chunk_dominated(objs, blk, eps)
        keep[start:start + blk.shape[0]] = ~dominated
    return keep


#: Default device-memory budget for one Pareto chunk's comparison masks.
DEFAULT_PARETO_BUDGET_BYTES = 256 * 1024 * 1024


def pareto_chunk_size(n_points: int, n_objectives: int = 3,
                      budget_bytes: int = DEFAULT_PARETO_BUDGET_BYTES) -> int:
    """Chunk size for the chunked Pareto masks such that the peak comparison
    footprint fits the accelerator budget.

    One chunk row holds the ``le``/``lt`` masks plus one comparison temp per
    objective against all ``n_points`` columns (~1 byte each), so a chunk
    costs about ``chunk * n_points * (2 + n_objectives)`` bytes."""
    per_row = max(1, n_points) * (2 + max(1, n_objectives))
    chunk = budget_bytes // per_row
    return int(min(max(chunk, 64), max(n_points, 64)))


def pareto_indices(objs: Sequence[Sequence[float]]) -> list[int]:
    """Indices of the non-dominated, deduplicated members of ``objs``, sorted
    by objective tuple.  This is the single source of truth for frontier
    semantics: :func:`pareto_front` and the batched engine's vectorized
    extraction both reduce to it, so scalar and batched sweeps agree exactly.

    Dominance testing delegates to the vectorized :func:`nondominated_mask`
    (the per-pair Python walk was O(N^2) and hung at lattice scale); the
    documented output order is preserved exactly: near-duplicates (all
    coordinates within :data:`PARETO_EPS`) keep their first occurrence in
    input order, and the surviving set is sorted by objective tuple."""
    objs = list(objs)
    if not objs:
        return []
    arr = np.asarray([[float(x) for x in o] for o in objs], dtype=np.float64)
    survivors = np.flatnonzero(nondominated_mask(arr))
    # Dedup in input order against the accepted set (vectorized per survivor,
    # matching the incremental semantics of the original Python walk).
    acc = np.empty((survivors.size, arr.shape[1]), dtype=np.float64)
    n_acc = 0
    front: list[tuple[Sequence[float], int]] = []
    for i in survivors:
        o = arr[i]
        if n_acc and (np.abs(acc[:n_acc] - o) < PARETO_EPS).all(axis=1).any():
            continue
        acc[n_acc] = o
        n_acc += 1
        front.append((objs[i], int(i)))
    front.sort(key=lambda oi: tuple(oi[0]))
    return [i for _, i in front]


def pareto_front(items: Iterable[T], objectives: Callable[[T], Sequence[float]]
                 ) -> list[T]:
    """Filter ``items`` to the non-dominated set, stably ordered by the first
    objective."""
    items = list(items)
    objs = [objectives(it) for it in items]
    return [items[i] for i in pareto_indices(objs)]


def scalarize(weights: Sequence[float], objectives: Sequence[float],
              refs: Sequence[float]) -> float:
    """Weighted-sum scalarization with reference normalization (used to pick a
    single design for a PPA preference)."""
    return sum(w * (o / max(r, 1e-30))
               for w, o, r in zip(weights, objectives, refs))


def preference_grid(resolution: int = 4) -> list[tuple[float, float, float]]:
    """Deterministic simplex grid over (power, area, throughput) preference
    weights — the multi-spec sweep driving the searcher."""
    out = []
    for a in range(resolution + 1):
        for b in range(resolution + 1 - a):
            c = resolution - a - b
            if a == b == c == 0:
                continue
            out.append((a / resolution, b / resolution, c / resolution))
    return out
