"""Declarative axis registry for the discrete macro design lattice.

The design lattice used to hard-code its ten axes (memcell x multmux x CSA
rho/reorder/retimed/split x OFU pipe x retime/fusion flags) into
``DesignLattice.__init__/index_of/strides`` — adding an axis meant editing
every layer from the roll-up kernel to the cache keys.  This module makes the
axis set *data*: each axis is a descriptor with

  name          stable identifier (also the per-axis cache-signature label);
  values        the discrete domain, resolved per spec + lattice config;
  validity      an optional per-value feasibility mask (e.g. OAI22 mult/mux
                beyond MCR=2);
  payloads      per-VALUE canonical signature payloads — what
                :func:`repro.service.keys.axis_signatures` hashes, so a
                single-value recalibration invalidates exactly that value's
                sublattice slice;
  tech_fields   per-value tech-model field names the value's PPA tables read
                (scoped fields are excluded from the global tech signature,
                which is what makes e.g. an ``a_sram12t`` recalibration
                invalidate only the 12T slice).

``DesignLattice`` (:mod:`repro.core.batched`) composes the registered axes:
dims, strides and the mixed-radix flat-index round-trip are all derived from
the resolved axis tuple.  The seed axis set is re-expressed here as registry
entries and stays bit-identical under the differential oracle harness; the
two scale-up axes (multi-precision provisioning per SEGA-DCIM, approximate
adder-tree cells per OpenACM) are plain additional registry entries gated
behind :class:`LatticeConfig`.

Adding an axis is one ``register_axis`` call: provide a builder returning a
:class:`ResolvedAxis` (or None when the config disables it), teach
``SpecTables`` its table contribution, and the lattice enumeration, flat
indexing, per-axis cache signatures and sublattice slicing all follow.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional

from . import subcircuits as sc
from .csa import valid_splits
from .macro import MacroSpec
from .searcher import RHO_STEPS

#: Seed OFU pipeline depths (tt5 repeats); kept here so the axis registry is
#: the one owner of the discrete axis constants.
PIPE_STEPS: tuple[int, ...] = (0, 1, 2, 3)

_BOOL_VALUES: tuple[bool, bool] = (False, True)


# ---------------------------------------------------------------------------
# Per-axis tech-field attribution (scoped cache invalidation)
# ---------------------------------------------------------------------------

#: Tech fields read only by one memcell variant's PPA model — changing one
#: recalibrates exactly that value's sublattice slice.
MEMCELL_TECH_FIELDS: dict[sc.MemCellKind, tuple[str, ...]] = {
    sc.MemCellKind.SRAM_6T: ("a_sram6t", "e_sram_read_bit"),
    sc.MemCellKind.DLATCH_8T: ("a_sram8t", "e_sram_read_bit"),
    sc.MemCellKind.OAI_12T: ("a_sram12t", "e_sram_read_bit"),
}

#: Tech fields read only by one mult/mux variant's PPA model.
MULTMUX_TECH_FIELDS: dict[sc.MultMuxKind, tuple[str, ...]] = {
    sc.MultMuxKind.PASS_1T: ("d_mult_pass1t", "e_mult_pass1t",
                             "a_mult_pass1t", "a_mult_nor"),
    sc.MultMuxKind.OAI22_FUSED: ("d_mult_oai22", "e_mult_oai22",
                                 "a_mult_oai22"),
    sc.MultMuxKind.TG_NOR: ("d_mux2", "e_mux2", "d_mult_nor", "e_mult_nor",
                            "a_tg2t", "a_mult_nor"),
}

#: Tech fields whose effect is scoped to single axis values (the union of the
#: per-value maps above, minus fields shared with spec-constant blocks).
#: :func:`repro.service.keys.axis_signatures` excludes these from the global
#: tech digest — everything else lands in the global component, so a change
#: there invalidates the full lattice (correct: those fields feed every
#: point through the CSA/OFU/driver models).
SCOPED_TECH_FIELDS: frozenset[str] = frozenset(
    f for fields in MEMCELL_TECH_FIELDS.values() for f in fields
) | frozenset(
    f for fields in MULTMUX_TECH_FIELDS.values() for f in fields
    # d_mux2/e_mux2 also feed the OFU and alignment models (every point):
    if f not in ("d_mux2", "e_mux2")
)


# ---------------------------------------------------------------------------
# Lattice configuration + precision plans
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecisionPlan:
    """One precision-provisioning mode: the weight-precision set the OFU
    fusion chain is built for and the FP format set the alignment unit is
    built for.  Plan 0 always equals the spec's own precision lists (the
    seed behavior); later plans provision headroom — octaves above the
    spec's max INT precision and the remaining FP formats — so one macro
    can serve future higher-precision workloads (SEGA-DCIM's
    multi-precision pitch)."""

    ints: tuple[int, ...]
    fps: tuple[str, ...]

    def label(self) -> str:
        return f"int{max(self.ints)}fp{len(self.fps)}"


def precision_plans(spec: MacroSpec, n_modes: int) -> tuple[PrecisionPlan, ...]:
    """The first ``n_modes`` provisioning plans derived from
    ``spec.int_precisions`` / ``spec.fp_precisions`` (deterministic order;
    plan 0 is the spec itself)."""
    ints = tuple(spec.int_precisions)
    fps = tuple(spec.fp_precisions)
    pmax = max(ints)
    all_fps = fps + tuple(f for f in sc.FP_FORMATS if f not in fps)
    plans = [
        PrecisionPlan(ints, fps),                          # exact provisioning
        PrecisionPlan(ints + (2 * pmax,), fps),            # +1 INT octave
        PrecisionPlan(ints + (2 * pmax,), all_fps),        # + all FP formats
        PrecisionPlan(ints + (2 * pmax, 4 * pmax), all_fps),
    ]
    if not 1 <= n_modes <= len(plans):
        raise ValueError(f"precision_modes must be in 1..{len(plans)}, "
                         f"got {n_modes}")
    return tuple(plans[:n_modes])


@dataclass(frozen=True)
class LatticeConfig:
    """Which axes the lattice enumerates, and their discrete domains.

    The default value reproduces the seed lattice exactly.  ``precision_modes
    = 0`` / ``approx_cells = ()`` mean the axis is absent (not size-1): the
    seed lattice shape, strides and flat indices are unchanged."""

    memcells: tuple[sc.MemCellKind, ...] = tuple(sc.MemCellKind)
    multmuxes: tuple[sc.MultMuxKind, ...] = tuple(sc.MultMuxKind)
    rho_steps: tuple[float, ...] = RHO_STEPS
    pipe_steps: tuple[int, ...] = PIPE_STEPS
    #: 0 disables the precision axis; n >= 1 enumerates the first n
    #: :func:`precision_plans` (plan 0 == the spec's own precisions).
    precision_modes: int = 0
    #: () disables the approximate-cell axis; otherwise the adder-tree cell
    #: variants to enumerate (include :data:`repro.core.subcircuits.
    #: EXACT_CELL` first to keep the exact tree in the space).
    approx_cells: tuple[sc.ApproxCellSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "memcells", tuple(self.memcells))
        object.__setattr__(self, "multmuxes", tuple(self.multmuxes))
        object.__setattr__(self, "rho_steps", tuple(self.rho_steps))
        object.__setattr__(self, "pipe_steps", tuple(self.pipe_steps))
        object.__setattr__(self, "approx_cells", tuple(self.approx_cells))
        if not self.memcells or not self.multmuxes:
            raise ValueError("memcells and multmuxes must be non-empty")
        if not self.rho_steps or not self.pipe_steps:
            raise ValueError("rho_steps and pipe_steps must be non-empty")
        if self.precision_modes < 0:
            raise ValueError("precision_modes must be >= 0")

    def with_memcells(self, memcells) -> "LatticeConfig":
        return replace(self, memcells=tuple(memcells))


#: The seed configuration (module-level singleton so identical configs share
#: one object in lru_cache keys).
SEED_CONFIG = LatticeConfig()


def seed_config(memcells=None) -> LatticeConfig:
    """The seed axis set, optionally restricted to a memcell subset (the
    historical ``memcells=`` argument of the batched entry points)."""
    if memcells is None:
        return SEED_CONFIG
    memcells = tuple(memcells)
    if memcells == SEED_CONFIG.memcells:
        return SEED_CONFIG
    return LatticeConfig(memcells=memcells)


# ---------------------------------------------------------------------------
# Resolved axes + the registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResolvedAxis:
    """One lattice axis resolved against a (spec, config) pair."""

    name: str
    values: tuple
    #: Per-value canonical signature payloads (JSON-able); hashed by
    #: :func:`repro.service.keys.axis_signatures`.
    payloads: tuple
    #: Per-value tech field names this axis's tables read (resolved to the
    #: tech model's current values at signature time).
    tech_fields: tuple[tuple[str, ...], ...] = ()
    #: Per-value validity for this spec (None = all valid).
    validity: Optional[tuple[bool, ...]] = None
    #: Coordinates stored as bool arrays (the seed flag axes).
    bool_coords: bool = False

    @property
    def size(self) -> int:
        return len(self.values)

    def __post_init__(self):
        if len(self.payloads) != len(self.values):
            raise ValueError(f"axis {self.name}: one payload per value")
        if self.tech_fields and len(self.tech_fields) != len(self.values):
            raise ValueError(f"axis {self.name}: one tech-field tuple "
                             "per value")
        if self.validity is not None and len(self.validity) != len(self.values):
            raise ValueError(f"axis {self.name}: one validity bit per value")


def value_label(axis: ResolvedAxis, i: int) -> str:
    """Stable per-value label used by the per-axis cache signatures."""
    v = axis.values[i]
    if isinstance(v, (sc.MemCellKind, sc.MultMuxKind)):
        return v.value
    if isinstance(v, sc.ApproxCellSpec):
        return v.name
    if isinstance(v, PrecisionPlan):
        return v.label()
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, float):
        return repr(v)
    return str(v)


AxisBuilder = Callable[[MacroSpec, LatticeConfig], Optional[ResolvedAxis]]

#: name -> builder, in composition (stride) order.  The first entry is the
#: outermost (largest-stride) axis — the seed ordering is preserved so seed
#: flat indices are unchanged; new axes append after the seed ten.
AXIS_REGISTRY: dict[str, AxisBuilder] = {}


def register_axis(name: str, builder: AxisBuilder) -> AxisBuilder:
    """Add one axis to the lattice.  Everything else — enumeration, strides,
    flat-index round-trip, per-axis cache signatures, sublattice slicing —
    derives from the ResolvedAxis the builder returns."""
    if name in AXIS_REGISTRY:
        raise ValueError(f"axis {name!r} already registered")
    AXIS_REGISTRY[name] = builder
    return builder


def _bool_axis(name: str) -> ResolvedAxis:
    return ResolvedAxis(name=name, values=_BOOL_VALUES,
                        payloads=(0, 1), bool_coords=True)


def _memcell_axis(spec, cfg):
    return ResolvedAxis(
        name="memcell", values=cfg.memcells,
        payloads=tuple(k.value for k in cfg.memcells),
        tech_fields=tuple(MEMCELL_TECH_FIELDS[k] for k in cfg.memcells))


def _multmux_axis(spec, cfg):
    return ResolvedAxis(
        name="multmux", values=cfg.multmuxes,
        payloads=tuple(k.value for k in cfg.multmuxes),
        tech_fields=tuple(MULTMUX_TECH_FIELDS[k] for k in cfg.multmuxes),
        validity=tuple(sc.multmux_valid(k, spec.mcr) for k in cfg.multmuxes))


def _rho_axis(spec, cfg):
    return ResolvedAxis(name="rho", values=cfg.rho_steps,
                        payloads=tuple(float(r) for r in cfg.rho_steps))


def _split_axis(spec, cfg):
    splits = valid_splits(spec.h)
    # The split domain is spec-derived (h); the payload records the derivation
    # rule, not the values — the spec half of the cache address owns h.
    return ResolvedAxis(name="split", values=splits,
                        payloads=tuple(int(s) for s in splits))


def _pipe_axis(spec, cfg):
    return ResolvedAxis(name="pipe", values=cfg.pipe_steps,
                        payloads=tuple(int(p) for p in cfg.pipe_steps))


def _precision_axis(spec, cfg):
    if cfg.precision_modes == 0:
        return None
    plans = precision_plans(spec, cfg.precision_modes)
    return ResolvedAxis(
        name="precision", values=plans,
        # Plan values are spec-derived; the payload pins the derivation mode
        # index (the spec half of the address owns the precision lists).
        payloads=tuple({"mode": i, "ints": list(p.ints), "fps": list(p.fps)}
                       for i, p in enumerate(plans)))


def _approx_axis(spec, cfg):
    if not cfg.approx_cells:
        return None
    return ResolvedAxis(
        name="approx_cell", values=cfg.approx_cells,
        payloads=tuple({"name": c.name, "k_delay": c.k_delay,
                        "k_energy": c.k_energy, "k_area": c.k_area}
                       for c in cfg.approx_cells))


# Seed axes, in the seed stride order (outermost first) — re-registered here
# exactly as the hard-coded lattice enumerated them, so flat indices are
# bit-identical.  New axes append after the seed ten: when disabled the seed
# shape is untouched, when enabled they take the innermost strides.
register_axis("memcell", _memcell_axis)
register_axis("multmux", _multmux_axis)
register_axis("rho", _rho_axis)
register_axis("reorder", lambda spec, cfg: _bool_axis("reorder"))
register_axis("retimed", lambda spec, cfg: _bool_axis("retimed"))
register_axis("split", _split_axis)
register_axis("pipe", _pipe_axis)
register_axis("ofu_retime", lambda spec, cfg: _bool_axis("ofu_retime"))
register_axis("fuse_tree_sa", lambda spec, cfg: _bool_axis("fuse_tree_sa"))
register_axis("fuse_sa_ofu", lambda spec, cfg: _bool_axis("fuse_sa_ofu"))
register_axis("precision", _precision_axis)
register_axis("approx_cell", _approx_axis)

#: Axes the incremental sweep path caches per-value slice frontiers for —
#: the axes whose values can gain members or be recalibrated independently.
#: Flag axes and the spec-derived split axis are excluded (their per-value
#: payloads never change independently of the spec).
SLICEABLE_AXES: tuple[str, ...] = ("memcell", "multmux", "rho", "pipe",
                                   "precision", "approx_cell")


def resolve_axes(spec: MacroSpec,
                 config: LatticeConfig | None = None
                 ) -> tuple[ResolvedAxis, ...]:
    """Resolve every registered axis for one (spec, config) pair, in
    composition order; disabled axes drop out."""
    cfg = config if config is not None else SEED_CONFIG
    out = []
    for name, builder in AXIS_REGISTRY.items():
        ax = builder(spec, cfg)
        if ax is not None:
            out.append(ax)
    return tuple(out)


def dims_of(axes: tuple[ResolvedAxis, ...]) -> tuple[int, ...]:
    return tuple(a.size for a in axes)


def strides_of(dims: tuple[int, ...]) -> tuple[int, ...]:
    out, acc = [], 1
    for n in reversed(dims):
        out.append(acc)
        acc *= n
    return tuple(reversed(out))
