"""Multi-Spec-Oriented (MSO) searcher — paper Algorithm 1 (§III-C).

Faithful implementation of the heuristic hierarchical search:

  Step 1  set subcircuit configuration from SPEC (defaults otherwise)
  Step 2  critical-path optimization
            MAC/adder path:  tt1 faster adders from SCL (incl. carry/sum port
                             reordering), tt2 retiming the output register
                             before the final RCA, tt3 column split H -> H/2
            OFU path:        tt4 retime combinational logic into the S&A,
                             tt5 add an extra OFU pipeline stage
  Step 3  latency optimization — remove pipeline registers between adder tree,
          S&A and OFU when the fused combinational path still meets timing
  Step 4  preference-oriented PPA fine-tuning ft1 (power), ft2 (area),
          ft3 (throughput)

Objective (verbatim from Alg. 1): minimize power/area such that
TOPS(Macro) > TOPS(SPEC).  The multi-spec sweep runs the hierarchy over a
preference grid and returns the Pareto frontier (Fig. 8).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from . import subcircuits as sc
from .csa import CSADesign
from .macro import (MacroDesign, MacroPPA, MacroSpec, rollup, timing_paths)
from .pareto import pareto_front, preference_grid
from .scl import SubcircuitLibrary
from .tech import TechModel, delay_scale

RHO_STEPS = (1.0, 0.75, 0.5, 0.25, 0.0)


@dataclass(frozen=True)
class SearchResult:
    spec: MacroSpec
    frontier: tuple[MacroPPA, ...]       # Pareto-optimal design points
    explored: tuple[MacroPPA, ...]       # everything evaluated (Fig. 8 scatter)
    n_evaluated: int


def max_crit_rel(spec: MacroSpec, tech: TechModel) -> float:
    """Clock-period budget in tau units at the spec voltage."""
    period_ps = 1e12 / spec.f_mac_hz
    return period_ps / (tech.tau_ps * delay_scale(spec.vdd, tech.vth, tech.alpha))


# ---------------------------------------------------------------------------
# Step 1 — subcircuit configuration from SPEC
# ---------------------------------------------------------------------------


def step1_initial_design(spec: MacroSpec,
                         overrides: dict | None = None) -> MacroDesign:
    """SPEC-defined configuration where given, defaults otherwise.

    Default posture is the power/area-lean corner: all-compressor CSA, TG+NOR
    mult/mux, 6T cells, no extra pipeline — Step 2 then *spends* power/area to
    buy timing only where needed.
    """
    overrides = overrides or {}
    d = MacroDesign(
        spec=spec,
        memcell=overrides.get("memcell", sc.MemCellKind.SRAM_6T),
        multmux=overrides.get("multmux", sc.MultMuxKind.TG_NOR),
        csa=overrides.get("csa", CSADesign(rho=1.0)),
        ofu_pipe_stages=overrides.get("ofu_pipe_stages", 0),
    )
    if not sc.multmux_valid(d.multmux, spec.mcr):
        d = replace(d, multmux=sc.MultMuxKind.TG_NOR)
        d = d.with_audit("step1: OAI22 invalid for MCR>2 -> TG_NOR")
    return d.with_audit(f"step1: init {d.name()}")


# ---------------------------------------------------------------------------
# Step 2 — critical-path optimization
# ---------------------------------------------------------------------------


def _mac_path_ok(design: MacroDesign, tech: TechModel, budget: float) -> bool:
    paths, _, _ = timing_paths(design, tech)
    return paths.mac_path_rel <= budget


def _ofu_path_ok(design: MacroDesign, tech: TechModel, budget: float) -> bool:
    paths, _, _ = timing_paths(design, tech)
    return max(paths.ofu_path_rel, paths.sa_path_rel) <= budget


def step2_critical_path(design: MacroDesign, scl: SubcircuitLibrary,
                        tech: TechModel, budget: float) -> MacroDesign:
    # ---- adder/MAC path: tt1 -> tt2 -> tt3 in sequence (Alg. 1) -------------
    guard = 0
    while not _mac_path_ok(design, tech, budget) and guard < 32:
        guard += 1
        csa = design.csa
        # tt1a: port reordering (free speedup from the SCL's characterized
        # carry-vs-sum path data).
        if not csa.reorder:
            design = replace(design, csa=replace(csa, reorder=True))
            design = design.with_audit("tt1: enable carry/sum port reordering")
            continue
        # tt1b: next-faster adder mix from the SCL.
        faster = [r for r in RHO_STEPS if r < csa.rho]
        if faster:
            design = replace(design, csa=replace(csa, rho=faster[0]))
            design = design.with_audit(f"tt1: faster adders rho={faster[0]}")
            continue
        # tt2: retime output register before the final RCA stage.
        if not csa.retimed:
            design = replace(design, csa=replace(csa, retimed=True))
            design = design.with_audit("tt2: retime register before final RCA")
            continue
        # tt3: split the column H -> H/2.
        if csa.split < 4 and design.spec.h // (csa.split * 2) >= 4:
            design = replace(design, csa=replace(csa, split=csa.split * 2))
            design = design.with_audit(f"tt3: column split -> {csa.split * 2}")
            continue
        design = design.with_audit("tt: MAC path UNMET (exhausted techniques)")
        break

    # Relaxation toward the Alg. 1 objective ("minimum power/area such that
    # TOPS(Macro) > TOPS(SPEC)"): once timing is met, walk the adder mix back
    # to the most compressor-heavy (cheapest) point that still meets.
    if _mac_path_ok(design, tech, budget):
        for rho in RHO_STEPS:  # descending: 1.0 first
            if rho <= design.csa.rho:
                break
            cand = replace(design, csa=replace(design.csa, rho=rho))
            if _mac_path_ok(cand, tech, budget):
                design = cand.with_audit(
                    f"tt1-relax: cheapest adder mix meeting timing rho={rho}")
                break

    # ---- OFU path: tt4 -> tt5 in sequence -----------------------------------
    guard = 0
    while not _ofu_path_ok(design, tech, budget) and guard < 8:
        guard += 1
        if not design.ofu_retimed_into_sa:
            cand = replace(design, ofu_retimed_into_sa=True)
            paths, _, _ = timing_paths(cand, tech)
            if max(paths.ofu_path_rel, paths.sa_path_rel) <= budget or \
                    paths.ofu_path_rel < timing_paths(design, tech)[0].ofu_path_rel:
                design = cand.with_audit("tt4: retime OFU logic into S&A")
                continue
        if design.ofu_pipe_stages < 3:
            design = replace(design, ofu_pipe_stages=design.ofu_pipe_stages + 1)
            design = design.with_audit(
                f"tt5: extra OFU pipeline stage -> {design.ofu_pipe_stages}")
            continue
        design = design.with_audit("tt: OFU path UNMET (exhausted techniques)")
        break
    return design


# ---------------------------------------------------------------------------
# Step 3 — latency optimization (register fusion)
# ---------------------------------------------------------------------------


def step3_latency(design: MacroDesign, tech: TechModel,
                  budget: float) -> MacroDesign:
    # Try fusing adder tree + S&A + OFU, then S&A + OFU (Alg. 1 order).
    full = replace(design, fuse_tree_sa=True, fuse_sa_ofu=True)
    paths, _, _ = timing_paths(full, tech)
    if paths.crit_rel <= budget:
        return full.with_audit("step3: fused adder+S&A+OFU registers")
    part = replace(design, fuse_sa_ofu=True)
    paths, _, _ = timing_paths(part, tech)
    if paths.crit_rel <= budget:
        return part.with_audit("step3: fused S&A+OFU registers")
    return design.with_audit("step3: no fusion possible -> power fine-tuning")


# ---------------------------------------------------------------------------
# Step 4 — preference-oriented fine-tuning
# ---------------------------------------------------------------------------


def _meets(design: MacroDesign, tech: TechModel, budget: float) -> bool:
    paths, _, _ = timing_paths(design, tech)
    return paths.crit_rel <= budget


def step4_fine_tune(design: MacroDesign, scl: SubcircuitLibrary,
                    tech: TechModel, budget: float,
                    prefs: tuple[float, float, float]) -> MacroDesign:
    w_power, w_area, w_tput = prefs
    # ft1 (power): substitute the most compressor-heavy CSA that still meets
    # timing; un-split columns and drop surplus OFU pipeline stages (register
    # + clock power) when slack allows.
    if w_power >= max(w_area, w_tput) * 0.999:
        for rho in RHO_STEPS:  # descending power cost
            if rho <= design.csa.rho:
                break
            cand = replace(design, csa=replace(design.csa, rho=rho))
            if _meets(cand, tech, budget):
                design = cand.with_audit(f"ft1: power — rho back up to {rho}")
                break
        while design.csa.split > 1:
            cand = replace(design, csa=replace(design.csa,
                                               split=design.csa.split // 2))
            if _meets(cand, tech, budget):
                design = cand.with_audit("ft1: power — un-split column")
            else:
                break
        while design.ofu_pipe_stages > 0:
            cand = replace(design, ofu_pipe_stages=design.ofu_pipe_stages - 1)
            if _meets(cand, tech, budget):
                design = cand.with_audit("ft1: power — drop OFU pipe stage")
            else:
                break
    # ft2 (area): area-efficient mult/mux substitution; prefer the fused OAI22
    # when MCR allows, the 1T pass gate when area dominates everything.
    if w_area > 0:
        if design.spec.mcr <= 2:
            cand = replace(design, multmux=sc.MultMuxKind.OAI22_FUSED)
            if _meets(cand, tech, budget) and w_area >= w_power:
                design = cand.with_audit("ft2: area — OAI22 fused mult/mux")
        if w_area > max(w_power, w_tput) and design.multmux is not sc.MultMuxKind.PASS_1T:
            cand = replace(design, multmux=sc.MultMuxKind.PASS_1T)
            if _meets(cand, tech, budget):
                design = cand.with_audit("ft2: area — 1T pass-gate mux")
        while w_area >= max(w_power, w_tput) and design.csa.split > 1:
            cand = replace(design, csa=replace(design.csa,
                                               split=design.csa.split // 2))
            if _meets(cand, tech, budget):
                design = cand.with_audit("ft2: area — un-split column")
            else:
                break
    return design


def _throughput_overdrive(prefs: tuple[float, float, float]) -> float:
    """ft3: throughput-leaning preferences retarget synthesis to a frequency
    above spec (the paper's right-corner, high-throughput designs).  Returns
    the frequency multiplier (1.0 = exactly the spec)."""
    w_power, w_area, w_tput = prefs
    if w_tput <= max(w_power, w_area):
        return 1.0
    return 1.0 + 0.35 * w_tput


# ---------------------------------------------------------------------------
# Full hierarchy + multi-spec sweep
# ---------------------------------------------------------------------------


def synthesize_one(spec: MacroSpec, scl: SubcircuitLibrary, tech: TechModel,
                   prefs: tuple[float, float, float],
                   overrides: dict | None = None) -> MacroPPA:
    # ft3 manifests as an overdriven timing target for throughput-leaning
    # preference corners.
    overdrive = _throughput_overdrive(prefs)
    budget = max_crit_rel(spec, tech) / overdrive
    d = step1_initial_design(spec, overrides)
    if overdrive > 1.0:
        d = d.with_audit(f"ft3: throughput overdrive x{overdrive:.2f} "
                         f"(target {spec.f_mac_hz * overdrive / 1e6:.0f} MHz)")
    d = step2_critical_path(d, scl, tech, budget)
    d = step3_latency(d, tech, budget)
    d = step4_fine_tune(d, scl, tech, budget, prefs)
    return rollup(d, tech)


def mso_search(spec: MacroSpec, scl: SubcircuitLibrary, tech: TechModel,
               resolution: int = 4, backend: str = "scalar") -> SearchResult:
    """Sweep the PPA-preference simplex, synthesize each corner, and return
    the Pareto frontier over (energy/op, area, period).

    ``backend="scalar"`` runs the reference per-point hierarchy (this module);
    ``backend="batched"`` evaluates the whole design lattice in one fused pass
    and replays the hierarchy as masked selection (identical frontier, see
    :mod:`repro.core.batched`).
    """
    if backend == "batched":
        from .batched import mso_search_batched
        return mso_search_batched(spec, scl, tech, resolution)
    if backend != "scalar":
        raise ValueError(f"unknown mso_search backend: {backend!r}")
    explored: list[MacroPPA] = []
    seen: set[str] = set()
    for prefs in preference_grid(resolution):
        ppa = synthesize_one(spec, scl, tech, prefs)
        if ppa.design.name() not in seen:
            seen.add(ppa.design.name())
            explored.append(ppa)

    feasible = [p for p in explored if p.meets_timing]
    pool = feasible if feasible else explored

    def objectives(p: MacroPPA) -> tuple[float, float, float]:
        e_per_cycle = p.e_cycle_fj["int_lo"]
        return (e_per_cycle, p.area_um2, 1.0 / p.fmax_hz)

    frontier = pareto_front(pool, objectives)
    return SearchResult(spec=spec, frontier=tuple(frontier),
                        explored=tuple(explored), n_evaluated=len(explored))
