"""Device-sharded multi-spec co-synthesis (100+-spec sweeps).

:mod:`repro.core.multispec` fuses N same-shape specs into one vmapped kernel
launch; this module is the **sharded strategy pair** over the shared
execution engine (:mod:`repro.core.engine`), placing that launch *across
devices* so spec sweeps keep scaling past what one accelerator holds.  The
stacked spec axis of each vmap group is padded to the device count and the
*same* jitted vmapped kernel runs under the placed strategy — the kernel is
elementwise per spec lane, so partitioning the lane axis cannot change
per-lane float64 arithmetic and results stay bit-identical to the unsharded
path on 1 device and on N devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI).

Two execution modes, selected by the engine's capability-probed dispatcher
(``hasattr``), never by version pins:

  ``"jit"``   the engine's ``"sharded-jit"`` strategy — inputs are committed
              to a ``NamedSharding`` over the ``('spec',)`` mesh (through the
              repo's shared logical-axis rules,
              :func:`repro.parallel.sharding.rules_for_mesh`) and the jitted
              kernel's partitioner follows the data — the preferred path on
              every jax this repo supports.
  ``"pmap"``  the engine's ``"pmap"`` strategy — the stacked axis is
              reshaped to (devices, specs/device) and the vmapped kernel
              runs under ``jax.pmap`` — the fallback for runtimes whose
              ``jax.sharding`` surface is incomplete.

Entry points mirror the unsharded engine one-for-one: ``evaluate_many`` ->
:func:`evaluate_many_sharded`, ``mso_search_many`` ->
:func:`mso_search_many_sharded`, ``design_space_sweep_many`` ->
:func:`design_space_sweep_many_sharded` (whose sweeps also extract their
frontiers device-sharded, via
:func:`repro.core.pareto.nondominated_mask_sharded`).  :func:`spec_variants`
generates the deterministic 100+-spec request the sweeps and benchmarks
drive this with.  Padding, placement and packing live in the engine layer —
this module registers nothing of its own.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from . import batched as B
from . import engine as E
from . import multispec as MS
from . import subcircuits as sc
from .batched import BatchedPPA, BatchedSweep, DesignLattice, SpecTables
from .macro import MacroSpec
from .pareto import (SHARDED_EXTRACT_MIN_POINTS, nondominated_mask,
                     nondominated_mask_sharded)
from .searcher import SearchResult
from .tech import TechModel

#: Execution modes accepted by the sharded entry points.
MODES = E.SHARDED_MODES

#: Public sharded mode -> engine strategy (the engine owns the dispatch).
_ENGINE_MODE = dict(E._SHARDED_STRATEGY)

# One capability-probed dispatcher for every sharded surface — this is the
# engine's, re-exported under the historical name.
resolve_mode = E.resolve_sharded_mode


def evaluate_many_sharded(specs: Sequence[MacroSpec], tech: TechModel,
                          memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS,
                          mesh=None, mode: str = "auto",
                          config: B.LatticeConfig | None = None
                          ) -> list[tuple[DesignLattice, SpecTables,
                                          BatchedPPA]]:
    """Device-sharded counterpart of :func:`repro.core.multispec.
    evaluate_many`: same grouping, same kernel, same numpy tail — the spec
    axis of each group is simply partitioned across ``mesh`` (default: a
    ``('spec',)`` mesh over every visible device).  Results are returned in
    input order, bit-identical per spec to the unsharded path.  ``config``
    selects the registered axis set (seed when None)."""
    plan = E.plan(list(specs), tech, tuple(memcells),
                  mode=_ENGINE_MODE[resolve_mode(mode)], mesh=mesh,
                  config=config)
    return E.execute(plan)


def mso_search_many_sharded(specs: Sequence[MacroSpec], scl=None,
                            tech: TechModel = None, resolution: int = 4,
                            mesh=None, mode: str = "auto",
                            config: B.LatticeConfig | None = None
                            ) -> list[SearchResult]:
    """Synthesize 100+ macro specs in one device-sharded pass.

    Per-spec results (explored set, frontier, every PPA field, Alg.-1
    selection order) are bit-identical to :func:`repro.core.multispec.
    mso_search_many` and hence to looping the scalar oracle — pinned by
    ``tests/test_oracle_equivalence.py`` on 1 and on 8 (fake) devices.
    ``scl`` is accepted for signature parity with the other search entry
    points."""
    if tech is None:
        raise ValueError("tech model required")
    evals = evaluate_many_sharded(specs, tech,
                                  memcells=(sc.MemCellKind.SRAM_6T,),
                                  mesh=mesh, mode=mode, config=config)
    return [B._alg1_replay(lat, tab, T, resolution)
            for lat, tab, T in evals]


def _sharded_extract(objs, mode: str, mesh) -> np.ndarray:
    """Survivor mask for a sharded sweep's frontier: the device-sharded
    map-reduce at lattice scale, the host pass below the sharding payoff
    point (:data:`repro.core.pareto.SHARDED_EXTRACT_MIN_POINTS` — feasible
    candidate sets are often small after the validity filter).  Same bits
    either way; the sweep's own mesh bounds which devices extraction may
    touch."""
    if len(objs) < SHARDED_EXTRACT_MIN_POINTS:
        return nondominated_mask(objs)
    return nondominated_mask_sharded(objs, mode=mode, mesh=mesh)


def design_space_sweep_many_sharded(specs: Sequence[MacroSpec],
                                    tech: TechModel,
                                    memcells: tuple[sc.MemCellKind, ...]
                                    = B.MEMCELLS,
                                    mesh=None, mode: str = "auto",
                                    config: B.LatticeConfig | None = None
                                    ) -> list[BatchedSweep]:
    """Exhaustive sweeps for N specs, spec axis sharded across devices.

    The returned sweeps extract their frontiers device-sharded too
    (:func:`repro.core.pareto.nondominated_mask_sharded`, same placement
    mode and mesh as the evaluation, host pass below the payoff point) —
    bit-identical membership and order to the unsharded sweeps, so
    lattice-scale frontier extraction no longer serializes on one host."""
    public_mode = resolve_mode(mode)
    extract = functools.partial(_sharded_extract, mode=public_mode,
                                mesh=mesh)
    return [BatchedSweep(lattice=lat, tables=tab, ppa=T, extract_mask=extract)
            for lat, tab, T in evaluate_many_sharded(specs, tech, memcells,
                                                     mesh=mesh, mode=mode,
                                                     config=config)]


# ---------------------------------------------------------------------------
# Deterministic 100+-spec sweep requests
# ---------------------------------------------------------------------------

#: Perturbation lattice for spec variants: deployment-posture knobs that keep
#: the macro geometry (and hence the vmap group) fixed.
_VARIANT_F_MAC = (250e6, 400e6, 600e6, 800e6, 1.0e9, 1.1e9)
_VARIANT_VDD = (0.7, 0.8, 0.9, 1.0, 1.1, 1.2)
_VARIANT_MCR = (1, 2, 4)

#: Reachable (f_mac, f_wupdate, vdd, mcr) keys: f_wupdate is clamped to
#: min(f_mac, draw), so an f_mac at ascending rank i admits i+1 distinct
#: f_wupdate values — NOT |F_MAC|^2 pairs.  Past this count the generator
#: must allow duplicates or it would spin forever.
_N_REACHABLE = (sum(i + 1 for i in range(len(_VARIANT_F_MAC)))
                * len(_VARIANT_VDD) * len(_VARIANT_MCR))


def spec_variants(n: int, base: MacroSpec | None = None,
                  seed: int = 0) -> list[MacroSpec]:
    """``n`` deterministic macro-spec variants of ``base`` (default: the §I
    vision scenario) — the shape of a real 100+-spec co-synthesis request.

    Variants perturb deployment posture (clock, update rate, voltage, MCR)
    while sharing the base geometry, so they all land in one vmap group and
    exercise the sharded spec axis rather than the grouping.  Seeded, and
    duplicate-free in (f_mac, f_wupdate, vdd, mcr) until the reachable
    posture lattice is exhausted (``n`` beyond that repeats postures), so
    sweeps and benchmarks are reproducible across runs."""
    if base is None:
        base = MS.scenario_specs()["vision"]
    rng = np.random.default_rng(seed)
    out: list[MacroSpec] = []
    seen: set[tuple] = set()
    while len(out) < n:
        f_mac = float(rng.choice(_VARIANT_F_MAC))
        f_wup = float(min(f_mac, rng.choice(_VARIANT_F_MAC)))
        vdd = float(rng.choice(_VARIANT_VDD))
        mcr = int(rng.choice(_VARIANT_MCR))
        key = (f_mac, f_wup, vdd, mcr)
        if key in seen and len(seen) < _N_REACHABLE:
            continue
        seen.add(key)
        out.append(dataclasses.replace(base, f_mac_hz=f_mac,
                                       f_wupdate_hz=f_wup, vdd=vdd, mcr=mcr))
    return out
