"""Device-sharded multi-spec co-synthesis (100+-spec sweeps).

:mod:`repro.core.multispec` fuses N same-shape specs into one vmapped kernel
launch; this module places that launch *across devices* so spec sweeps keep
scaling past what one accelerator holds.  The stacked spec axis of each vmap
group is padded to the device count, placed with a ``Mesh``/``NamedSharding``
along a ``('spec',)`` mesh (through the repo's shared logical-axis rules,
:func:`repro.parallel.sharding.rules_for_mesh`), and the *same* jitted vmapped
kernel runs under that placement — the kernel is elementwise per spec lane, so
partitioning the lane axis cannot change per-lane float64 arithmetic and
results stay bit-identical to the unsharded path on 1 device and on N devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI).

Two execution modes, selected by capability (``hasattr``), never by version
pins:

  ``"jit"``   inputs are committed to a ``NamedSharding`` over the
              ``('spec',)`` mesh and the jitted kernel's partitioner follows
              the data — the preferred path on every jax this repo supports.
  ``"pmap"``  the stacked axis is reshaped to (devices, specs/device) and the
              vmapped kernel runs under ``jax.pmap`` — the fallback for
              runtimes whose ``jax.sharding`` surface is incomplete.

Entry points mirror the unsharded engine one-for-one: ``evaluate_many`` ->
:func:`evaluate_many_sharded`, ``mso_search_many`` ->
:func:`mso_search_many_sharded`, ``design_space_sweep_many`` ->
:func:`design_space_sweep_many_sharded`.  :func:`spec_variants` generates the
deterministic 100+-spec request the sweeps and benchmarks drive this with.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import batched as B
from . import multispec as MS
from . import subcircuits as sc
from .batched import BatchedPPA, BatchedSweep, DesignLattice, SpecTables
from .macro import MacroSpec
from .searcher import SearchResult
from .tech import TechModel

#: Execution modes accepted by the sharded entry points.
MODES = ("auto", "jit", "pmap")


def _supports_named_sharding() -> bool:
    """Capability probe for the NamedSharding execution path (hasattr, not a
    version pin — the same detection style the distributed tests use)."""
    return (hasattr(jax.sharding, "Mesh")
            and hasattr(jax.sharding, "NamedSharding")
            and hasattr(jax.sharding, "PartitionSpec")
            and hasattr(jax, "device_put"))


def resolve_mode(mode: str = "auto") -> str:
    """'auto' picks NamedSharding+jit when the runtime has it, else pmap."""
    if mode not in MODES:
        raise ValueError(f"unknown shardspec mode: {mode!r}; pick from {MODES}")
    if mode == "auto":
        return "jit" if _supports_named_sharding() else "pmap"
    return mode


# The pmap fallback: the same vmapped single-spec kernel, mapped over a
# leading device axis.  Both maps are elementwise per spec lane so per-lane
# arithmetic is the unbatched kernel's, bit for bit.
_eval_kernel_pmap = jax.pmap(
    jax.vmap(B._eval_kernel, in_axes=(None, 0, 0, 0, 0)),
    in_axes=(None, 0, 0, 0, 0))


def _pad_lanes(arr: np.ndarray, pad: int) -> np.ndarray:
    """Pad the leading spec axis with copies of lane 0 (cheap, NaN-free
    filler — padded lanes are computed and discarded, never compared)."""
    if pad == 0:
        return arr
    return np.concatenate([arr, np.repeat(arr[:1], pad, axis=0)], axis=0)


def _evaluate_group_sharded(lattices: Sequence[DesignLattice],
                            tables_list: Sequence[SpecTables],
                            n_dev: int, mesh, mode: str) -> list[BatchedPPA]:
    """One device-sharded kernel launch for a group of same-shape specs.

    Packs through the unsharded engine's :func:`repro.core.multispec.
    _pack_group`, pads the ragged spec count up to the device count, runs the
    shared kernel under the requested placement, strips the padding, and
    finishes with the shared numpy tail — so every per-spec result is
    bit-identical to :func:`repro.core.multispec._evaluate_group`.
    ``mesh`` is only consulted (and only required) in ``"jit"`` mode; the
    pmap fallback needs nothing from ``jax.sharding``.
    """
    csa_i, idx_np, (tabs_s, consts_s, e_ofu_s, e_align_s) = \
        MS._pack_group(lattices, tables_list)
    n_spec = len(lattices)
    pad = (-n_spec) % n_dev
    tabs_p = tuple(_pad_lanes(t, pad) for t in tabs_s)
    consts_p = _pad_lanes(consts_s, pad)
    e_ofu_p = _pad_lanes(e_ofu_s, pad)
    e_align_p = _pad_lanes(e_align_s, pad)

    with enable_x64():
        if mode == "jit":
            # jax.sharding machinery is touched only on this branch, so the
            # pmap fallback stays importable/runnable on runtimes without it.
            from jax.sharding import NamedSharding

            from ..parallel.sharding import logical_to_spec, rules_for_mesh
            rules = rules_for_mesh(mesh)

            def place(a, leading_spec: bool):
                axes = (("spec",) if leading_spec else (None,)) \
                    + (None,) * (np.ndim(a) - 1)
                sharding = NamedSharding(mesh, logical_to_spec(axes, rules))
                return jax.device_put(jnp.asarray(a), sharding)

            idx = tuple(place(a, False) for a in idx_np)
            out = MS._eval_kernel_many(
                idx, tuple(place(t, True) for t in tabs_p),
                place(consts_p, True), place(e_ofu_p, True),
                place(e_align_p, True))
        else:                                   # pmap fallback
            per_dev = (n_spec + pad) // n_dev

            def fold(a):
                a = np.asarray(a)
                return a.reshape((n_dev, per_dev) + a.shape[1:])

            idx = tuple(jnp.asarray(a) for a in idx_np)
            out = _eval_kernel_pmap(idx, tuple(fold(t) for t in tabs_p),
                                    fold(consts_p), fold(e_ofu_p),
                                    fold(e_align_p))
            # unfold (devices, specs/device) -> specs on the host copy: a
            # numpy view, and no further jax dispatch on this branch
            out = jax.tree.map(
                lambda a: np.asarray(a).reshape((n_dev * per_dev,)
                                                + a.shape[2:]), out)
        out = jax.tree.map(np.asarray, out)
    if pad:
        out = jax.tree.map(lambda a: a[:n_spec], out)
    return MS._unpack_group(lattices, tables_list, csa_i, out)


def evaluate_many_sharded(specs: Sequence[MacroSpec], tech: TechModel,
                          memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS,
                          mesh=None, mode: str = "auto"
                          ) -> list[tuple[DesignLattice, SpecTables,
                                          BatchedPPA]]:
    """Device-sharded counterpart of :func:`repro.core.multispec.
    evaluate_many`: same grouping, same kernel, same numpy tail — the spec
    axis of each group is simply partitioned across ``mesh`` (default: a
    ``('spec',)`` mesh over every visible device).  Results are returned in
    input order, bit-identical per spec to the unsharded path."""
    specs = list(specs)
    mode = resolve_mode(mode)
    if mesh is None and mode == "jit":
        from ..parallel.sharding import spec_sweep_mesh
        mesh = spec_sweep_mesh()
    n_dev = int(mesh.devices.size) if mesh is not None else len(jax.devices())
    lattices, tables, groups = MS._grouped(specs, tech, memcells)
    out: list = [None] * len(specs)
    for members in groups.values():
        ppas = _evaluate_group_sharded([lattices[i] for i in members],
                                       [tables[i] for i in members],
                                       n_dev, mesh, mode)
        for i, ppa in zip(members, ppas):
            out[i] = (lattices[i], tables[i], ppa)
    return out


def mso_search_many_sharded(specs: Sequence[MacroSpec], scl=None,
                            tech: TechModel = None, resolution: int = 4,
                            mesh=None, mode: str = "auto"
                            ) -> list[SearchResult]:
    """Synthesize 100+ macro specs in one device-sharded pass.

    Per-spec results (explored set, frontier, every PPA field, Alg.-1
    selection order) are bit-identical to :func:`repro.core.multispec.
    mso_search_many` and hence to looping the scalar oracle — pinned by
    ``tests/test_oracle_equivalence.py`` on 1 and on 8 (fake) devices.
    ``scl`` is accepted for signature parity with the other search entry
    points."""
    if tech is None:
        raise ValueError("tech model required")
    evals = evaluate_many_sharded(specs, tech,
                                  memcells=(sc.MemCellKind.SRAM_6T,),
                                  mesh=mesh, mode=mode)
    return [B._alg1_replay(lat, tab, T, resolution)
            for lat, tab, T in evals]


def design_space_sweep_many_sharded(specs: Sequence[MacroSpec],
                                    tech: TechModel,
                                    memcells: tuple[sc.MemCellKind, ...]
                                    = B.MEMCELLS,
                                    mesh=None, mode: str = "auto"
                                    ) -> list[BatchedSweep]:
    """Exhaustive sweeps for N specs, spec axis sharded across devices."""
    return [BatchedSweep(lattice=lat, tables=tab, ppa=T)
            for lat, tab, T in evaluate_many_sharded(specs, tech, memcells,
                                                     mesh=mesh, mode=mode)]


# ---------------------------------------------------------------------------
# Deterministic 100+-spec sweep requests
# ---------------------------------------------------------------------------

#: Perturbation lattice for spec variants: deployment-posture knobs that keep
#: the macro geometry (and hence the vmap group) fixed.
_VARIANT_F_MAC = (250e6, 400e6, 600e6, 800e6, 1.0e9, 1.1e9)
_VARIANT_VDD = (0.7, 0.8, 0.9, 1.0, 1.1, 1.2)
_VARIANT_MCR = (1, 2, 4)

#: Reachable (f_mac, f_wupdate, vdd, mcr) keys: f_wupdate is clamped to
#: min(f_mac, draw), so an f_mac at ascending rank i admits i+1 distinct
#: f_wupdate values — NOT |F_MAC|^2 pairs.  Past this count the generator
#: must allow duplicates or it would spin forever.
_N_REACHABLE = (sum(i + 1 for i in range(len(_VARIANT_F_MAC)))
                * len(_VARIANT_VDD) * len(_VARIANT_MCR))


def spec_variants(n: int, base: MacroSpec | None = None,
                  seed: int = 0) -> list[MacroSpec]:
    """``n`` deterministic macro-spec variants of ``base`` (default: the §I
    vision scenario) — the shape of a real 100+-spec co-synthesis request.

    Variants perturb deployment posture (clock, update rate, voltage, MCR)
    while sharing the base geometry, so they all land in one vmap group and
    exercise the sharded spec axis rather than the grouping.  Seeded, and
    duplicate-free in (f_mac, f_wupdate, vdd, mcr) until the reachable
    posture lattice is exhausted (``n`` beyond that repeats postures), so
    sweeps and benchmarks are reproducible across runs."""
    if base is None:
        base = MS.scenario_specs()["vision"]
    rng = np.random.default_rng(seed)
    out: list[MacroSpec] = []
    seen: set[tuple] = set()
    while len(out) < n:
        f_mac = float(rng.choice(_VARIANT_F_MAC))
        f_wup = float(min(f_mac, rng.choice(_VARIANT_F_MAC)))
        vdd = float(rng.choice(_VARIANT_VDD))
        mcr = int(rng.choice(_VARIANT_MCR))
        key = (f_mac, f_wup, vdd, mcr)
        if key in seen and len(seen) < _N_REACHABLE:
            continue
        seen.add(key)
        out.append(dataclasses.replace(base, f_mac_hz=f_mac,
                                       f_wupdate_hz=f_wup, vdd=vdd, mcr=mcr))
    return out
