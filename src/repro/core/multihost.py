"""Multi-host spec sharding: the ROADMAP's named next step, landed as a
``register_strategy`` call on the shared execution engine — not a new
execution-path module.

The strategy partitions each vmap group's stacked spec axis over a 2-D
``('host', 'spec')`` mesh (:func:`repro.parallel.sharding.host_spec_mesh`):
one mesh axis per host (``jax.process_count()`` rows), the per-host devices
along the second.  A fused pass splits specs first across hosts and then
across each host's devices — the scaling story the online synthesis
service (:mod:`repro.service`) needs once one host's devices are saturated
by coalesced request batches.  On a single-controller runtime every mesh
device is addressable and one ``NamedSharding`` over both axes places the
lane axis directly; on a genuinely multi-process runtime each process runs
its contiguous lane slice on its local devices and the per-host results
are reassembled with ``multihost_utils.process_allgather`` (process order
== lane order), so no array ever spans non-addressable devices.

On a single-host runtime the host axis has length 1 and the placement
degenerates to the single-host spec sweep — same device set, same per-lane
float64 arithmetic, bit-identical results (the kernel is elementwise per
spec lane; partitioning the lane axis over one mesh axis or two cannot
change per-lane arithmetic).  When the runtime lacks the ``jax.sharding``
surface entirely, :func:`repro.core.engine.resolve_sharded_mode` falls back
from "multihost" to the single-host auto pick (sharded-jit or pmap) — the
single-host path is the fallback, never an error.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import engine as E


def _host_spec_mesh():
    from ..parallel.sharding import host_spec_mesh
    return host_spec_mesh()


def _available() -> bool:
    """Capability probe (hasattr, never a version pin): the NamedSharding
    surface plus a queryable host count; a genuinely multi-process runtime
    additionally needs the cross-process gather primitive — without it the
    engine falls back to the single-host pick instead of crashing on
    non-addressable shards."""
    if not (E._supports_named_sharding() and hasattr(jax, "process_count")):
        return False
    n_proc = jax.process_count()
    if n_proc == 1:
        return True
    if len(jax.devices()) % n_proc:
        return False     # no even host rows -> single-host fallback
    try:
        from jax.experimental import multihost_utils
    except ImportError:
        return False
    return hasattr(multihost_utils, "process_allgather")


def _check_mesh(placement: E.Placement):
    mesh = placement.mesh
    if mesh is None:
        raise ValueError("the 'multihost' strategy needs a mesh "
                         "(use engine.place to resolve one)")
    if tuple(mesh.axis_names) != ("host", "spec"):
        raise ValueError("the 'multihost' strategy needs a ('host', 'spec') "
                         f"mesh, got axes {tuple(mesh.axis_names)}")
    return mesh


def _slice_packed(packed: E.PackedGroup, lo: int, hi: int) -> E.PackedGroup:
    """One host's contiguous slice of a group's lane axis (shared gather
    tuple kept whole — it is lane-invariant)."""
    tabs_s, consts_s, e_ofu_s, e_align_s = packed.operands
    return E.PackedGroup(
        lattices=packed.lattices[lo:hi],
        tables_list=packed.tables_list[lo:hi], csa_i=packed.csa_i,
        idx=packed.idx,
        operands=(tuple(t[lo:hi] for t in tabs_s), consts_s[lo:hi],
                  e_ofu_s[lo:hi], e_align_s[lo:hi]))


def _run_single_controller(packed: E.PackedGroup,
                           placement: E.Placement) -> dict:
    """process_count == 1: every mesh device is addressable, so the lane
    axis is partitioned over *both* mesh axes with one NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = placement.mesh
    pad, tabs_p, consts_p, e_ofu_p, e_align_p = \
        E._padded_operands(packed, placement.n_dev)

    with enable_x64():
        def put(a, leading_spec: bool):
            axes = ((("host", "spec"),) if leading_spec else (None,)) \
                + (None,) * (np.ndim(a) - 1)
            return jax.device_put(jnp.asarray(a),
                                  NamedSharding(mesh, P(*axes)))

        idx = tuple(put(a, False) for a in packed.idx)
        out = E._eval_kernel_many(idx, tuple(put(t, True) for t in tabs_p),
                                  put(consts_p, True), put(e_ofu_p, True),
                                  put(e_align_p, True))
        out = jax.tree.map(np.asarray, out)
    if pad:
        out = jax.tree.map(lambda a: a[:len(packed)], out)
    return out


def _run_multiprocess(packed: E.PackedGroup, placement: E.Placement) -> dict:
    """process_count > 1: a global NamedSharding over the ('host', 'spec')
    mesh would span non-addressable devices, so each process instead runs
    its own contiguous lane slice on its *local* devices (the single-host
    sharded path over a local ('spec',) mesh — every operand is replicated
    host-side by construction, the planner being deterministic) and the
    per-host results are reassembled with ``process_allgather`` in process
    order, which is lane order."""
    from jax.experimental import multihost_utils
    from jax.sharding import Mesh

    n_hosts = placement.mesh.devices.shape[0]
    if n_hosts != jax.process_count():
        # A hand-built mesh whose host axis disagrees with the runtime would
        # make the per-process slices (and the allgather shapes) inconsistent
        # across processes — fail loudly instead of gathering garbage.
        raise ValueError(
            f"multihost mesh has {n_hosts} host rows but the runtime has "
            f"{jax.process_count()} processes; build the mesh with "
            "parallel.sharding.host_spec_mesh on every process")
    me = int(jax.process_index())
    tabs_s, consts_s, e_ofu_s, e_align_s = packed.operands
    pad = (-len(packed)) % n_hosts
    padded = E.PackedGroup(
        lattices=packed.lattices + (packed.lattices[0],) * pad,
        tables_list=packed.tables_list + (packed.tables_list[0],) * pad,
        csa_i=packed.csa_i, idx=packed.idx,
        operands=(tuple(E.pad_lanes(t, pad) for t in tabs_s),
                  E.pad_lanes(consts_s, pad), E.pad_lanes(e_ofu_s, pad),
                  E.pad_lanes(e_align_s, pad)))
    per = len(padded) // n_hosts
    mine = _slice_packed(padded, me * per, (me + 1) * per)

    local_mesh = Mesh(np.asarray(jax.local_devices()), ("spec",))
    local = E.Placement(mode="sharded-jit", mesh=local_mesh,
                        n_dev=int(local_mesh.devices.size))
    out_local = E._run_sharded_jit(mine, local)
    out = jax.tree.map(
        lambda a: np.asarray(multihost_utils.process_allgather(a,
                                                               tiled=True)),
        out_local)
    if pad:
        out = jax.tree.map(lambda a: a[:len(packed)], out)
    return out


def _run_multihost(packed: E.PackedGroup, placement: E.Placement) -> dict:
    """The vmapped kernel with its spec axis partitioned over the
    ``('host', 'spec')`` mesh — specs split across hosts, then across each
    host's devices."""
    _check_mesh(placement)
    if jax.process_count() == 1:
        return _run_single_controller(packed, placement)
    return _run_multiprocess(packed, placement)


#: The ROADMAP contract, verbatim: multi-host spec sharding is a
#: register_strategy call on the engine.
MULTIHOST = E.register_strategy(
    E.Strategy("multihost", _available, _run_multihost, sharded=True,
               default_mesh=_host_spec_mesh))
