"""40nm CMOS technology model for SynDCIM PPA estimation.

The paper characterizes subcircuits with a foundry PDK (custom cell
characterization -> LEF/LIB) and validates with a 40nm test chip.  This module
replaces the PDK with an analytical technology model whose free constants are
calibrated against the paper's *measured* silicon:

  * f_max = 1.1 GHz @ 1.2 V and 300 MHz @ 0.7 V        (Fig. 9 shmoo)
  * 9.0 TOPS (1b x 1b scaled, 4 Kb array) @ 1.2 V      (Fig. 9)
  * 1921 TOPS/W @ 0.7 V, INT4, 12.5% input / 50% weight activity (Table II)
  * macro area 0.112 mm^2 (455 x 246 um) for the 64x64 MCR=2 macro (Fig. 10)

Voltage/frequency scaling follows the alpha-power law

    delay(V) ∝ V / (V - Vth)^alpha

with (Vth, alpha) fit to the two shmoo anchor points, and dynamic energy
follows E ∝ V^2.  All per-gate constants below are expressed at VDD_NOM and
scaled from there.

Units used throughout ``repro.core``:
  delay  : ps
  energy : fJ (per event, at VDD_NOM unless stated)
  area   : um^2
  power  : mW (derived)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Voltage scaling
# ---------------------------------------------------------------------------

VDD_NOM = 1.1     # V   — characterization voltage for all base constants
VTH = 0.35        # V   — fit (see DESIGN.md §7)
ALPHA = 2.05      # alpha-power-law exponent — fit to the Fig. 9 shmoo anchors


def delay_scale(vdd: float, vth: float = VTH, alpha: float = ALPHA) -> float:
    """Multiplier on delay when running at ``vdd`` instead of VDD_NOM."""
    if vdd <= vth:
        return float("inf")

    def d(v: float) -> float:
        return v / (v - vth) ** alpha

    return d(vdd) / d(VDD_NOM)


def energy_scale(vdd: float) -> float:
    """Multiplier on dynamic energy when running at ``vdd`` (E ∝ V^2)."""
    return (vdd / VDD_NOM) ** 2


def leakage_scale(vdd: float) -> float:
    """Sub-threshold leakage grows superlinearly with VDD; a V^3-ish fit is
    adequate over the paper's 0.7—1.2 V window."""
    return (vdd / VDD_NOM) ** 3


# ---------------------------------------------------------------------------
# Technology model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TechModel:
    """Per-gate PPA constants at 40nm, VDD_NOM.

    The ``tau`` delay unit and ``eps`` energy unit are the two calibration
    knobs solved by :func:`calibrated_tech` so the reference macro reproduces
    the paper's measured silicon exactly; the *relative* constants (an XOR is
    ~1.4x an NAND, a 4-2 compressor is ~1.9x an FA, ...) come from standard
    40nm standard-cell-library ratios.
    """

    node_nm: int = 40
    vdd_nom: float = VDD_NOM
    vth: float = VTH
    alpha: float = ALPHA

    # Calibration knobs (solved in calibrated_tech()):
    tau_ps: float = 1.0     # base gate-delay unit (≈ loaded NAND2 delay)
    eps_fj: float = 1.0     # base gate-energy unit (≈ NAND2 switching energy)

    # --- relative delay (in tau) -------------------------------------------------
    d_nand: float = 1.0
    d_xor: float = 1.6
    d_fa_sum: float = 3.2        # two chained XORs
    d_fa_carry: float = 2.2      # majority gate path (carry is faster — §III-B)
    d_comp42_sum: float = 4.8    # 4-2 compressor through-sum path (slower than FA)
    d_comp42_carry: float = 3.4
    d_mux2: float = 1.2
    d_reg_cq_su: float = 2.6     # clk->q + setup budget
    d_wl_driver_base: float = 2.0
    d_wl_driver_per_log2col: float = 0.8   # buffer chain grows with fanout
    d_mult_nor: float = 1.1      # NOR2 bitwise multiplier
    d_mult_oai22: float = 1.5    # fused OAI22 multiplier+mux
    d_mult_pass1t: float = 2.4   # 1T pass gate: voltage-drop slows downstream
    d_rca_per_bit: float = 1.1   # ripple-carry per-bit carry delay
    d_cmp_per_bit: float = 0.9   # comparator tree per-bit

    # --- relative energy (in eps, per active event) ------------------------------
    e_nand: float = 1.0
    e_xor: float = 1.8
    e_fa: float = 4.2            # full adder total switching energy
    e_ha: float = 2.2
    e_comp42: float = 7.2        # < 2x FA: shared internal nodes (§III-B)
    e_mux2: float = 1.2
    e_reg: float = 2.8           # per flop toggle incl. local clock
    e_clk_per_reg: float = 0.9   # clock tree distribution per sink, every cycle
    e_sram_read_bit: float = 1.3
    e_sram_write_bit: float = 3.6
    e_mult_nor: float = 0.9
    e_mult_oai22: float = 1.3
    e_mult_pass1t: float = 1.6   # voltage drop -> short-circuit current penalty
    e_wl_per_cell: float = 0.35  # WL wire+driver energy amortized per cell on row
    e_bl_per_cell: float = 0.5

    # --- area (um^2, absolute — 40nm standard cell estimates) --------------------
    a_sram6t: float = 0.62
    a_sram8t: float = 0.92      # 8T D-latch cell (robust R/W, [3])
    a_sram12t: float = 1.35     # 12T OAI-gate cell ([10])
    a_fa: float = 5.2
    a_ha: float = 2.8
    a_comp42: float = 8.6       # < 2x FA area
    a_mux2: float = 1.9
    a_reg: float = 6.5
    a_nand: float = 1.1
    a_xor: float = 2.2
    a_mult_nor: float = 1.2
    a_mult_oai22: float = 2.4
    a_mult_pass1t: float = 0.45
    a_tg2t: float = 0.9
    a_driver_per_row: float = 14.0     # WL driver slice
    a_driver_per_col: float = 11.0     # BL driver slice
    # APR fill / routing overhead multiplier on placed cell area (SDP keeps the
    # array regular; peripheral logic is APR'd around it — §III-D):
    apr_overhead: float = 1.0

    # --- leakage ------------------------------------------------------------------
    # static power per um^2 of placed cells at VDD_NOM, in mW/um^2
    leak_mw_per_um2: float = 2.1e-6

    # ------------------------------------------------------------------ helpers
    def delay_ps(self, rel: float, vdd: float) -> float:
        return rel * self.tau_ps * delay_scale(vdd, self.vth, self.alpha)

    def energy_fj(self, rel: float, vdd: float) -> float:
        return rel * self.eps_fj * energy_scale(vdd)

    def fmax_hz(self, crit_path_rel: float, vdd: float) -> float:
        """Max clock for a critical path of ``crit_path_rel`` tau units."""
        d = self.delay_ps(crit_path_rel, vdd)
        return 1e12 / d

    def leakage_mw(self, area_um2: float, vdd: float) -> float:
        return area_um2 * self.leak_mw_per_um2 * leakage_scale(vdd)

    def with_calibration(self, tau_ps: float, eps_fj: float,
                         apr_overhead: float) -> "TechModel":
        return dataclasses.replace(self, tau_ps=tau_ps, eps_fj=eps_fj,
                                   apr_overhead=apr_overhead)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

# Anchors from the paper (see module docstring).
F_ANCHOR_HZ = 1.1e9        # @ 1.2 V           (Fig. 9)
V_ANCHOR = 1.2
F_LOW_HZ = 300e6           # @ 0.7 V           (Fig. 9) — check, not a knob
V_LOW = 0.7
EEFF_ANCHOR_TOPS_W = 1921.0  # 1b-1b scaled, INT4, 12.5%/50% activity @ 0.7 V
AREA_ANCHOR_UM2 = 0.112e6    # 64x64 MCR=2 macro (Fig. 10)


def _check_shmoo_consistency() -> float:
    """The (Vth, alpha) pair must map 1.1 GHz @1.2 V to ~300 MHz @0.7 V."""
    ratio = delay_scale(V_LOW) / delay_scale(V_ANCHOR)
    f_low_pred = F_ANCHOR_HZ / ratio
    return f_low_pred


def calibrated_tech(reference_crit_rel: float | None = None,
                    reference_e_cycle_rel: float | None = None,
                    reference_area_um2: float | None = None) -> TechModel:
    """Solve (tau_ps, eps_fj, apr_overhead) so the reference 64x64 macro hits
    the silicon anchors.

    Callers from :mod:`repro.core.macro` pass the reference design's critical
    path (in tau), per-cycle energy (in eps, already activity-weighted at the
    Table II measurement conditions) and placed area; this function returns a
    TechModel whose units make those equal the measured values.  Called with
    no arguments it returns the uncalibrated base model (unit knobs).
    """
    base = TechModel()
    if reference_crit_rel is None:
        return base

    # tau: critical path at V_ANCHOR must be 1/F_ANCHOR.
    target_delay_ps = 1e12 / F_ANCHOR_HZ
    tau = target_delay_ps / (reference_crit_rel * delay_scale(V_ANCHOR))

    # eps: per-cycle energy at V_LOW must give EEFF_ANCHOR at 1b-1b scaling.
    #   TOPS(1b) = 2*H*W*f ; P = E_cycle * f  =>  TOPS/W = 2*H*W / E_cycle
    #   => E_cycle(V_LOW) = 2*4096 / 1921e12  J = 4.264 pJ
    eps = 1.0
    if reference_e_cycle_rel and reference_e_cycle_rel > 0:
        e_cycle_target_fj = 2.0 * 64 * 64 / (EEFF_ANCHOR_TOPS_W * 1e12) * 1e15
        eps = e_cycle_target_fj / (reference_e_cycle_rel * energy_scale(V_LOW))

    apr = 1.0
    if reference_area_um2 and reference_area_um2 > 0:
        apr = AREA_ANCHOR_UM2 / reference_area_um2

    return base.with_calibration(tau_ps=tau, eps_fj=eps, apr_overhead=apr)
