"""Batched design-space evaluation engine (vectorized Algorithm 1).

The scalar compiler path (:mod:`repro.core.searcher`) evaluates one preference
point at a time, re-running the full subcircuit characterization on every
candidate it probes.  This module evaluates the *entire* discrete macro design
space in one fused pass instead:

  ``SpecTables``
      per-spec subcircuit characterization, factored along the lattice axes —
      the CSA family (rho x reorder x retimed x split), the mult/mux variants,
      the OFU pipeline depths, plus the spec-constant blocks (WL/BL drivers,
      S&A, alignment).  Every table entry is produced by the *same* scalar
      model functions the reference path uses, so the two paths share one
      ground truth.

  ``DesignLattice``
      structure-of-arrays enumeration of the discrete design space
      (memcell x mult/mux x CSA x OFU pipe x retiming/fusion flags), with a
      mixed-radix ``index_of`` so searches address points in O(1).

  ``evaluate``
      the PPA roll-up and timing-path checks of :mod:`repro.core.macro`
      reimplemented as vectorized float64 JAX over the whole lattice.  Term
      gathering and accumulation mirror the scalar arithmetic operation for
      operation, so results are bit-identical to :func:`repro.core.macro.rollup`.

  ``mso_search_batched``
      Algorithm 1 (steps 1-4) layered on top as masked first-feasible
      selection over the batched tensors: the tt1→tt3 critical-path walk, the
      tt4/tt5 OFU walk, register fusion, and the ft1-ft3 preference
      fine-tuning all become per-preference gathers into the precomputed
      timing arrays.  The returned frontier is identical to the scalar
      :func:`repro.core.searcher.mso_search`.

  ``design_space_sweep`` / ``pareto_mask``
      exhaustive sweeps with chunked vectorized Pareto extraction — the entry
      point :mod:`repro.core.dse` uses for many-workload co-design.

Execution (packing, kernel launch, numpy tail) and frontier extraction are
routed through the shared engine layer (:mod:`repro.core.engine`): this
module is the single-spec ``"jit"`` strategy, :mod:`repro.core.multispec`
the ``"vmap"`` strategy, :mod:`repro.core.shardspec` the sharded pair.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import subcircuits as sc
from .axes import (LatticeConfig, PrecisionPlan, ResolvedAxis, dims_of,
                   resolve_axes, seed_config, strides_of)
from .axes import PIPE_STEPS  # noqa: F401  (re-export; historical home)
from .csa import CSADesign, CSAReport, characterize
from .macro import (ACT_IN_MEAS, ACT_WT_MEAS, MacroDesign, MacroPPA,
                    MacroSpec, PathReport, _mode_bits, _product_bits,
                    reporting_frequency)
from .pareto import (PARETO_EPS, chunk_dominated, nondominated_mask,
                     pareto_chunk_size, preference_grid)
from .searcher import (RHO_STEPS, SearchResult,  # noqa: F401  (re-export)
                       _throughput_overdrive, max_crit_rel)
from .tech import TechModel, delay_scale, energy_scale, leakage_scale

# CSA characterization is pure in (design, rows, product_bits, tech); memoize
# it so multi-spec table builds sharing an H re-use one family characterization
# instead of re-walking the analytical model per spec.
_characterize = functools.lru_cache(maxsize=None)(characterize)

MEMCELLS: tuple[sc.MemCellKind, ...] = tuple(sc.MemCellKind)
MULTMUXES: tuple[sc.MultMuxKind, ...] = tuple(sc.MultMuxKind)
BOOLS: tuple[bool, bool] = (False, True)

_MM_INDEX = {k: i for i, k in enumerate(MULTMUXES)}


# ---------------------------------------------------------------------------
# Per-spec subcircuit tables
# ---------------------------------------------------------------------------


class SpecTables:
    """Subcircuit PPA factored along the *registered* lattice axes for one
    spec (:mod:`repro.core.axes`).

    All entries come from the scalar model functions (``characterize``,
    ``multmux_ppa``, ``ofu_ppa``, ...) with exactly the arguments the scalar
    roll-up would pass, and the derived per-term constants reproduce the
    scalar accumulation expressions float-for-float.

    Axis-dependent tables are flattened so the jitted kernel needs no new
    gathers when an optional axis is enabled:

      * CSA tables are ``approx_cell``-major: flat index
        ``csa_index(rho_i, ro, rt, sp_i, apx_i) = apx_i*n_csa_base + base``;
        with the approx axis disabled ``n_apx == 1`` and the layout is the
        seed layout bit-for-bit.
      * OFU tables are ``precision``-plan-major: flat index
        ``ofu_index(pipe_i, prec_i) = prec_i*n_pipe + pipe_i``; with the
        precision axis disabled ``n_prec == 1`` — the seed layout.
      * Alignment-unit area/energy become per-plan vectors gathered by the
        precision coordinate (a single seed entry when disabled).
    """

    def __init__(self, spec: MacroSpec, tech: TechModel,
                 config: LatticeConfig | None = None,
                 axes: tuple[ResolvedAxis, ...] | None = None):
        self.spec = spec
        self.tech = tech
        self.config = config if config is not None else seed_config()
        self.axes = axes if axes is not None else resolve_axes(spec,
                                                               self.config)
        by_name = {a.name: a for a in self.axes}
        self.memcells: tuple[sc.MemCellKind, ...] = by_name["memcell"].values
        self.multmuxes: tuple[sc.MultMuxKind, ...] = by_name["multmux"].values
        self.rho_steps: tuple[float, ...] = by_name["rho"].values
        self.splits: tuple[int, ...] = by_name["split"].values
        self.pipe_steps: tuple[int, ...] = by_name["pipe"].values
        prec_ax = by_name.get("precision")
        apx_ax = by_name.get("approx_cell")
        # Effective values when the axis is disabled: one seed entry, so the
        # flattened tables reduce to the seed layout.
        self.plans: tuple[PrecisionPlan, ...] = (
            prec_ax.values if prec_ax is not None
            else (PrecisionPlan(tuple(spec.int_precisions),
                                tuple(spec.fp_precisions)),))
        self.approx_cells: tuple[sc.ApproxCellSpec, ...] = (
            apx_ax.values if apx_ax is not None else (sc.EXACT_CELL,))
        self.n_rho = len(self.rho_steps)
        self.n_sp = len(self.splits)
        self.n_pipe = len(self.pipe_steps)
        self.n_prec = len(self.plans)
        self.n_apx = len(self.approx_cells)
        self.n_csa_base = self.n_rho * 2 * 2 * self.n_sp

        # --- CSA family axis (approx_cell x rho x reorder x retimed x split) -
        self.csa_designs: list[CSADesign] = []
        self.csa_reports: list[CSAReport] = []
        for cell in self.approx_cells:
            for rho in self.rho_steps:
                for ro in BOOLS:
                    for rt in BOOLS:
                        for sp in self.splits:
                            d = CSADesign(rho=rho, reorder=ro, retimed=rt,
                                          split=sp)
                            self.csa_designs.append(d)
                            self.csa_reports.append(sc.approx_tree_report(
                                _characterize(d, spec.h, _product_bits(spec),
                                              tech), cell))
        self.csa_crit = np.array([r.crit_path_rel for r in self.csa_reports])
        self.csa_energy = np.array([r.energy_rel for r in self.csa_reports])
        self.csa_area = np.array([r.area_um2 for r in self.csa_reports])
        self.csa_lat = np.array([r.latency_cycles for r in self.csa_reports])
        self.acc_width = self.csa_reports[0].acc_width
        self.out_w = self.acc_width + spec.max_input_bits

        # --- mult/mux axis ---------------------------------------------------
        self.mm_valid = np.array([sc.multmux_valid(k, spec.mcr)
                                  for k in self.multmuxes])
        mm_ppa = [sc.multmux_ppa(k, spec.mcr, tech) if v else None
                  for k, v in zip(self.multmuxes, self.mm_valid)]
        nanppa = sc.PPA(float("nan"), float("nan"), float("nan"))
        self.mm_ppa = [p if p is not None else nanppa for p in mm_ppa]

        # --- memcell axis (area only: timing/energy use the array drivers) --
        self.cell_area = np.array([sc.memcell_ppa(k, tech).area_um2
                                   for k in self.memcells])

        # --- OFU pipeline x precision-plan axes ------------------------------
        self.ofu_ppa = [sc.ofu_ppa(spec.w, plan.ints, self.out_w, ps, tech)
                        for plan in self.plans for ps in self.pipe_steps]

        # --- spec-constant subcircuits ---------------------------------------
        self.wl = sc.wl_driver_ppa(spec.h, spec.w, spec.mcr, tech)
        self.bl = sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, tech)
        # _mode_energy_rel uses base-unit BL constants (rel consts only):
        self.bl_base = sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, TechModel())
        self.sa = sc.shift_adder_ppa(self.acc_width, spec.max_input_bits, tech)
        # Alignment unit per precision plan (plan 0 == the spec's own FP set).
        self.align_t = [sc.align_ppa(spec.w, plan.fps, tech)
                        for plan in self.plans]
        self.align = self.align_t[0]

        self.modes = ["int_lo", "int_hi"] + list(spec.fp_precisions)
        self._build_terms()

    def csa_index(self, rho_i, ro, rt, sp_i, apx_i=0):
        """Flat index into the CSA tables (vectorized-friendly)."""
        base = ((np.asarray(rho_i) * 2 + np.asarray(ro)) * 2
                + np.asarray(rt)) * self.n_sp + np.asarray(sp_i)
        return np.asarray(apx_i) * self.n_csa_base + base

    def ofu_index(self, pipe_i, prec_i=0):
        """Flat index into the OFU tables (vectorized-friendly)."""
        return np.asarray(prec_i) * self.n_pipe + np.asarray(pipe_i)

    def compatible_with(self, lattice: "DesignLattice") -> bool:
        """Whether this table set can serve gathers for ``lattice`` — the
        lattice's axis values must prefix-match the table axes (the seed
        service path enumerates a memcell subset against full tables)."""
        mine = {a.name: a.values for a in self.axes}
        for ax in lattice.axes:
            vals = mine.get(ax.name)
            if vals is None or vals[:len(ax.values)] != tuple(ax.values):
                return False
        return True

    # -- per-term constants mirroring the scalar accumulation expressions ----
    def _build_terms(self) -> None:
        spec, tech = self.spec, self.tech
        act_in, act_wt = ACT_IN_MEAS, ACT_WT_MEAS

        # timing: scalar mac path is (wl + mm) + tree
        self.t_wl_mm = np.array([self.wl.delay_rel + p.delay_rel
                                 for p in self.mm_ppa])
        self.t_ofu = np.array([p.delay_rel for p in self.ofu_ppa])
        self.t_sa = self.sa.delay_rel

        # area: scalar breakdown entries in roll-up order
        n_cells = spec.h * spec.w * spec.mcr
        self.a_array = np.array([n_cells * a for a in self.cell_area])
        self.a_mult = np.array([spec.h * spec.w * p.area_um2
                                for p in self.mm_ppa])
        self.a_tree = np.array([a * spec.w for a in self.csa_area])
        self.a_sa = self.sa.area_um2 * spec.w
        self.a_ofu = np.array([p.area_um2 for p in self.ofu_ppa])
        self.a_align_t = np.array([p.area_um2 for p in self.align_t])
        self.a_align = float(self.a_align_t[0])
        self.a_drv = self.wl.area_um2 + self.bl.area_um2

        # energy: term tables per _mode_energy_rel accumulation step
        self.e_wl = self.wl.energy_rel * act_in
        self.e_mm = np.array([spec.h * spec.w * p.energy_rel * act_in * act_wt
                              for p in self.mm_ppa])
        tree_act = min(1.0, act_in * act_wt + 0.02)
        self.e_tree = np.array([(e * spec.w) * tree_act
                                for e in self.csa_energy])
        self.e_sa = (self.sa.energy_rel * spec.w) * 0.55
        duty = (min(1.0, spec.f_wupdate_hz / max(spec.f_mac_hz, 1.0))
                * 1.0 / (spec.h * spec.mcr))
        self.e_bl = (self.bl_base.energy_rel / (spec.h * spec.mcr)) * duty
        self.e_ofu: dict[str, np.ndarray] = {}
        self.e_align: dict[str, np.ndarray] = {}
        for m in self.modes:
            ib = _mode_bits(spec, m)
            self.e_ofu[m] = np.array([p.energy_rel * (0.5 / max(1, ib))
                                      for p in self.ofu_ppa])
            per_plan = []
            for plan, align in zip(self.plans, self.align_t):
                if m in sc.FP_FORMATS:
                    exp, man = sc.FP_FORMATS[m]
                    emax = max(sc.FP_FORMATS[f][0] for f in plan.fps)
                    mmax = max(sc.FP_FORMATS[f][1] for f in plan.fps)
                    frac = (exp + 0.5 * man) / (emax + 0.5 * mmax)
                    per_plan.append(align.energy_rel * 0.62 * frac)
                else:
                    per_plan.append(align.energy_rel * 0.04)
            self.e_align[m] = np.array(per_plan)

        # latency components (ints)
        self.l_csa = self.csa_lat
        self.l_sa = self.sa.latency_cycles
        self.l_ofu = np.array([p.latency_cycles for p in self.ofu_ppa])


# ---------------------------------------------------------------------------
# Design lattice (structure-of-arrays)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class DesignLattice:
    """Flattened enumeration of the discrete macro design space.

    The lattice is a composition of the *registered* axes
    (:mod:`repro.core.axes`): dims, strides, the mixed-radix flat-index
    round-trip, per-point validity and the materialized ``MacroDesign`` are
    all derived from the resolved axis tuple.  The seed ten axes keep their
    historical coordinate attributes (``mem_i`` ... ``fso``); optional axes
    (``precision``, ``approx_cell``) append after them, so seed flat indices
    — and any caller passing only the leading coordinates to
    :meth:`index_of` — are unchanged (missing trailing coordinates address
    the axis default, index 0).
    """

    spec: MacroSpec
    config: LatticeConfig
    axes: tuple[ResolvedAxis, ...]
    coords: tuple[np.ndarray, ...]   # one flat coordinate array per axis
    valid: np.ndarray                # per-point validity (axis masks ANDed)
    # Satellite bugfix: dims/strides used to be properties recomputed on
    # every index_of call (hot in the oracle harness) — now computed once
    # at construction.
    dims: tuple[int, ...]
    strides: tuple[int, ...]

    @classmethod
    def enumerate(cls, spec: MacroSpec,
                  memcells: tuple[sc.MemCellKind, ...] | None = None,
                  config: LatticeConfig | None = None) -> "DesignLattice":
        if config is None:
            config = seed_config(memcells)
        elif memcells is not None:
            config = config.with_memcells(memcells)
        return cls.from_axes(spec, config, resolve_axes(spec, config))

    @classmethod
    def from_axes(cls, spec: MacroSpec, config: LatticeConfig,
                  axes: tuple[ResolvedAxis, ...]) -> "DesignLattice":
        dims = dims_of(axes)
        grids = np.meshgrid(*[np.arange(n) for n in dims], indexing="ij")
        coords = []
        valid = None
        for ax, g in zip(axes, grids):
            c = g.ravel()
            if ax.validity is not None:
                v = np.asarray(ax.validity, dtype=bool)[c]
                valid = v if valid is None else (valid & v)
            coords.append(c.astype(bool) if ax.bool_coords else c)
        n = coords[0].shape[0] if coords else 0
        if valid is None:
            valid = np.ones(n, dtype=bool)
        return cls(spec=spec, config=config, axes=axes, coords=tuple(coords),
                   valid=valid, dims=dims, strides=strides_of(dims))

    def __len__(self) -> int:
        return self.coords[0].shape[0]

    # -- axis access ---------------------------------------------------------

    def axis(self, name: str) -> ResolvedAxis | None:
        for ax in self.axes:
            if ax.name == name:
                return ax
        return None

    def axis_pos(self, name: str) -> int:
        for k, ax in enumerate(self.axes):
            if ax.name == name:
                return k
        raise KeyError(name)

    def coord(self, name: str) -> np.ndarray | None:
        for ax, c in zip(self.axes, self.coords):
            if ax.name == name:
                return c
        return None

    def _coord_or_zeros(self, name: str) -> np.ndarray:
        c = self.coord(name)
        return c if c is not None else np.zeros(len(self), dtype=np.int64)

    # Historical coordinate attributes (seed axes; always present).
    @property
    def mem_i(self) -> np.ndarray:
        return self.coord("memcell")

    @property
    def mm_i(self) -> np.ndarray:
        return self.coord("multmux")

    @property
    def rho_i(self) -> np.ndarray:
        return self.coord("rho")

    @property
    def ro(self) -> np.ndarray:
        return self.coord("reorder")

    @property
    def rt(self) -> np.ndarray:
        return self.coord("retimed")

    @property
    def sp_i(self) -> np.ndarray:
        return self.coord("split")

    @property
    def pipe_i(self) -> np.ndarray:
        return self.coord("pipe")

    @property
    def ort(self) -> np.ndarray:
        return self.coord("ofu_retime")

    @property
    def fts(self) -> np.ndarray:
        return self.coord("fuse_tree_sa")

    @property
    def fso(self) -> np.ndarray:
        return self.coord("fuse_sa_ofu")

    # Optional-axis coordinates (zeros when the axis is disabled — the
    # seed design).
    @property
    def prec_i(self) -> np.ndarray:
        return self._coord_or_zeros("precision")

    @property
    def apx_i(self) -> np.ndarray:
        return self._coord_or_zeros("approx_cell")

    @property
    def memcells(self) -> tuple[sc.MemCellKind, ...]:
        return self.axis("memcell").values

    @property
    def splits(self) -> tuple[int, ...]:
        return self.axis("split").values

    def index_of(self, *coords):
        """Mixed-radix flat index — O(1) addressing for masked selection.
        Bool flags participate directly (False=0/True=1).  Callers may pass
        only the leading coordinates: missing trailing axes address index 0
        (their default value), so seed-axis call sites work unchanged on an
        extended lattice."""
        if len(coords) > len(self.strides):
            raise ValueError(f"got {len(coords)} coordinates for "
                             f"{len(self.strides)} axes")
        total = 0
        for c, s in zip(coords, self.strides):
            total = total + c * s
        return total

    def coords_of(self, i: int) -> tuple[int, ...]:
        """Inverse of :meth:`index_of` (per-axis coordinates of a point)."""
        return tuple(int((i // s) % n)
                     for s, n in zip(self.strides, self.dims))

    def design_at(self, i: int, audit: tuple[str, ...] = ()) -> MacroDesign:
        rho_ax = self.axis("rho")
        pipe_ax = self.axis("pipe")
        mm_ax = self.axis("multmux")
        csa = CSADesign(rho=rho_ax.values[self.rho_i[i]],
                        reorder=bool(self.ro[i]),
                        retimed=bool(self.rt[i]),
                        split=self.splits[self.sp_i[i]])
        kw = {}
        prec_ax = self.axis("precision")
        if prec_ax is not None and self.prec_i[i] != 0:
            plan = prec_ax.values[self.prec_i[i]]
            kw["ofu_precisions"] = plan.ints
            kw["align_fp"] = plan.fps
        apx_ax = self.axis("approx_cell")
        if apx_ax is not None:
            cell = apx_ax.values[self.apx_i[i]]
            if not cell.is_exact():
                kw["approx_cell"] = cell
        return MacroDesign(spec=self.spec,
                           memcell=self.memcells[self.mem_i[i]],
                           multmux=mm_ax.values[self.mm_i[i]], csa=csa,
                           ofu_pipe_stages=pipe_ax.values[self.pipe_i[i]],
                           ofu_retimed_into_sa=bool(self.ort[i]),
                           fuse_tree_sa=bool(self.fts[i]),
                           fuse_sa_ofu=bool(self.fso[i]), audit=audit,
                           **kw)

    def index_of_design(self, design: MacroDesign) -> int:
        """Flat index of the point that materializes ``design`` — the inverse
        of :meth:`design_at` up to the audit trail.  The incremental merge
        uses this to re-anchor cached slice-frontier points in the parent
        lattice's flat order (deterministic duplicate collapse).  Raises
        ``ValueError`` when a design coordinate is not on this lattice."""
        coords = []
        for ax in self.axes:
            if ax.name == "precision":
                if design.ofu_precisions is None and design.align_fp is None:
                    coords.append(0)
                    continue
                v = next((k for k, p in enumerate(ax.values)
                          if p.ints == design.ofu_precisions
                          and p.fps == design.align_fp), None)
                if v is None:
                    raise ValueError(f"precision plan "
                                     f"{design.ofu_precisions}/"
                                     f"{design.align_fp} not on this lattice")
                coords.append(v)
                continue
            if ax.name == "approx_cell":
                cell = design.approx_cell
                if cell is None:
                    v = next((k for k, c in enumerate(ax.values)
                              if c.is_exact()), None)
                else:
                    v = next((k for k, c in enumerate(ax.values)
                              if c == cell), None)
                if v is None:
                    raise ValueError(f"approx cell {cell!r} not on this "
                                     "lattice")
                coords.append(v)
                continue
            value = {
                "memcell": design.memcell,
                "multmux": design.multmux,
                "rho": design.csa.rho,
                "reorder": design.csa.reorder,
                "retimed": design.csa.retimed,
                "split": design.csa.split,
                "pipe": design.ofu_pipe_stages,
                "ofu_retime": design.ofu_retimed_into_sa,
                "fuse_tree_sa": design.fuse_tree_sa,
                "fuse_sa_ofu": design.fuse_sa_ofu,
            }[ax.name]
            try:
                coords.append(ax.values.index(value))
            except ValueError:
                raise ValueError(f"{ax.name} value {value!r} not on this "
                                 "lattice") from None
        return int(self.index_of(*coords))

    def sublattice(self, axis_name: str, value_indices: tuple[int, ...]
                   ) -> tuple["DesignLattice", np.ndarray]:
        """Restrict one axis to a subset of its values.

        Returns ``(sub, parent_flat)`` where ``sub`` is a proper product
        lattice over the restricted axis (evaluable by every strategy) and
        ``parent_flat[j]`` is the flat index of ``sub`` point ``j`` in this
        lattice.  This is the unit of incremental re-synthesis: when one
        axis's cache signature changes, only the invalidated value slices
        are re-evaluated and merged with the cached per-slice frontiers.
        """
        value_indices = tuple(int(v) for v in value_indices)
        pos = self.axis_pos(axis_name)
        src = self.axes[pos]
        if not value_indices or not all(0 <= v < src.size
                                        for v in value_indices):
            raise ValueError(f"bad value indices {value_indices} for axis "
                             f"{axis_name} of size {src.size}")
        sub_axis = ResolvedAxis(
            name=src.name,
            values=tuple(src.values[v] for v in value_indices),
            payloads=tuple(src.payloads[v] for v in value_indices),
            tech_fields=(tuple(src.tech_fields[v] for v in value_indices)
                         if src.tech_fields else ()),
            validity=(tuple(src.validity[v] for v in value_indices)
                      if src.validity is not None else None),
            bool_coords=src.bool_coords)
        axes = self.axes[:pos] + (sub_axis,) + self.axes[pos + 1:]
        sub = DesignLattice.from_axes(self.spec, self.config, axes)
        remap = np.asarray(value_indices, dtype=np.int64)
        parent_flat = np.zeros(len(sub), dtype=np.int64)
        for k, (st, c) in enumerate(zip(self.strides, sub.coords)):
            ci = remap[c.astype(np.int64)] if k == pos else c
            parent_flat = parent_flat + ci * st
        return sub, parent_flat


# ---------------------------------------------------------------------------
# Vectorized timing + PPA roll-up
# ---------------------------------------------------------------------------


@dataclass
class BatchedPPA:
    """Roll-up of the whole lattice as structure-of-arrays (float64)."""

    lattice: DesignLattice
    tables: SpecTables
    mac: np.ndarray
    sa: np.ndarray
    ofu: np.ndarray
    crit: np.ndarray
    fmax: np.ndarray
    meets: np.ndarray
    area: np.ndarray
    breakdown: dict[str, np.ndarray]
    e_cycle: dict[str, np.ndarray]
    latency: np.ndarray
    tops_1b: np.ndarray
    tops_w: dict[str, np.ndarray]
    tops_mm2: np.ndarray

    def materialize(self, i: int, audit: tuple[str, ...] = ()) -> MacroPPA:
        """Reconstruct the scalar MacroPPA view of lattice point ``i``."""
        design = self.lattice.design_at(i, audit)
        paths = PathReport(float(self.mac[i]), float(self.sa[i]),
                           float(self.ofu[i]), float(self.crit[i]))
        return MacroPPA(
            design=design, paths=paths, fmax_hz=float(self.fmax[i]),
            area_um2=float(self.area[i]),
            area_breakdown={k: float(v[i])
                            for k, v in self.breakdown.items()},
            e_cycle_fj={m: float(v[i]) for m, v in self.e_cycle.items()},
            latency_cycles=int(self.latency[i]),
            tops_1b=float(self.tops_1b[i]),
            tops_per_w_1b={m: float(v[i]) for m, v in self.tops_w.items()},
            tops_per_mm2_1b=float(self.tops_mm2[i]),
            meets_timing=bool(self.meets[i]),
            csa_report=self.tables.csa_reports[
                int(self.tables.csa_index(self.lattice.rho_i[i],
                                          self.lattice.ro[i],
                                          self.lattice.rt[i],
                                          self.lattice.sp_i[i],
                                          self.lattice.apx_i[i]))])


# Scalar constants packed into one f64 argument so every (spec, tech) change
# reaches the jitted kernel as data — never as a baked-in trace constant
# (which would also expose literal divisors to reciprocal strength-reduction).
_CONST_FIELDS = ("apr", "a_sa", "a_drv", "e_wl", "e_sa", "e_bl",
                 "eps_fj", "escale")


@functools.partial(jax.jit, static_argnames=())
def _eval_kernel(idx, tabs, consts, e_ofu_m, e_align_m):
    """Fused gather + area + per-mode-energy roll-up over the lattice
    (float64 under x64).

    Arithmetic mirrors macro.rollup operation for operation so results are
    bit-identical to the scalar reference path.  Only contraction-safe
    expressions live in here: gathers, additions of precomputed terms, and
    multiplies that never feed an add (XLA's FMA contraction rewrites
    mul-then-add chains even across an optimization_barrier, so the retiming
    timing chain is computed eagerly by the caller instead).

    Axis-generic addressing: ``csa_j`` indexes the approx-cell-flattened CSA
    tables, ``ofu_j`` the precision-plan-flattened OFU tables, and ``prec_j``
    gathers the per-plan alignment-unit terms.  With the optional axes
    disabled these degenerate to the seed gathers (index 0 everywhere) and
    every gathered value equals the former scalar constant — bit-identical.
    """
    mem_i, mm_i, csa_j, ofu_j, prec_j, ort, fts, fso = idx
    (t_wl_mm, csa_crit, t_ofu, a_array_t, a_mult_t, a_tree_t, a_ofu_t,
     a_align_t, e_mm_t, e_tree_t) = tabs
    c = {k: consts[i] for i, k in enumerate(_CONST_FIELDS)}
    n = mm_i.shape[0]

    # ---- raw timing components (the fixup chain runs in numpy) -------------
    mac_base = t_wl_mm[mm_i] + csa_crit[csa_j]
    ofu_base = t_ofu[ofu_j]

    # ---- area (accumulated in the scalar breakdown order) -------------------
    a_array = a_array_t[mem_i]
    a_mult = a_mult_t[mm_i]
    a_tree = a_tree_t[csa_j]
    a_ofu = a_ofu_t[ofu_j]
    a_align = a_align_t[prec_j]
    placed = a_array + a_mult
    placed = placed + a_tree
    placed = placed + c["a_sa"]
    placed = placed + a_ofu
    placed = placed + a_align
    placed = placed + c["a_drv"]
    area = placed * c["apr"]
    breakdown = {
        "sram_array": a_array, "multmux": a_mult, "adder_tree": a_tree,
        "shift_adder": jnp.broadcast_to(c["a_sa"], (n,)),
        "ofu": a_ofu,
        "align": a_align,
        "drivers": jnp.broadcast_to(c["a_drv"], (n,)),
    }

    # ---- per-cycle energy by mode (macro._mode_energy_rel order) ------------
    n_modes = e_ofu_m.shape[0]
    e_cycle = []
    for m in range(n_modes):
        e = 0.0 + c["e_wl"]
        e = e + e_mm_t[mm_i]
        e = e + e_tree_t[csa_j]
        e = e + c["e_sa"]
        e = e + e_ofu_m[m][ofu_j]
        e = e + e_align_m[m][prec_j]
        e = e + c["e_bl"]
        e_cycle.append((e * c["eps_fj"]) * c["escale"])
    e_cycle = jnp.stack(e_cycle)                       # (M, n)

    return {"mac_base": mac_base, "ofu_base": ofu_base, "area": area,
            "breakdown": breakdown, "e_cycle": e_cycle}


def _kernel_inputs(tables: SpecTables
                   ) -> tuple[tuple[np.ndarray, ...], np.ndarray,
                              np.ndarray, np.ndarray]:
    """numpy-side operands for :func:`_eval_kernel`, in argument order
    (tabs, consts, e_ofu_m, e_align_m).  The multi-spec engine stacks these
    along a leading spec axis and vmaps the same kernel over them."""
    spec, tech = tables.spec, tables.tech
    consts = np.array([
        tech.apr_overhead,
        tables.a_sa, tables.a_drv,
        tables.e_wl, tables.e_sa, tables.e_bl,
        tech.eps_fj,
        energy_scale(spec.vdd),
    ], dtype=np.float64)
    tabs = (tables.t_wl_mm, tables.csa_crit, tables.t_ofu,
            tables.a_array, tables.a_mult, tables.a_tree,
            tables.a_ofu, tables.a_align_t, tables.e_mm, tables.e_tree)
    e_ofu_m = np.stack([tables.e_ofu[m] for m in tables.modes])
    e_align_m = np.stack([tables.e_align[m] for m in tables.modes])
    return tabs, consts, e_ofu_m, e_align_m


def evaluate(lattice: DesignLattice, tables: SpecTables) -> BatchedPPA:
    """One fused (jitted) pass: timing paths + full PPA roll-up for every
    lattice point, mirroring :func:`repro.core.macro.rollup` float-for-float.

    Routed through the shared execution engine's single-spec ``"jit"``
    strategy (:mod:`repro.core.engine`), so this path packs, launches and
    finishes through exactly the code the multi-spec and sharded paths use."""
    from . import engine as E          # lazy: the engine imports this module
    (_, _, ppa), = E.execute(E.plan_for([lattice], [tables], mode="jit"))
    return ppa


def _finish(lattice: DesignLattice, tables: SpecTables, csa_i: np.ndarray,
            ofu_j: np.ndarray, out: dict) -> BatchedPPA:
    """numpy tail of the roll-up, applied to one spec's kernel outputs."""
    spec, tech = tables.spec, tables.tech
    e_cycle = {m: out["e_cycle"][k] for k, m in enumerate(tables.modes)}
    # The timing fixup chain and throughput derivations run in numpy: their
    # multiply-add chains and constant divisors are FMA / reciprocal
    # contraction targets for XLA, which would perturb the last ulp vs the
    # scalar reference.  numpy f64 executes op-for-op; the op count is tiny.
    ort, fts, fso = lattice.ort, lattice.fts, lattice.fso
    mac = out["mac_base"]
    sa_p = np.full(len(lattice), tables.t_sa)
    ofu_p = out["ofu_base"]
    moved = 0.3 * ofu_p
    ofu_p = np.where(ort, ofu_p - moved, ofu_p)
    sa_p = np.where(ort, sa_p + moved, sa_p)
    mac = np.where(fts, mac + sa_p, mac)
    sa_p = np.where(fts, 0.0, sa_p)
    sa_p = np.where(fso, sa_p + ofu_p, sa_p)
    ofu_p = np.where(fso, 0.0, ofu_p)
    crit = np.maximum(mac, np.maximum(sa_p, ofu_p))

    area = out["area"]
    dscale = delay_scale(spec.vdd, tech.vth, tech.alpha)
    fmax = 1e12 / ((crit * tech.tau_ps) * dscale)
    meets = fmax >= spec.f_mac_hz * 0.999
    f_rep = reporting_frequency(fmax, spec.f_mac_hz, meets)
    tops_1b = ((2.0 * spec.h * spec.w) * f_rep) / 1e12
    leak_mw = (area * tech.leak_mw_per_um2) * leakage_scale(spec.vdd)
    tops_w = {}
    for m, efj in e_cycle.items():
        p_mw = ((efj * 1e-15) * f_rep) * 1e3 + leak_mw
        tops_w[m] = np.where(p_mw > 0, tops_1b / (p_mw * 1e-3), np.inf)
    tops_mm2 = tops_1b / (area / 1e6)

    # latency is pure integer bookkeeping.
    ib = max(spec.int_precisions)
    pipe_lat = (tables.l_csa[csa_i] + tables.l_sa
                + tables.l_ofu[ofu_j]
                - lattice.fts.astype(np.int64)
                - lattice.fso.astype(np.int64))
    latency = ib + np.maximum(1, pipe_lat)

    return BatchedPPA(lattice=lattice, tables=tables, mac=mac,
                      sa=sa_p, ofu=ofu_p, crit=crit,
                      fmax=fmax, meets=meets, area=area,
                      breakdown=out["breakdown"], e_cycle=e_cycle,
                      latency=latency, tops_1b=tops_1b, tops_w=tops_w,
                      tops_mm2=tops_mm2)


@functools.lru_cache(maxsize=32)
def _evaluated(spec: MacroSpec, tech: TechModel, config: LatticeConfig
               ) -> tuple[DesignLattice, SpecTables, BatchedPPA]:
    """Characterize-once cache (the SCL-LUT philosophy): the evaluated
    lattice for a (spec, tech, config) triple is immutable and reused by
    every preference sweep and co-design query against it."""
    lattice = DesignLattice.enumerate(spec, config=config)
    tables = SpecTables(spec, tech, config=config)
    return lattice, tables, evaluate(lattice, tables)


# ---------------------------------------------------------------------------
# Vectorized Pareto extraction
# ---------------------------------------------------------------------------


def pareto_mask(objs: np.ndarray, eps: float = PARETO_EPS,
                chunk: int = 512) -> np.ndarray:
    """Non-dominated mask over an (n, k) objective matrix (minimization),
    vectorized and chunked so lattice-sized sweeps stay in memory (size the
    chunk for the accelerator with :func:`repro.core.pareto.
    pareto_chunk_size`).  Dominance semantics match
    :func:`repro.core.pareto.dominates` through the shared
    :data:`repro.core.pareto.PARETO_EPS` band — near-tie objectives land on
    the same frontier in the scalar and batched paths by construction."""
    objs = np.asarray(objs, dtype=np.float64)
    n, _k = objs.shape
    keep = np.ones(n, dtype=bool)
    with enable_x64():
        all_o = jnp.asarray(objs)
        for start in range(0, n, chunk):
            blk = all_o[start:start + chunk]            # (c, k)
            dominated = chunk_dominated(all_o, blk, eps, xp=jnp)
            keep[start:start + blk.shape[0]] = ~np.asarray(dominated)
    return keep


# ---------------------------------------------------------------------------
# Exhaustive sweep
# ---------------------------------------------------------------------------


@dataclass
class BatchedSweep:
    """A fully evaluated design space for one spec."""

    lattice: DesignLattice
    tables: SpecTables
    ppa: BatchedPPA
    #: Optional survivor-mask override for frontier extraction (e.g. the
    #: device-sharded :func:`repro.core.pareto.nondominated_mask_sharded`,
    #: wired in by the sharded sweep path).  Every mask implementation
    #: returns the same bits; only the wall-clock differs.
    extract_mask: Callable[[np.ndarray], np.ndarray] | None = None

    def objectives(self) -> np.ndarray:
        """(n, 3) frontier objectives — (energy/cycle INT-lo, area, period),
        the scalar searcher's ordering."""
        return np.stack([self.ppa.e_cycle["int_lo"], self.ppa.area,
                         1.0 / self.ppa.fmax], axis=1)

    def frontier_indices(self, feasible_only: bool = True,
                         chunk: int | None = None) -> list[int]:
        cand = np.flatnonzero(self.lattice.valid
                              & (self.ppa.meets if feasible_only else True))
        if cand.size == 0:
            cand = np.flatnonzero(self.lattice.valid)
        objs = self.objectives()[cand]
        mask_fn = self.extract_mask
        if mask_fn is None:
            if chunk is None:   # size for the device-memory budget
                chunk = pareto_chunk_size(len(objs), objs.shape[1])
            mask_fn = functools.partial(pareto_mask, chunk=chunk)
        from . import engine as E
        return [int(cand[i]) for i in E.extract_frontier(objs, mask_fn)]

    def materialize(self, i: int) -> MacroPPA:
        return self.ppa.materialize(i, audit=("batched: exhaustive sweep",))


def design_space_sweep(spec: MacroSpec, tech: TechModel,
                       memcells: tuple[sc.MemCellKind, ...] | None = None,
                       config: LatticeConfig | None = None) -> BatchedSweep:
    """Evaluate every discrete design point for ``spec`` in one fused pass."""
    if config is None:
        config = seed_config(memcells)
    elif memcells is not None:
        config = config.with_memcells(memcells)
    lattice, tables, ppa = _evaluated(spec, tech, config)
    return BatchedSweep(lattice=lattice, tables=tables, ppa=ppa)


# ---------------------------------------------------------------------------
# Algorithm 1 as masked selection over the batched tensors
# ---------------------------------------------------------------------------


def _first_feasible(values: np.ndarray, budget: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """For each row budget, index of the first chain entry meeting it; the
    last entry (UNMET) when none does.  values: (n_chain,) or (P, n_chain)."""
    if values.ndim == 1:
        ok = values[None, :] <= budget[:, None]
    else:
        ok = values <= budget[:, None]
    any_ok = ok.any(axis=1)
    idx = np.where(any_ok, ok.argmax(axis=1), ok.shape[1] - 1)
    return idx, any_ok


def mso_search_batched(spec: MacroSpec, scl=None, tech: TechModel = None,
                       resolution: int = 4,
                       config: LatticeConfig | None = None) -> SearchResult:
    """Multi-spec sweep with the hierarchical search replayed as masked
    selection over the batched lattice tensors.  Frontier is identical to the
    scalar :func:`repro.core.searcher.mso_search` (``scl`` is accepted for
    signature parity; the batched path reads the same models directly).

    ``config`` may enable optional axes: the replay walks the seed axes with
    every optional coordinate pinned at its default (index 0), so the result
    stays identical to the scalar search while the evaluated lattice covers
    the extended space."""
    if tech is None:
        raise ValueError("tech model required")
    if config is None:
        config = seed_config((sc.MemCellKind.SRAM_6T,))
    else:
        config = config.with_memcells((sc.MemCellKind.SRAM_6T,))
    lattice, tables, T = _evaluated(spec, tech, config)
    return _alg1_replay(lattice, tables, T, resolution)


def _alg1_replay(lattice: DesignLattice, tables: SpecTables, T: BatchedPPA,
                 resolution: int) -> SearchResult:
    """Algorithm 1 (steps 1-4) as masked first-feasible selection over an
    already-evaluated lattice.  Split out of :func:`mso_search_batched` so the
    multi-spec engine can run one fused evaluation for N specs and replay the
    hierarchy per spec against it."""
    spec, tech = tables.spec, tables.tech

    prefs = preference_grid(resolution)
    P = len(prefs)
    base_budget = max_crit_rel(spec, tech)
    budget = np.array([base_budget / _throughput_overdrive(p) for p in prefs])

    mm_tg = _MM_INDEX[sc.MultMuxKind.TG_NOR]
    zeros = np.zeros(P, dtype=np.int64)

    def gather(arr, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort, fts, fso):
        idx = lattice.index_of(zeros, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort,
                               fts, fso)
        return arr[idx]

    n_rho, n_pipe = tables.n_rho, tables.n_pipe

    # ---- step 2, MAC path: tt1 -> tt2 -> tt3 as a first-feasible chain -----
    # cumulative transform chain from the step-1 state
    chain: list[tuple[int, int, int, int]] = [(0, 0, 0, 0), (0, 1, 0, 0)]
    for ri in range(1, n_rho):
        chain.append((ri, 1, 0, 0))
    last_rho = n_rho - 1
    chain.append((last_rho, 1, 1, 0))
    for sp_i in range(1, len(tables.splits)):
        chain.append((last_rho, 1, 1, sp_i))
    chain_arr = np.array(chain, dtype=np.int64)
    mac_chain = np.array([
        T.mac[lattice.index_of(0, mm_tg, r, ro, rt, s, 0, 0, 0, 0)]
        for r, ro, rt, s in chain])
    pick, mac_ok = _first_feasible(mac_chain, budget)
    rho_i = chain_arr[pick, 0]
    ro = chain_arr[pick, 1]
    rt = chain_arr[pick, 2]
    sp_i = chain_arr[pick, 3]
    unmet_mac = ~mac_ok

    # tt1-relax: cheapest adder mix (highest rho) still meeting timing.
    mac_rho = np.stack([gather(T.mac, np.full(P, mm_tg), np.full(P, j), ro,
                               rt, sp_i, zeros, zeros, zeros, zeros)
                        for j in range(n_rho)], axis=1)
    elig = (np.arange(n_rho)[None, :] < rho_i[:, None]) \
        & (mac_rho <= budget[:, None])
    has_relax = elig.any(axis=1) & mac_ok
    rho_i = np.where(has_relax, elig.argmax(axis=1), rho_i)

    # ---- step 2, OFU path: tt4 -> tt5 as a first-feasible chain ------------
    ofu_states = [(0, 0), (1, 0)] + [(1, p) for p in range(1, n_pipe)]
    ofu_chain = np.array([
        max(T.ofu[lattice.index_of(0, mm_tg, 0, 0, 0, 0, p, o, 0, 0)],
            T.sa[lattice.index_of(0, mm_tg, 0, 0, 0, 0, p, o, 0, 0)])
        for o, p in ofu_states])
    opick, ofu_ok = _first_feasible(ofu_chain, budget)
    ostates = np.array(ofu_states, dtype=np.int64)
    ort = ostates[opick, 0]
    pipe = ostates[opick, 1]
    unmet_ofu = ~ofu_ok

    # ---- step 3: register fusion as masked selection -----------------------
    mm_cur = np.full(P, mm_tg, dtype=np.int64)
    ones = np.ones(P, dtype=np.int64)
    crit_full = gather(T.crit, mm_cur, rho_i, ro, rt, sp_i, pipe, ort, ones,
                       ones)
    crit_part = gather(T.crit, mm_cur, rho_i, ro, rt, sp_i, pipe, ort, zeros,
                       ones)
    full_ok = crit_full <= budget
    part_ok = crit_part <= budget
    fts = np.where(full_ok, 1, 0).astype(np.int64)
    fso = np.where(full_ok | part_ok, 1, 0).astype(np.int64)

    # ---- step 4: preference-oriented fine-tuning ---------------------------
    # preference masks evaluated with the scalar searcher's exact comparisons
    power_pref = np.array([p[0] >= max(p[1], p[2]) * 0.999 for p in prefs])
    area_any = np.array([p[1] > 0 for p in prefs])
    area_dom = np.array([p[1] > max(p[0], p[2]) for p in prefs])
    area_ge = np.array([p[1] >= max(p[0], p[2]) for p in prefs])
    area_ge_power = np.array([p[1] >= p[0] for p in prefs])

    def meets(mm_i_, rho_i_, ro_, rt_, sp_i_, pipe_, ort_, fts_, fso_):
        return gather(T.crit, mm_i_, rho_i_, ro_, rt_, sp_i_, pipe_, ort_,
                      fts_, fso_) <= budget

    # ft1 (power): rho back up, then un-split, then drop OFU pipe stages.
    crit_rho = np.stack([meets(mm_cur, np.full(P, j), ro, rt, sp_i, pipe, ort,
                               fts, fso)
                         for j in range(n_rho)], axis=1)
    elig = (np.arange(n_rho)[None, :] < rho_i[:, None]) & crit_rho
    take = elig.any(axis=1) & power_pref
    rho_i = np.where(take, elig.argmax(axis=1), rho_i)

    active = power_pref.copy()
    for _ in range(len(tables.splits) - 1):
        can = active & (sp_i > 0)
        ok = meets(mm_cur, rho_i, ro, rt, np.maximum(sp_i - 1, 0), pipe, ort,
                   fts, fso)
        apply_ = can & ok
        sp_i = np.where(apply_, sp_i - 1, sp_i)
        active = apply_     # a failed halving stops the walk

    active = power_pref.copy()
    for _ in range(n_pipe - 1):
        can = active & (pipe > 0)
        ok = meets(mm_cur, rho_i, ro, rt, sp_i, np.maximum(pipe - 1, 0), ort,
                   fts, fso)
        apply_ = can & ok
        pipe = np.where(apply_, pipe - 1, pipe)
        active = apply_

    # ft2 (area): OAI22 substitution (MCR permitting), 1T pass-gate mux,
    # un-split columns.
    if spec.mcr <= 2:
        mm_oai = _MM_INDEX[sc.MultMuxKind.OAI22_FUSED]
        ok = meets(np.full(P, mm_oai), rho_i, ro, rt, sp_i, pipe, ort, fts,
                   fso)
        apply_ = area_any & ok & area_ge_power
        mm_cur = np.where(apply_, mm_oai, mm_cur)
    mm_pass = _MM_INDEX[sc.MultMuxKind.PASS_1T]
    ok = meets(np.full(P, mm_pass), rho_i, ro, rt, sp_i, pipe, ort, fts, fso)
    apply_ = area_any & area_dom & (mm_cur != mm_pass) & ok
    mm_cur = np.where(apply_, mm_pass, mm_cur)

    active = area_any & area_ge
    for _ in range(len(tables.splits) - 1):
        can = active & (sp_i > 0)
        ok = meets(mm_cur, rho_i, ro, rt, np.maximum(sp_i - 1, 0), pipe, ort,
                   fts, fso)
        apply_ = can & ok
        sp_i = np.where(apply_, sp_i - 1, sp_i)
        active = apply_

    # ---- materialize + frontier (same dedup/pool/objectives as scalar) -----
    final_idx = lattice.index_of(zeros, mm_cur, rho_i, ro, rt, sp_i, pipe,
                                 ort, fts, fso)
    explored: list[MacroPPA] = []
    seen: set[str] = set()
    seen_idx: set[int] = set()
    for p in range(P):
        i = int(final_idx[p])
        if i in seen_idx:        # distinct lattice points can share a name;
            continue             # same point never needs re-materializing
        seen_idx.add(i)
        audit = ("batched: Alg. 1 replay",)
        if unmet_mac[p]:
            audit += ("tt: MAC path UNMET (exhausted techniques)",)
        if unmet_ofu[p]:
            audit += ("tt: OFU path UNMET (exhausted techniques)",)
        ppa = T.materialize(i, audit=audit)
        if ppa.design.name() not in seen:
            seen.add(ppa.design.name())
            explored.append(ppa)

    feasible = [p for p in explored if p.meets_timing]
    pool = feasible if feasible else explored
    objs = [(p.e_cycle_fj["int_lo"], p.area_um2, 1.0 / p.fmax_hz)
            for p in pool]
    # The shared frontier tail (mask + exact dedup/order) — identical to
    # pareto_indices(objs) on these small pools, and the same tail the
    # lattice-scale sweeps run with their device/sharded masks.
    from . import engine as E
    frontier = [pool[i] for i in E.extract_frontier(objs, nondominated_mask)]
    return SearchResult(spec=spec, frontier=tuple(frontier),
                        explored=tuple(explored), n_evaluated=len(explored))
