"""Batched design-space evaluation engine (vectorized Algorithm 1).

The scalar compiler path (:mod:`repro.core.searcher`) evaluates one preference
point at a time, re-running the full subcircuit characterization on every
candidate it probes.  This module evaluates the *entire* discrete macro design
space in one fused pass instead:

  ``SpecTables``
      per-spec subcircuit characterization, factored along the lattice axes —
      the CSA family (rho x reorder x retimed x split), the mult/mux variants,
      the OFU pipeline depths, plus the spec-constant blocks (WL/BL drivers,
      S&A, alignment).  Every table entry is produced by the *same* scalar
      model functions the reference path uses, so the two paths share one
      ground truth.

  ``DesignLattice``
      structure-of-arrays enumeration of the discrete design space
      (memcell x mult/mux x CSA x OFU pipe x retiming/fusion flags), with a
      mixed-radix ``index_of`` so searches address points in O(1).

  ``evaluate``
      the PPA roll-up and timing-path checks of :mod:`repro.core.macro`
      reimplemented as vectorized float64 JAX over the whole lattice.  Term
      gathering and accumulation mirror the scalar arithmetic operation for
      operation, so results are bit-identical to :func:`repro.core.macro.rollup`.

  ``mso_search_batched``
      Algorithm 1 (steps 1-4) layered on top as masked first-feasible
      selection over the batched tensors: the tt1→tt3 critical-path walk, the
      tt4/tt5 OFU walk, register fusion, and the ft1-ft3 preference
      fine-tuning all become per-preference gathers into the precomputed
      timing arrays.  The returned frontier is identical to the scalar
      :func:`repro.core.searcher.mso_search`.

  ``design_space_sweep`` / ``pareto_mask``
      exhaustive sweeps with chunked vectorized Pareto extraction — the entry
      point :mod:`repro.core.dse` uses for many-workload co-design.

Execution (packing, kernel launch, numpy tail) and frontier extraction are
routed through the shared engine layer (:mod:`repro.core.engine`): this
module is the single-spec ``"jit"`` strategy, :mod:`repro.core.multispec`
the ``"vmap"`` strategy, :mod:`repro.core.shardspec` the sharded pair.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import subcircuits as sc
from .csa import CSADesign, CSAReport, characterize, valid_splits
from .macro import (ACT_IN_MEAS, ACT_WT_MEAS, MacroDesign, MacroPPA,
                    MacroSpec, PathReport, _mode_bits, _product_bits,
                    reporting_frequency)
from .pareto import (PARETO_EPS, chunk_dominated, nondominated_mask,
                     pareto_chunk_size, preference_grid)
from .searcher import (RHO_STEPS, SearchResult, _throughput_overdrive,
                       max_crit_rel)
from .tech import TechModel, delay_scale, energy_scale, leakage_scale

# CSA characterization is pure in (design, rows, product_bits, tech); memoize
# it so multi-spec table builds sharing an H re-use one family characterization
# instead of re-walking the analytical model per spec.
_characterize = functools.lru_cache(maxsize=None)(characterize)

MEMCELLS: tuple[sc.MemCellKind, ...] = tuple(sc.MemCellKind)
MULTMUXES: tuple[sc.MultMuxKind, ...] = tuple(sc.MultMuxKind)
PIPE_STEPS: tuple[int, ...] = (0, 1, 2, 3)
BOOLS: tuple[bool, bool] = (False, True)

_MM_INDEX = {k: i for i, k in enumerate(MULTMUXES)}


# ---------------------------------------------------------------------------
# Per-spec subcircuit tables
# ---------------------------------------------------------------------------


class SpecTables:
    """Subcircuit PPA factored along the lattice axes for one spec.

    All entries come from the scalar model functions (``characterize``,
    ``multmux_ppa``, ``ofu_ppa``, ...) with exactly the arguments the scalar
    roll-up would pass, and the derived per-term constants reproduce the
    scalar accumulation expressions float-for-float.
    """

    def __init__(self, spec: MacroSpec, tech: TechModel):
        self.spec = spec
        self.tech = tech
        self.splits = valid_splits(spec.h)
        self.n_rho = len(RHO_STEPS)
        self.n_sp = len(self.splits)

        # --- CSA family axis (rho x reorder x retimed x split) --------------
        self.csa_designs: list[CSADesign] = []
        self.csa_reports: list[CSAReport] = []
        for ri, rho in enumerate(RHO_STEPS):
            for ro in BOOLS:
                for rt in BOOLS:
                    for sp in self.splits:
                        d = CSADesign(rho=rho, reorder=ro, retimed=rt, split=sp)
                        self.csa_designs.append(d)
                        self.csa_reports.append(
                            _characterize(d, spec.h, _product_bits(spec),
                                          tech))
        self.csa_crit = np.array([r.crit_path_rel for r in self.csa_reports])
        self.csa_energy = np.array([r.energy_rel for r in self.csa_reports])
        self.csa_area = np.array([r.area_um2 for r in self.csa_reports])
        self.csa_lat = np.array([r.latency_cycles for r in self.csa_reports])
        self.acc_width = self.csa_reports[0].acc_width
        self.out_w = self.acc_width + spec.max_input_bits

        # --- mult/mux axis ---------------------------------------------------
        self.mm_valid = np.array([sc.multmux_valid(k, spec.mcr)
                                  for k in MULTMUXES])
        mm_ppa = [sc.multmux_ppa(k, spec.mcr, tech) if v else None
                  for k, v in zip(MULTMUXES, self.mm_valid)]
        nanppa = sc.PPA(float("nan"), float("nan"), float("nan"))
        self.mm_ppa = [p if p is not None else nanppa for p in mm_ppa]

        # --- memcell axis (area only: timing/energy use the array drivers) --
        self.cell_area = np.array([sc.memcell_ppa(k, tech).area_um2
                                   for k in MEMCELLS])

        # --- OFU pipeline axis ----------------------------------------------
        self.ofu_ppa = [sc.ofu_ppa(spec.w, tuple(spec.int_precisions),
                                   self.out_w, ps, tech) for ps in PIPE_STEPS]

        # --- spec-constant subcircuits ---------------------------------------
        self.wl = sc.wl_driver_ppa(spec.h, spec.w, spec.mcr, tech)
        self.bl = sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, tech)
        # _mode_energy_rel uses base-unit BL constants (rel consts only):
        self.bl_base = sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, TechModel())
        self.sa = sc.shift_adder_ppa(self.acc_width, spec.max_input_bits, tech)
        self.align = sc.align_ppa(spec.w, tuple(spec.fp_precisions), tech)

        self.modes = ["int_lo", "int_hi"] + list(spec.fp_precisions)
        self._build_terms()

    def csa_index(self, rho_i, ro, rt, sp_i):
        """Flat index into the CSA axis (vectorized-friendly)."""
        return ((np.asarray(rho_i) * 2 + np.asarray(ro)) * 2
                + np.asarray(rt)) * self.n_sp + np.asarray(sp_i)

    # -- per-term constants mirroring the scalar accumulation expressions ----
    def _build_terms(self) -> None:
        spec, tech = self.spec, self.tech
        act_in, act_wt = ACT_IN_MEAS, ACT_WT_MEAS

        # timing: scalar mac path is (wl + mm) + tree
        self.t_wl_mm = np.array([self.wl.delay_rel + p.delay_rel
                                 for p in self.mm_ppa])
        self.t_ofu = np.array([p.delay_rel for p in self.ofu_ppa])
        self.t_sa = self.sa.delay_rel

        # area: scalar breakdown entries in roll-up order
        n_cells = spec.h * spec.w * spec.mcr
        self.a_array = np.array([n_cells * a for a in self.cell_area])
        self.a_mult = np.array([spec.h * spec.w * p.area_um2
                                for p in self.mm_ppa])
        self.a_tree = np.array([a * spec.w for a in self.csa_area])
        self.a_sa = self.sa.area_um2 * spec.w
        self.a_ofu = np.array([p.area_um2 for p in self.ofu_ppa])
        self.a_align = self.align.area_um2
        self.a_drv = self.wl.area_um2 + self.bl.area_um2

        # energy: term tables per _mode_energy_rel accumulation step
        self.e_wl = self.wl.energy_rel * act_in
        self.e_mm = np.array([spec.h * spec.w * p.energy_rel * act_in * act_wt
                              for p in self.mm_ppa])
        tree_act = min(1.0, act_in * act_wt + 0.02)
        self.e_tree = np.array([(e * spec.w) * tree_act
                                for e in self.csa_energy])
        self.e_sa = (self.sa.energy_rel * spec.w) * 0.55
        duty = (min(1.0, spec.f_wupdate_hz / max(spec.f_mac_hz, 1.0))
                * 1.0 / (spec.h * spec.mcr))
        self.e_bl = (self.bl_base.energy_rel / (spec.h * spec.mcr)) * duty
        self.e_ofu: dict[str, np.ndarray] = {}
        self.e_align: dict[str, float] = {}
        for m in self.modes:
            ib = _mode_bits(spec, m)
            self.e_ofu[m] = np.array([p.energy_rel * (0.5 / max(1, ib))
                                      for p in self.ofu_ppa])
            if m in sc.FP_FORMATS:
                exp, man = sc.FP_FORMATS[m]
                emax = max(sc.FP_FORMATS[f][0] for f in spec.fp_precisions)
                mmax = max(sc.FP_FORMATS[f][1] for f in spec.fp_precisions)
                frac = (exp + 0.5 * man) / (emax + 0.5 * mmax)
                self.e_align[m] = self.align.energy_rel * 0.62 * frac
            else:
                self.e_align[m] = self.align.energy_rel * 0.04

        # latency components (ints)
        self.l_csa = self.csa_lat
        self.l_sa = self.sa.latency_cycles
        self.l_ofu = np.array([p.latency_cycles for p in self.ofu_ppa])


# ---------------------------------------------------------------------------
# Design lattice (structure-of-arrays)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DesignLattice:
    """Flattened enumeration of the discrete macro design space."""

    spec: MacroSpec
    memcells: tuple[sc.MemCellKind, ...]
    splits: tuple[int, ...]
    mem_i: np.ndarray
    mm_i: np.ndarray
    rho_i: np.ndarray
    ro: np.ndarray
    rt: np.ndarray
    sp_i: np.ndarray
    pipe_i: np.ndarray
    ort: np.ndarray
    fts: np.ndarray
    fso: np.ndarray
    valid: np.ndarray          # mult/mux validity for this spec's MCR

    @classmethod
    def enumerate(cls, spec: MacroSpec,
                  memcells: tuple[sc.MemCellKind, ...] = MEMCELLS
                  ) -> "DesignLattice":
        splits = valid_splits(spec.h)
        axes = [np.arange(len(memcells)), np.arange(len(MULTMUXES)),
                np.arange(len(RHO_STEPS)), np.arange(2), np.arange(2),
                np.arange(len(splits)), np.arange(len(PIPE_STEPS)),
                np.arange(2), np.arange(2), np.arange(2)]
        grids = np.meshgrid(*axes, indexing="ij")
        flat = [g.ravel() for g in grids]
        mem_i, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort, fts, fso = flat
        mm_valid = np.array([sc.multmux_valid(k, spec.mcr) for k in MULTMUXES])
        return cls(spec=spec, memcells=tuple(memcells), splits=splits,
                   mem_i=mem_i, mm_i=mm_i, rho_i=rho_i,
                   ro=ro.astype(bool), rt=rt.astype(bool), sp_i=sp_i,
                   pipe_i=pipe_i, ort=ort.astype(bool),
                   fts=fts.astype(bool), fso=fso.astype(bool),
                   valid=mm_valid[mm_i])

    def __len__(self) -> int:
        return self.mem_i.shape[0]

    @property
    def dims(self) -> tuple[int, ...]:
        return (len(self.memcells), len(MULTMUXES), len(RHO_STEPS), 2, 2,
                len(self.splits), len(PIPE_STEPS), 2, 2, 2)

    @property
    def strides(self) -> tuple[int, ...]:
        dims = self.dims
        out = []
        acc = 1
        for n in reversed(dims):
            out.append(acc)
            acc *= n
        return tuple(reversed(out))

    def index_of(self, mem_i, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort, fts,
                 fso):
        """Mixed-radix flat index — O(1) addressing for masked selection.
        Bool flags participate directly (False=0/True=1)."""
        s = self.strides
        return (mem_i * s[0] + mm_i * s[1] + rho_i * s[2] + ro * s[3]
                + rt * s[4] + sp_i * s[5] + pipe_i * s[6] + ort * s[7]
                + fts * s[8] + fso * s[9])

    def design_at(self, i: int, audit: tuple[str, ...] = ()) -> MacroDesign:
        csa = CSADesign(rho=RHO_STEPS[self.rho_i[i]], reorder=bool(self.ro[i]),
                        retimed=bool(self.rt[i]),
                        split=self.splits[self.sp_i[i]])
        return MacroDesign(spec=self.spec,
                           memcell=self.memcells[self.mem_i[i]],
                           multmux=MULTMUXES[self.mm_i[i]], csa=csa,
                           ofu_pipe_stages=PIPE_STEPS[self.pipe_i[i]],
                           ofu_retimed_into_sa=bool(self.ort[i]),
                           fuse_tree_sa=bool(self.fts[i]),
                           fuse_sa_ofu=bool(self.fso[i]), audit=audit)


# ---------------------------------------------------------------------------
# Vectorized timing + PPA roll-up
# ---------------------------------------------------------------------------


@dataclass
class BatchedPPA:
    """Roll-up of the whole lattice as structure-of-arrays (float64)."""

    lattice: DesignLattice
    tables: SpecTables
    mac: np.ndarray
    sa: np.ndarray
    ofu: np.ndarray
    crit: np.ndarray
    fmax: np.ndarray
    meets: np.ndarray
    area: np.ndarray
    breakdown: dict[str, np.ndarray]
    e_cycle: dict[str, np.ndarray]
    latency: np.ndarray
    tops_1b: np.ndarray
    tops_w: dict[str, np.ndarray]
    tops_mm2: np.ndarray

    def materialize(self, i: int, audit: tuple[str, ...] = ()) -> MacroPPA:
        """Reconstruct the scalar MacroPPA view of lattice point ``i``."""
        design = self.lattice.design_at(i, audit)
        paths = PathReport(float(self.mac[i]), float(self.sa[i]),
                           float(self.ofu[i]), float(self.crit[i]))
        return MacroPPA(
            design=design, paths=paths, fmax_hz=float(self.fmax[i]),
            area_um2=float(self.area[i]),
            area_breakdown={k: float(v[i])
                            for k, v in self.breakdown.items()},
            e_cycle_fj={m: float(v[i]) for m, v in self.e_cycle.items()},
            latency_cycles=int(self.latency[i]),
            tops_1b=float(self.tops_1b[i]),
            tops_per_w_1b={m: float(v[i]) for m, v in self.tops_w.items()},
            tops_per_mm2_1b=float(self.tops_mm2[i]),
            meets_timing=bool(self.meets[i]),
            csa_report=self.tables.csa_reports[
                int(self.tables.csa_index(self.lattice.rho_i[i],
                                          self.lattice.ro[i],
                                          self.lattice.rt[i],
                                          self.lattice.sp_i[i]))])


# Scalar constants packed into one f64 argument so every (spec, tech) change
# reaches the jitted kernel as data — never as a baked-in trace constant
# (which would also expose literal divisors to reciprocal strength-reduction).
_CONST_FIELDS = ("apr", "a_sa", "a_align", "a_drv", "e_wl", "e_sa", "e_bl",
                 "eps_fj", "escale")


@functools.partial(jax.jit, static_argnames=())
def _eval_kernel(idx, tabs, consts, e_ofu_m, e_align_m):
    """Fused gather + area + per-mode-energy roll-up over the lattice
    (float64 under x64).

    Arithmetic mirrors macro.rollup operation for operation so results are
    bit-identical to the scalar reference path.  Only contraction-safe
    expressions live in here: gathers, additions of precomputed terms, and
    multiplies that never feed an add (XLA's FMA contraction rewrites
    mul-then-add chains even across an optimization_barrier, so the retiming
    timing chain is computed eagerly by the caller instead).
    """
    mem_i, mm_i, csa_j, pipe_i, ort, fts, fso = idx
    (t_wl_mm, csa_crit, t_ofu, a_array_t, a_mult_t, a_tree_t, a_ofu_t,
     e_mm_t, e_tree_t) = tabs
    c = {k: consts[i] for i, k in enumerate(_CONST_FIELDS)}
    n = mm_i.shape[0]

    # ---- raw timing components (the fixup chain runs in numpy) -------------
    mac_base = t_wl_mm[mm_i] + csa_crit[csa_j]
    ofu_base = t_ofu[pipe_i]

    # ---- area (accumulated in the scalar breakdown order) -------------------
    a_array = a_array_t[mem_i]
    a_mult = a_mult_t[mm_i]
    a_tree = a_tree_t[csa_j]
    a_ofu = a_ofu_t[pipe_i]
    placed = a_array + a_mult
    placed = placed + a_tree
    placed = placed + c["a_sa"]
    placed = placed + a_ofu
    placed = placed + c["a_align"]
    placed = placed + c["a_drv"]
    area = placed * c["apr"]
    breakdown = {
        "sram_array": a_array, "multmux": a_mult, "adder_tree": a_tree,
        "shift_adder": jnp.broadcast_to(c["a_sa"], (n,)),
        "ofu": a_ofu,
        "align": jnp.broadcast_to(c["a_align"], (n,)),
        "drivers": jnp.broadcast_to(c["a_drv"], (n,)),
    }

    # ---- per-cycle energy by mode (macro._mode_energy_rel order) ------------
    n_modes = e_ofu_m.shape[0]
    e_cycle = []
    for m in range(n_modes):
        e = 0.0 + c["e_wl"]
        e = e + e_mm_t[mm_i]
        e = e + e_tree_t[csa_j]
        e = e + c["e_sa"]
        e = e + e_ofu_m[m][pipe_i]
        e = e + e_align_m[m]
        e = e + c["e_bl"]
        e_cycle.append((e * c["eps_fj"]) * c["escale"])
    e_cycle = jnp.stack(e_cycle)                       # (M, n)

    return {"mac_base": mac_base, "ofu_base": ofu_base, "area": area,
            "breakdown": breakdown, "e_cycle": e_cycle}


def _kernel_inputs(tables: SpecTables
                   ) -> tuple[tuple[np.ndarray, ...], np.ndarray,
                              np.ndarray, np.ndarray]:
    """numpy-side operands for :func:`_eval_kernel`, in argument order
    (tabs, consts, e_ofu_m, e_align_m).  The multi-spec engine stacks these
    along a leading spec axis and vmaps the same kernel over them."""
    spec, tech = tables.spec, tables.tech
    consts = np.array([
        tech.apr_overhead,
        tables.a_sa, tables.a_align, tables.a_drv,
        tables.e_wl, tables.e_sa, tables.e_bl,
        tech.eps_fj,
        energy_scale(spec.vdd),
    ], dtype=np.float64)
    tabs = (tables.t_wl_mm, tables.csa_crit, tables.t_ofu,
            tables.a_array, tables.a_mult, tables.a_tree,
            tables.a_ofu, tables.e_mm, tables.e_tree)
    e_ofu_m = np.stack([tables.e_ofu[m] for m in tables.modes])
    e_align_m = np.array([tables.e_align[m] for m in tables.modes])
    return tabs, consts, e_ofu_m, e_align_m


def evaluate(lattice: DesignLattice, tables: SpecTables) -> BatchedPPA:
    """One fused (jitted) pass: timing paths + full PPA roll-up for every
    lattice point, mirroring :func:`repro.core.macro.rollup` float-for-float.

    Routed through the shared execution engine's single-spec ``"jit"``
    strategy (:mod:`repro.core.engine`), so this path packs, launches and
    finishes through exactly the code the multi-spec and sharded paths use."""
    from . import engine as E          # lazy: the engine imports this module
    (_, _, ppa), = E.execute(E.plan_for([lattice], [tables], mode="jit"))
    return ppa


def _finish(lattice: DesignLattice, tables: SpecTables, csa_i: np.ndarray,
            out: dict) -> BatchedPPA:
    """numpy tail of the roll-up, applied to one spec's kernel outputs."""
    spec, tech = tables.spec, tables.tech
    e_cycle = {m: out["e_cycle"][k] for k, m in enumerate(tables.modes)}
    # The timing fixup chain and throughput derivations run in numpy: their
    # multiply-add chains and constant divisors are FMA / reciprocal
    # contraction targets for XLA, which would perturb the last ulp vs the
    # scalar reference.  numpy f64 executes op-for-op; the op count is tiny.
    ort, fts, fso = lattice.ort, lattice.fts, lattice.fso
    mac = out["mac_base"]
    sa_p = np.full(len(lattice), tables.t_sa)
    ofu_p = out["ofu_base"]
    moved = 0.3 * ofu_p
    ofu_p = np.where(ort, ofu_p - moved, ofu_p)
    sa_p = np.where(ort, sa_p + moved, sa_p)
    mac = np.where(fts, mac + sa_p, mac)
    sa_p = np.where(fts, 0.0, sa_p)
    sa_p = np.where(fso, sa_p + ofu_p, sa_p)
    ofu_p = np.where(fso, 0.0, ofu_p)
    crit = np.maximum(mac, np.maximum(sa_p, ofu_p))

    area = out["area"]
    dscale = delay_scale(spec.vdd, tech.vth, tech.alpha)
    fmax = 1e12 / ((crit * tech.tau_ps) * dscale)
    meets = fmax >= spec.f_mac_hz * 0.999
    f_rep = reporting_frequency(fmax, spec.f_mac_hz, meets)
    tops_1b = ((2.0 * spec.h * spec.w) * f_rep) / 1e12
    leak_mw = (area * tech.leak_mw_per_um2) * leakage_scale(spec.vdd)
    tops_w = {}
    for m, efj in e_cycle.items():
        p_mw = ((efj * 1e-15) * f_rep) * 1e3 + leak_mw
        tops_w[m] = np.where(p_mw > 0, tops_1b / (p_mw * 1e-3), np.inf)
    tops_mm2 = tops_1b / (area / 1e6)

    # latency is pure integer bookkeeping.
    ib = max(spec.int_precisions)
    pipe_lat = (tables.l_csa[csa_i] + tables.l_sa
                + tables.l_ofu[lattice.pipe_i]
                - lattice.fts.astype(np.int64)
                - lattice.fso.astype(np.int64))
    latency = ib + np.maximum(1, pipe_lat)

    return BatchedPPA(lattice=lattice, tables=tables, mac=mac,
                      sa=sa_p, ofu=ofu_p, crit=crit,
                      fmax=fmax, meets=meets, area=area,
                      breakdown=out["breakdown"], e_cycle=e_cycle,
                      latency=latency, tops_1b=tops_1b, tops_w=tops_w,
                      tops_mm2=tops_mm2)


@functools.lru_cache(maxsize=32)
def _evaluated(spec: MacroSpec, tech: TechModel,
               memcells: tuple[sc.MemCellKind, ...]
               ) -> tuple[DesignLattice, SpecTables, BatchedPPA]:
    """Characterize-once cache (the SCL-LUT philosophy): the evaluated
    lattice for a (spec, tech) pair is immutable and reused by every
    preference sweep and co-design query against it."""
    lattice = DesignLattice.enumerate(spec, memcells)
    tables = SpecTables(spec, tech)
    return lattice, tables, evaluate(lattice, tables)


# ---------------------------------------------------------------------------
# Vectorized Pareto extraction
# ---------------------------------------------------------------------------


def pareto_mask(objs: np.ndarray, eps: float = PARETO_EPS,
                chunk: int = 512) -> np.ndarray:
    """Non-dominated mask over an (n, k) objective matrix (minimization),
    vectorized and chunked so lattice-sized sweeps stay in memory (size the
    chunk for the accelerator with :func:`repro.core.pareto.
    pareto_chunk_size`).  Dominance semantics match
    :func:`repro.core.pareto.dominates` through the shared
    :data:`repro.core.pareto.PARETO_EPS` band — near-tie objectives land on
    the same frontier in the scalar and batched paths by construction."""
    objs = np.asarray(objs, dtype=np.float64)
    n, _k = objs.shape
    keep = np.ones(n, dtype=bool)
    with enable_x64():
        all_o = jnp.asarray(objs)
        for start in range(0, n, chunk):
            blk = all_o[start:start + chunk]            # (c, k)
            dominated = chunk_dominated(all_o, blk, eps, xp=jnp)
            keep[start:start + blk.shape[0]] = ~np.asarray(dominated)
    return keep


# ---------------------------------------------------------------------------
# Exhaustive sweep
# ---------------------------------------------------------------------------


@dataclass
class BatchedSweep:
    """A fully evaluated design space for one spec."""

    lattice: DesignLattice
    tables: SpecTables
    ppa: BatchedPPA
    #: Optional survivor-mask override for frontier extraction (e.g. the
    #: device-sharded :func:`repro.core.pareto.nondominated_mask_sharded`,
    #: wired in by the sharded sweep path).  Every mask implementation
    #: returns the same bits; only the wall-clock differs.
    extract_mask: Callable[[np.ndarray], np.ndarray] | None = None

    def objectives(self) -> np.ndarray:
        """(n, 3) frontier objectives — (energy/cycle INT-lo, area, period),
        the scalar searcher's ordering."""
        return np.stack([self.ppa.e_cycle["int_lo"], self.ppa.area,
                         1.0 / self.ppa.fmax], axis=1)

    def frontier_indices(self, feasible_only: bool = True,
                         chunk: int | None = None) -> list[int]:
        cand = np.flatnonzero(self.lattice.valid
                              & (self.ppa.meets if feasible_only else True))
        if cand.size == 0:
            cand = np.flatnonzero(self.lattice.valid)
        objs = self.objectives()[cand]
        mask_fn = self.extract_mask
        if mask_fn is None:
            if chunk is None:   # size for the device-memory budget
                chunk = pareto_chunk_size(len(objs), objs.shape[1])
            mask_fn = functools.partial(pareto_mask, chunk=chunk)
        from . import engine as E
        return [int(cand[i]) for i in E.extract_frontier(objs, mask_fn)]

    def materialize(self, i: int) -> MacroPPA:
        return self.ppa.materialize(i, audit=("batched: exhaustive sweep",))


def design_space_sweep(spec: MacroSpec, tech: TechModel,
                       memcells: tuple[sc.MemCellKind, ...] = MEMCELLS
                       ) -> BatchedSweep:
    """Evaluate every discrete design point for ``spec`` in one fused pass."""
    lattice, tables, ppa = _evaluated(spec, tech, tuple(memcells))
    return BatchedSweep(lattice=lattice, tables=tables, ppa=ppa)


# ---------------------------------------------------------------------------
# Algorithm 1 as masked selection over the batched tensors
# ---------------------------------------------------------------------------


def _first_feasible(values: np.ndarray, budget: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """For each row budget, index of the first chain entry meeting it; the
    last entry (UNMET) when none does.  values: (n_chain,) or (P, n_chain)."""
    if values.ndim == 1:
        ok = values[None, :] <= budget[:, None]
    else:
        ok = values <= budget[:, None]
    any_ok = ok.any(axis=1)
    idx = np.where(any_ok, ok.argmax(axis=1), ok.shape[1] - 1)
    return idx, any_ok


def mso_search_batched(spec: MacroSpec, scl=None, tech: TechModel = None,
                       resolution: int = 4) -> SearchResult:
    """Multi-spec sweep with the hierarchical search replayed as masked
    selection over the batched lattice tensors.  Frontier is identical to the
    scalar :func:`repro.core.searcher.mso_search` (``scl`` is accepted for
    signature parity; the batched path reads the same models directly)."""
    if tech is None:
        raise ValueError("tech model required")
    memcell = sc.MemCellKind.SRAM_6T
    lattice, tables, T = _evaluated(spec, tech, (memcell,))
    return _alg1_replay(lattice, tables, T, resolution)


def _alg1_replay(lattice: DesignLattice, tables: SpecTables, T: BatchedPPA,
                 resolution: int) -> SearchResult:
    """Algorithm 1 (steps 1-4) as masked first-feasible selection over an
    already-evaluated lattice.  Split out of :func:`mso_search_batched` so the
    multi-spec engine can run one fused evaluation for N specs and replay the
    hierarchy per spec against it."""
    spec, tech = tables.spec, tables.tech

    prefs = preference_grid(resolution)
    P = len(prefs)
    base_budget = max_crit_rel(spec, tech)
    budget = np.array([base_budget / _throughput_overdrive(p) for p in prefs])

    mm_tg = _MM_INDEX[sc.MultMuxKind.TG_NOR]
    zeros = np.zeros(P, dtype=np.int64)

    def gather(arr, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort, fts, fso):
        idx = lattice.index_of(zeros, mm_i, rho_i, ro, rt, sp_i, pipe_i, ort,
                               fts, fso)
        return arr[idx]

    # ---- step 2, MAC path: tt1 -> tt2 -> tt3 as a first-feasible chain -----
    # cumulative transform chain from the step-1 state
    chain: list[tuple[int, int, int, int]] = [(0, 0, 0, 0), (0, 1, 0, 0)]
    for ri in range(1, len(RHO_STEPS)):
        chain.append((ri, 1, 0, 0))
    last_rho = len(RHO_STEPS) - 1
    chain.append((last_rho, 1, 1, 0))
    for sp_i in range(1, len(tables.splits)):
        chain.append((last_rho, 1, 1, sp_i))
    chain_arr = np.array(chain, dtype=np.int64)
    mac_chain = np.array([
        T.mac[lattice.index_of(0, mm_tg, r, ro, rt, s, 0, 0, 0, 0)]
        for r, ro, rt, s in chain])
    pick, mac_ok = _first_feasible(mac_chain, budget)
    rho_i = chain_arr[pick, 0]
    ro = chain_arr[pick, 1]
    rt = chain_arr[pick, 2]
    sp_i = chain_arr[pick, 3]
    unmet_mac = ~mac_ok

    # tt1-relax: cheapest adder mix (highest rho) still meeting timing.
    mac_rho = np.stack([gather(T.mac, np.full(P, mm_tg), np.full(P, j), ro,
                               rt, sp_i, zeros, zeros, zeros, zeros)
                        for j in range(len(RHO_STEPS))], axis=1)
    elig = (np.arange(len(RHO_STEPS))[None, :] < rho_i[:, None]) \
        & (mac_rho <= budget[:, None])
    has_relax = elig.any(axis=1) & mac_ok
    rho_i = np.where(has_relax, elig.argmax(axis=1), rho_i)

    # ---- step 2, OFU path: tt4 -> tt5 as a first-feasible chain ------------
    ofu_states = [(0, 0), (1, 0), (1, 1), (1, 2), (1, 3)]
    ofu_chain = np.array([
        max(T.ofu[lattice.index_of(0, mm_tg, 0, 0, 0, 0, p, o, 0, 0)],
            T.sa[lattice.index_of(0, mm_tg, 0, 0, 0, 0, p, o, 0, 0)])
        for o, p in ofu_states])
    opick, ofu_ok = _first_feasible(ofu_chain, budget)
    ostates = np.array(ofu_states, dtype=np.int64)
    ort = ostates[opick, 0]
    pipe = ostates[opick, 1]
    unmet_ofu = ~ofu_ok

    # ---- step 3: register fusion as masked selection -----------------------
    mm_cur = np.full(P, mm_tg, dtype=np.int64)
    ones = np.ones(P, dtype=np.int64)
    crit_full = gather(T.crit, mm_cur, rho_i, ro, rt, sp_i, pipe, ort, ones,
                       ones)
    crit_part = gather(T.crit, mm_cur, rho_i, ro, rt, sp_i, pipe, ort, zeros,
                       ones)
    full_ok = crit_full <= budget
    part_ok = crit_part <= budget
    fts = np.where(full_ok, 1, 0).astype(np.int64)
    fso = np.where(full_ok | part_ok, 1, 0).astype(np.int64)

    # ---- step 4: preference-oriented fine-tuning ---------------------------
    # preference masks evaluated with the scalar searcher's exact comparisons
    power_pref = np.array([p[0] >= max(p[1], p[2]) * 0.999 for p in prefs])
    area_any = np.array([p[1] > 0 for p in prefs])
    area_dom = np.array([p[1] > max(p[0], p[2]) for p in prefs])
    area_ge = np.array([p[1] >= max(p[0], p[2]) for p in prefs])
    area_ge_power = np.array([p[1] >= p[0] for p in prefs])

    def meets(mm_i_, rho_i_, ro_, rt_, sp_i_, pipe_, ort_, fts_, fso_):
        return gather(T.crit, mm_i_, rho_i_, ro_, rt_, sp_i_, pipe_, ort_,
                      fts_, fso_) <= budget

    # ft1 (power): rho back up, then un-split, then drop OFU pipe stages.
    crit_rho = np.stack([meets(mm_cur, np.full(P, j), ro, rt, sp_i, pipe, ort,
                               fts, fso)
                         for j in range(len(RHO_STEPS))], axis=1)
    elig = (np.arange(len(RHO_STEPS))[None, :] < rho_i[:, None]) & crit_rho
    take = elig.any(axis=1) & power_pref
    rho_i = np.where(take, elig.argmax(axis=1), rho_i)

    active = power_pref.copy()
    for _ in range(len(tables.splits) - 1):
        can = active & (sp_i > 0)
        ok = meets(mm_cur, rho_i, ro, rt, np.maximum(sp_i - 1, 0), pipe, ort,
                   fts, fso)
        apply_ = can & ok
        sp_i = np.where(apply_, sp_i - 1, sp_i)
        active = apply_     # a failed halving stops the walk

    active = power_pref.copy()
    for _ in range(len(PIPE_STEPS) - 1):
        can = active & (pipe > 0)
        ok = meets(mm_cur, rho_i, ro, rt, sp_i, np.maximum(pipe - 1, 0), ort,
                   fts, fso)
        apply_ = can & ok
        pipe = np.where(apply_, pipe - 1, pipe)
        active = apply_

    # ft2 (area): OAI22 substitution (MCR permitting), 1T pass-gate mux,
    # un-split columns.
    if spec.mcr <= 2:
        mm_oai = _MM_INDEX[sc.MultMuxKind.OAI22_FUSED]
        ok = meets(np.full(P, mm_oai), rho_i, ro, rt, sp_i, pipe, ort, fts,
                   fso)
        apply_ = area_any & ok & area_ge_power
        mm_cur = np.where(apply_, mm_oai, mm_cur)
    mm_pass = _MM_INDEX[sc.MultMuxKind.PASS_1T]
    ok = meets(np.full(P, mm_pass), rho_i, ro, rt, sp_i, pipe, ort, fts, fso)
    apply_ = area_any & area_dom & (mm_cur != mm_pass) & ok
    mm_cur = np.where(apply_, mm_pass, mm_cur)

    active = area_any & area_ge
    for _ in range(len(tables.splits) - 1):
        can = active & (sp_i > 0)
        ok = meets(mm_cur, rho_i, ro, rt, np.maximum(sp_i - 1, 0), pipe, ort,
                   fts, fso)
        apply_ = can & ok
        sp_i = np.where(apply_, sp_i - 1, sp_i)
        active = apply_

    # ---- materialize + frontier (same dedup/pool/objectives as scalar) -----
    final_idx = lattice.index_of(zeros, mm_cur, rho_i, ro, rt, sp_i, pipe,
                                 ort, fts, fso)
    explored: list[MacroPPA] = []
    seen: set[str] = set()
    seen_idx: set[int] = set()
    for p in range(P):
        i = int(final_idx[p])
        if i in seen_idx:        # distinct lattice points can share a name;
            continue             # same point never needs re-materializing
        seen_idx.add(i)
        audit = ("batched: Alg. 1 replay",)
        if unmet_mac[p]:
            audit += ("tt: MAC path UNMET (exhausted techniques)",)
        if unmet_ofu[p]:
            audit += ("tt: OFU path UNMET (exhausted techniques)",)
        ppa = T.materialize(i, audit=audit)
        if ppa.design.name() not in seen:
            seen.add(ppa.design.name())
            explored.append(ppa)

    feasible = [p for p in explored if p.meets_timing]
    pool = feasible if feasible else explored
    objs = [(p.e_cycle_fj["int_lo"], p.area_um2, 1.0 / p.fmax_hz)
            for p in pool]
    # The shared frontier tail (mask + exact dedup/order) — identical to
    # pareto_indices(objs) on these small pools, and the same tail the
    # lattice-scale sweeps run with their device/sharded masks.
    from . import engine as E
    frontier = [pool[i] for i in E.extract_frontier(objs, nondominated_mask)]
    return SearchResult(spec=spec, frontier=tuple(frontier),
                        explored=tuple(explored), n_evaluated=len(explored))
