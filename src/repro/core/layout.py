"""SDP-style structured placement (paper §III-D / Fig. 6).

The paper places the SRAM array with a scalable Structured-Data-Path TCL
script in Innovus ("regular SRAM place and uniform routing"), fills the gaps
between SRAM columns with adder cells, and APRs the peripherals around the
array.  This module reproduces that stage as an executable floorplanner:

  * deterministic coordinates for every placement region (SRAM banks,
    per-column adder strips, S&A row, OFU/alignment block, WL/BL drivers),
  * aspect-ratio solving against the measured die (455 x 246 um for the
    64x64 MCR=2 macro — Fig. 10),
  * DEF-flavored emission + the SDP script skeleton,
  * overlap/containment invariants checked by tests.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .macro import MacroPPA

# Fabricated macro footprint (Fig. 10): 455 x 246 um.
DIE_W_UM = 455.0
DIE_H_UM = 246.0


@dataclass(frozen=True)
class Region:
    name: str
    x: float
    y: float
    w: float
    h: float

    @property
    def area(self) -> float:
        return self.w * self.h

    def overlaps(self, other: "Region") -> bool:
        return not (self.x + self.w <= other.x + 1e-9 or
                    other.x + other.w <= self.x + 1e-9 or
                    self.y + self.h <= other.y + 1e-9 or
                    other.y + other.h <= self.y + 1e-9)


@dataclass
class Floorplan:
    die_w: float
    die_h: float
    regions: list[Region] = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return sum(r.area for r in self.regions) / (self.die_w * self.die_h)

    def region(self, name: str) -> Region:
        for r in self.regions:
            if r.name == name:
                return r
        raise KeyError(name)


def place(ppa: MacroPPA) -> Floorplan:
    """Deterministic SDP placement for a synthesized macro.

    Layout (bottom-up, mirroring Fig. 6): BL drivers | interleaved
    [SRAM bank row / adder strip] per column group | S&A row | OFU+alignment |
    WL drivers on the left flank.
    """
    spec = ppa.design.spec
    bd = ppa.area_breakdown
    total = sum(bd.values())
    # scale the analytical areas onto the measured die aspect
    die_scale = (ppa.area_um2 / total) if total else 1.0
    die_w = DIE_W_UM * math.sqrt(ppa.area_um2 / (DIE_W_UM * DIE_H_UM * 1.0))
    die_h = ppa.area_um2 / die_w

    wl_w = bd["drivers"] * die_scale * 0.55 / die_h
    x0 = wl_w
    usable_w = die_w - wl_w

    regions = [Region("wl_drivers", 0.0, 0.0, wl_w, die_h)]

    # bottom: BL drivers strip
    bl_h = bd["drivers"] * die_scale * 0.45 / usable_w
    regions.append(Region("bl_drivers", x0, 0.0, usable_w, bl_h))
    y = bl_h

    # interleaved SRAM + adder strips: one pair per column group (SDP rows)
    array_area = (bd["sram_array"] + bd["multmux"]) * die_scale
    adder_area = bd["adder_tree"] * die_scale
    groups = max(1, spec.w // 16)            # 16 columns per SDP group
    pair_h = (array_area + adder_area) / usable_w / groups
    sram_frac = array_area / (array_area + adder_area)
    for g in range(groups):
        regions.append(Region(f"sram_bank_{g}", x0, y,
                              usable_w, pair_h * sram_frac))
        y += pair_h * sram_frac
        regions.append(Region(f"adder_strip_{g}", x0, y,
                              usable_w, pair_h * (1 - sram_frac)))
        y += pair_h * (1 - sram_frac)

    # S&A row
    sa_h = bd["shift_adder"] * die_scale / usable_w
    regions.append(Region("shift_adder", x0, y, usable_w, sa_h))
    y += sa_h
    # OFU + alignment block at the top
    top_h = (bd["ofu"] + bd["align"]) * die_scale / usable_w
    regions.append(Region("ofu_align", x0, y, usable_w, top_h))
    y += top_h

    return Floorplan(die_w=die_w, die_h=max(die_h, y), regions=regions)


def emit_def(fp: Floorplan, name: str = "dcim_macro") -> str:
    """DEF-flavored text (units: nm)."""
    lines = [f"VERSION 5.8 ;", f"DESIGN {name} ;", "UNITS DISTANCE MICRONS 1000 ;",
             f"DIEAREA ( 0 0 ) ( {int(fp.die_w * 1000)} {int(fp.die_h * 1000)} ) ;",
             f"REGIONS {len(fp.regions)} ;"]
    for r in fp.regions:
        lines.append(f"- {r.name} ( {int(r.x * 1000)} {int(r.y * 1000)} ) "
                     f"( {int((r.x + r.w) * 1000)} {int((r.y + r.h) * 1000)} ) ;")
    lines.append("END REGIONS")
    lines.append("END DESIGN")
    return "\n".join(lines)


def emit_sdp_script(ppa: MacroPPA) -> str:
    """The scalable SDP TCL skeleton of §III-D (documentation artifact)."""
    spec = ppa.design.spec
    return "\n".join([
        "# SynDCIM structured-data-path placement (Innovus)",
        f"set H {spec.h}; set W {spec.w}; set MCR {spec.mcr}",
        "createInstGroup sram_array -region [dcim_region sram]",
        "foreach col [dcim_columns $W] {",
        "  sdpCreateGroup -name bank_$col -object [dcim_cells sram $col]",
        "  sdpCreateGroup -name csa_$col  -object [dcim_cells adder $col]",
        "}",
        "sdpPlace -pattern interleave {bank csa}",
        "placeDesign -incremental   ;# peripherals APR'd around the array",
    ])
