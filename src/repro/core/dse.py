"""System-level design-space exploration: mapping AI workloads onto arrays of
SynDCIM macros (the paper's §I framing — "system-level acceleration that DCIM
can offer", with distinct optimizations for vision / language / cloud /
wearable scenarios).

Given a workload (the GEMM inventory of one of the assigned model
architectures) and a synthesized macro design point, this module computes the
accelerator-level throughput/energy/area of an N-macro array executing the
workload — the bridge between the paper's circuit compiler and the JAX
framework's model zoo.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .macro import MacroPPA


@dataclass(frozen=True)
class GemmShape:
    """One GEMM in a model: out[m, n] += a[m, k] @ w[k, n], executed
    ``count`` times per model step."""

    name: str
    m: int
    k: int
    n: int
    count: int = 1

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count


@dataclass(frozen=True)
class MappingReport:
    gemm: GemmShape
    tiles: int                 # weight tiles (k x n) across macro array
    passes: int                # sequential tile waves on n_macros
    cycles: int                # total macro cycles for the GEMM
    weight_reloads: int        # tile swaps requiring BL writes (MCR-aware)
    energy_pj: float
    util: float                # MAC-lane utilization


@dataclass(frozen=True)
class AcceleratorReport:
    macro: str
    n_macros: int
    ib: int
    wb: int
    reports: tuple[MappingReport, ...]
    total_cycles: int
    total_energy_pj: float
    wallclock_s: float
    effective_tops: float      # at the workload's real precision
    avg_util: float
    area_mm2: float

    def summary(self) -> dict:
        return {
            "macro": self.macro,
            "n_macros": self.n_macros,
            "precision": f"INT{self.ib}xINT{self.wb}",
            "total_cycles": self.total_cycles,
            "energy_uj": round(self.total_energy_pj / 1e6, 3),
            "wallclock_ms": round(self.wallclock_s * 1e3, 4),
            "effective_tops": round(self.effective_tops, 4),
            "avg_util": round(self.avg_util, 4),
            "area_mm2": round(self.area_mm2, 3),
        }


def map_gemm(g: GemmShape, ppa: MacroPPA, n_macros: int, ib: int, wb: int
             ) -> MappingReport:
    """Weight-stationary tiling: the (k, n) weight matrix is cut into
    H x (W/wb) tiles held in the macro arrays; activations stream bit-serially
    (ib cycles per row of m).  MCR>1 lets a macro hold ``mcr`` tiles resident
    and switch per cycle, reducing weight reloads (the paper's MCR-aware
    memory-density argument)."""
    spec = ppa.design.spec
    cols_per_out = max(1, spec.w // wb)
    tiles_k = math.ceil(g.k / spec.h)
    tiles_n = math.ceil(g.n / cols_per_out)
    tiles = tiles_k * tiles_n
    resident = n_macros * spec.mcr
    passes = math.ceil(tiles / resident)
    weight_reloads = max(0, tiles - resident) * g.count

    cycles_per_tilewave = g.m * ib
    active_waves = math.ceil(tiles / min(tiles, resident))
    cycles = cycles_per_tilewave * active_waves * g.count
    # Weight reload cost: one row per cycle through BL drivers.
    reload_cycles = weight_reloads * spec.h
    cycles += reload_cycles

    # Energy: per-cycle macro energy (int mode) x active macros x cycles.
    e_cycle_fj = ppa.e_cycle_fj["int_hi" if ib > 4 else "int_lo"]
    active_macros = min(tiles, n_macros)
    energy_pj = (cycles - reload_cycles) * e_cycle_fj * active_macros / 1e3
    # BL write energy estimate per reload: ~array write energy.
    energy_pj += weight_reloads * spec.h * spec.w * 3.6 * ppa.design.spec.mcr / 1e3

    lanes_used = (min(g.k, tiles_k * spec.h) / (tiles_k * spec.h)) * \
                 (min(g.n, tiles_n * cols_per_out) / (tiles_n * cols_per_out))
    util = lanes_used * min(1.0, tiles / resident)
    return MappingReport(gemm=g, tiles=tiles, passes=passes, cycles=cycles,
                         weight_reloads=weight_reloads, energy_pj=energy_pj,
                         util=util)


def accelerator_report(gemms: list[GemmShape], ppa: MacroPPA, n_macros: int,
                       ib: int = 8, wb: int = 8) -> AcceleratorReport:
    reports = tuple(map_gemm(g, ppa, n_macros, ib, wb) for g in gemms)
    total_cycles = sum(r.cycles for r in reports)
    total_energy = sum(r.energy_pj for r in reports)
    f = min(ppa.fmax_hz, ppa.design.spec.f_mac_hz) if ppa.meets_timing else ppa.fmax_hz
    wall = total_cycles / f
    macs = sum(r.gemm.macs for r in reports)
    tops = 2.0 * macs / wall / 1e12 if wall > 0 else 0.0
    avg_util = (sum(r.util * r.cycles for r in reports) / total_cycles
                if total_cycles else 0.0)
    return AcceleratorReport(
        macro=ppa.design.name(), n_macros=n_macros, ib=ib, wb=wb,
        reports=reports, total_cycles=total_cycles,
        total_energy_pj=total_energy, wallclock_s=wall,
        effective_tops=tops, avg_util=avg_util,
        area_mm2=n_macros * ppa.area_um2 / 1e6)
