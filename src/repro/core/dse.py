"""System-level design-space exploration: mapping AI workloads onto arrays of
SynDCIM macros (the paper's §I framing — "system-level acceleration that DCIM
can offer", with distinct optimizations for vision / language / cloud /
wearable scenarios).

Given a workload (the GEMM inventory of one of the assigned model
architectures) and a synthesized macro design point, this module computes the
accelerator-level throughput/energy/area of an N-macro array executing the
workload — the bridge between the paper's circuit compiler and the JAX
framework's model zoo.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

# reporting_frequency is defined next to rollup (which also applies it); the
# scalar and batched reports below clamp through that one definition so the
# two paths can never drift.
from .macro import MacroPPA, reporting_frequency
from .pareto import nondominated_mask_auto, pareto_indices


@dataclass(frozen=True)
class GemmShape:
    """One GEMM in a model: out[m, n] += a[m, k] @ w[k, n], executed
    ``count`` times per model step."""

    name: str
    m: int
    k: int
    n: int
    count: int = 1

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count


def gemm_inventory(cfg, seq: int = 256) -> list[GemmShape]:
    """Model-zoo GEMM inventory: the per-token-batch weight-side GEMMs of one
    decoder layer x n_layers for an assigned architecture config (attention
    score/value matmuls are activation-activation and stay outside the
    weight-stationary CIM mapping).  This is the workload description the
    co-design sweep and serving-time macro selection map onto macro arrays."""
    d, hd = cfg.d_model, cfg.hd
    gs = [
        GemmShape("wq", seq, d, cfg.n_heads * hd, cfg.n_layers),
        GemmShape("wk", seq, d, cfg.n_kv_heads * hd, cfg.n_layers),
        GemmShape("wv", seq, d, cfg.n_kv_heads * hd, cfg.n_layers),
        GemmShape("wo", seq, cfg.n_heads * hd, d, cfg.n_layers),
    ]
    if cfg.family == "moe":
        e_active = cfg.moe.top_k
        gs += [GemmShape("moe_up", seq, d, 2 * cfg.moe.d_expert,
                         cfg.n_layers * e_active),
               GemmShape("moe_down", seq, cfg.moe.d_expert, d,
                         cfg.n_layers * e_active)]
    else:
        gs += [GemmShape("mlp_up", seq, d, 2 * cfg.d_ff, cfg.n_layers),
               GemmShape("mlp_down", seq, cfg.d_ff, d, cfg.n_layers)]
    return gs


@dataclass(frozen=True)
class MappingReport:
    gemm: GemmShape
    tiles: int                 # weight tiles (k x n) across macro array
    passes: int                # sequential tile waves on n_macros
    cycles: int                # total macro cycles for the GEMM
    weight_reloads: int        # tile swaps requiring BL writes (MCR-aware)
    energy_pj: float
    util: float                # MAC-lane utilization


@dataclass(frozen=True)
class AcceleratorReport:
    macro: str
    n_macros: int
    ib: int
    wb: int
    reports: tuple[MappingReport, ...]
    total_cycles: int
    total_energy_pj: float
    wallclock_s: float
    effective_tops: float      # at the workload's real precision
    avg_util: float
    area_mm2: float

    def summary(self) -> dict:
        return {
            "macro": self.macro,
            "n_macros": self.n_macros,
            "precision": f"INT{self.ib}xINT{self.wb}",
            "total_cycles": self.total_cycles,
            "energy_uj": round(self.total_energy_pj / 1e6, 3),
            "wallclock_ms": round(self.wallclock_s * 1e3, 4),
            "effective_tops": round(self.effective_tops, 4),
            "avg_util": round(self.avg_util, 4),
            "area_mm2": round(self.area_mm2, 3),
        }


def map_gemm(g: GemmShape, ppa: MacroPPA, n_macros: int, ib: int, wb: int
             ) -> MappingReport:
    """Weight-stationary tiling: the (k, n) weight matrix is cut into
    H x (W/wb) tiles held in the macro arrays; activations stream bit-serially
    (ib cycles per row of m).  MCR>1 lets a macro hold ``mcr`` tiles resident
    and switch per cycle, reducing weight reloads (the paper's MCR-aware
    memory-density argument)."""
    spec = ppa.design.spec
    cols_per_out = max(1, spec.w // wb)
    tiles_k = math.ceil(g.k / spec.h)
    tiles_n = math.ceil(g.n / cols_per_out)
    tiles = tiles_k * tiles_n
    resident = n_macros * spec.mcr
    passes = math.ceil(tiles / resident)
    weight_reloads = max(0, tiles - resident) * g.count

    cycles_per_tilewave = g.m * ib
    active_waves = math.ceil(tiles / min(tiles, resident))
    cycles = cycles_per_tilewave * active_waves * g.count
    # Weight reload cost: one row per cycle through BL drivers.
    reload_cycles = weight_reloads * spec.h
    cycles += reload_cycles

    # Energy: per-cycle macro energy (int mode) x active macros x cycles.
    e_cycle_fj = ppa.e_cycle_fj["int_hi" if ib > 4 else "int_lo"]
    active_macros = min(tiles, n_macros)
    energy_pj = (cycles - reload_cycles) * e_cycle_fj * active_macros / 1e3
    # BL write energy estimate per reload: ~array write energy.
    energy_pj += weight_reloads * spec.h * spec.w * 3.6 * ppa.design.spec.mcr / 1e3

    lanes_used = (min(g.k, tiles_k * spec.h) / (tiles_k * spec.h)) * \
                 (min(g.n, tiles_n * cols_per_out) / (tiles_n * cols_per_out))
    util = lanes_used * min(1.0, tiles / resident)
    return MappingReport(gemm=g, tiles=tiles, passes=passes, cycles=cycles,
                         weight_reloads=weight_reloads, energy_pj=energy_pj,
                         util=util)


# ---------------------------------------------------------------------------
# Batched workload x design mapping (vectorized map_gemm / accelerator_report)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadMatrix:
    """All (GEMM, design) mappings of one workload in structure-of-arrays.

    Row g, column d mirrors ``map_gemm(gemms[g], ppas[d], ...)`` exactly; the
    per-design totals mirror ``accelerator_report``.  Produced in one
    vectorized float64 numpy pass executing op-for-op, so values are
    bit-identical to the scalar path (see batched_workload_matrix for why
    this map must not be jitted)."""

    designs: tuple[str, ...]
    n_macros: int
    ib: int
    wb: int
    # All arrays are float64 computed op-for-op like the scalar path, so
    # integer-valued quantities (cycles, reloads) are exact.
    cycles: np.ndarray            # (G, D)
    energy_pj: np.ndarray         # (G, D)
    weight_reloads: np.ndarray    # (G, D)
    tiles: np.ndarray             # (G, D)
    util: np.ndarray              # (G, D)
    total_cycles: np.ndarray      # (D,)
    total_energy_pj: np.ndarray   # (D,)
    wallclock_s: np.ndarray       # (D,)
    effective_tops: np.ndarray    # (D,)
    avg_util: np.ndarray          # (D,)
    area_mm2: np.ndarray          # (D,)


def batched_workload_matrix(gemms: Sequence[GemmShape],
                            ppas: Sequence[MacroPPA], n_macros: int,
                            ib: int = 8, wb: int = 8) -> WorkloadMatrix:
    """Map every GEMM of a workload onto every candidate design point in one
    fused pass — the batched counterpart of calling ``accelerator_report``
    per design.  Arithmetic mirrors :func:`map_gemm` operation for operation
    (float ceils included) so totals are bit-identical.  Runs on vectorized
    float64 numpy: at these shapes per-op dispatch dominates, so the
    lattice-scale JAX kernel lives in :mod:`repro.core.batched` while this
    map stays dispatch-free."""
    G, D = len(gemms), len(ppas)
    m = np.array([g.m for g in gemms], dtype=np.float64)[:, None]
    k = np.array([g.k for g in gemms], dtype=np.float64)[:, None]
    n = np.array([g.n for g in gemms], dtype=np.float64)[:, None]
    count = np.array([g.count for g in gemms], dtype=np.float64)[:, None]

    h = np.array([p.design.spec.h for p in ppas], dtype=np.float64)[None, :]
    w = np.array([p.design.spec.w for p in ppas], dtype=np.float64)[None, :]
    mcr = np.array([p.design.spec.mcr for p in ppas],
                   dtype=np.float64)[None, :]
    cpo = np.array([max(1, p.design.spec.w // wb) for p in ppas],
                   dtype=np.float64)[None, :]
    e_cyc = np.array([p.e_cycle_fj["int_hi" if ib > 4 else "int_lo"]
                      for p in ppas], dtype=np.float64)[None, :]

    tiles_k = np.ceil(k / h)
    tiles_n = np.ceil(n / cpo)
    tiles = tiles_k * tiles_n
    resident = n_macros * mcr
    weight_reloads = np.maximum(0.0, tiles - resident) * count
    cpt = m * ib
    active_waves = np.ceil(tiles / np.minimum(tiles, resident))
    cycles = cpt * active_waves * count
    reload_cycles = weight_reloads * h
    cycles = cycles + reload_cycles
    active_macros = np.minimum(tiles, float(n_macros))
    energy_pj = (cycles - reload_cycles) * e_cyc * active_macros / 1e3
    energy_pj = energy_pj + (weight_reloads * h * w * 3.6 * mcr / 1e3)
    lanes = (np.minimum(k, tiles_k * h) / (tiles_k * h)) \
        * (np.minimum(n, tiles_n * cpo) / (tiles_n * cpo))
    util = lanes * np.minimum(1.0, tiles / resident)

    # per-design totals, accumulated in scalar summation order
    total_cycles = np.zeros(D)
    total_energy = np.zeros(D)
    util_cycles = np.zeros(D)
    for g in range(G):
        total_cycles = total_cycles + cycles[g]
        total_energy = total_energy + energy_pj[g]
        util_cycles = util_cycles + util[g] * cycles[g]

    f = reporting_frequency(
        np.array([p.fmax_hz for p in ppas]),
        np.array([p.design.spec.f_mac_hz for p in ppas]),
        np.array([p.meets_timing for p in ppas]))
    wall = total_cycles / f
    macs = sum(g.macs for g in gemms)
    tops = np.where(wall > 0, 2.0 * macs / wall / 1e12, 0.0)
    avg_util = np.where(total_cycles != 0, util_cycles / total_cycles, 0.0)
    area_mm2 = np.array([n_macros * p.area_um2 / 1e6 for p in ppas])

    return WorkloadMatrix(
        designs=tuple(p.design.name() for p in ppas), n_macros=n_macros,
        ib=ib, wb=wb, cycles=cycles, energy_pj=energy_pj,
        weight_reloads=weight_reloads, tiles=tiles,
        util=util, total_cycles=total_cycles,
        total_energy_pj=total_energy, wallclock_s=wall,
        effective_tops=tops, avg_util=avg_util, area_mm2=area_mm2)


@dataclass(frozen=True)
class CodesignReport:
    """Cross-scenario co-design: every workload of the model zoo mapped onto
    every candidate macro design point (paper Fig. 8 extended across
    vision/language/MoE scenarios).  Frontier indices minimize
    (total wallclock, total energy, array area) across the whole inventory."""

    workloads: tuple[str, ...]
    designs: tuple[str, ...]
    n_macros: int
    wallclock_s: np.ndarray       # (W, D)
    energy_pj: np.ndarray         # (W, D)
    effective_tops: np.ndarray    # (W, D)
    avg_util: np.ndarray          # (W, D)
    area_mm2: np.ndarray          # (D,)
    total_wallclock_s: np.ndarray   # (D,)
    total_energy_pj: np.ndarray     # (D,)
    frontier: tuple[int, ...]       # design indices on the co-design frontier

    def best_for(self, workload: str) -> int:
        """Design index with the lowest wallclock for one workload."""
        wi = self.workloads.index(workload)
        return int(np.argmin(self.wallclock_s[wi]))

    def summary(self) -> dict:
        return {
            "workloads": len(self.workloads),
            "designs": len(self.designs),
            "frontier": [self.designs[i] for i in self.frontier],
            "wallclock_spread": float(self.total_wallclock_s.max()
                                      / self.total_wallclock_s.min()),
            "energy_spread": float(self.total_energy_pj.max()
                                   / self.total_energy_pj.min()),
        }


def cross_workload_codesign(workloads: Mapping[str, Sequence[GemmShape]],
                            ppas: Sequence[MacroPPA], n_macros: int = 256,
                            ib: int = 8, wb: int = 8) -> CodesignReport:
    """Batch-map a whole GEMM inventory (workload x design) and extract the
    cross-scenario Pareto frontier over (latency, energy, area)."""
    if not workloads:
        raise ValueError("need at least one workload")
    if not ppas:
        raise ValueError("need at least one candidate design point")
    names = tuple(workloads)
    mats = [batched_workload_matrix(workloads[nm], ppas, n_macros, ib, wb)
            for nm in names]
    wall = np.stack([m.wallclock_s for m in mats])
    energy = np.stack([m.total_energy_pj for m in mats])
    tops = np.stack([m.effective_tops for m in mats])
    util = np.stack([m.avg_util for m in mats])
    area = mats[0].area_mm2
    # Totals accumulate in canonical (name-sorted) order so the frontier is
    # invariant under permutation of the workloads mapping — dict-insertion
    # order must never move a design on or off the co-design frontier.
    total_wall = np.zeros(len(ppas))
    total_energy = np.zeros(len(ppas))
    for wi in sorted(range(len(names)), key=lambda i: names[i]):
        total_wall = total_wall + wall[wi]
        total_energy = total_energy + energy[wi]
    objs = [(float(total_wall[d]), float(total_energy[d]), float(area[d]))
            for d in range(len(ppas))]
    # Candidate pools can reach lattice scale (exhaustive sweeps pooled
    # across 100+ specs); the auto mask runs the extraction device-sharded
    # there and on the host below the payoff point — same bits either way.
    frontier = tuple(pareto_indices(objs, mask_fn=nondominated_mask_auto))
    return CodesignReport(
        workloads=names, designs=mats[0].designs, n_macros=n_macros,
        wallclock_s=wall, energy_pj=energy, effective_tops=tops,
        avg_util=util, area_mm2=area, total_wallclock_s=total_wall,
        total_energy_pj=total_energy, frontier=frontier)


def accelerator_report(gemms: list[GemmShape], ppa: MacroPPA, n_macros: int,
                       ib: int = 8, wb: int = 8) -> AcceleratorReport:
    reports = tuple(map_gemm(g, ppa, n_macros, ib, wb) for g in gemms)
    total_cycles = sum(r.cycles for r in reports)
    total_energy = sum(r.energy_pj for r in reports)
    f = float(reporting_frequency(ppa.fmax_hz, ppa.design.spec.f_mac_hz,
                                  ppa.meets_timing))
    wall = total_cycles / f
    macs = sum(r.gemm.macs for r in reports)
    tops = 2.0 * macs / wall / 1e12 if wall > 0 else 0.0
    avg_util = (sum(r.util * r.cycles for r in reports) / total_cycles
                if total_cycles else 0.0)
    return AcceleratorReport(
        macro=ppa.design.name(), n_macros=n_macros, ib=ib, wb=wb,
        reports=reports, total_cycles=total_cycles,
        total_energy_pj=total_energy, wallclock_s=wall,
        effective_tops=tops, avg_util=avg_util,
        area_mm2=n_macros * ppa.area_um2 / 1e6)
