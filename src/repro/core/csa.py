"""Bit-wise carry-save adder-tree designs (paper Fig. 4 / §III-B).

The paper's adder-tree contribution is a *family* of bit-wise CSAs mixing 4-2
compressors (power/area-efficient but slow) with full adders (fast but
costlier), plus two structural optimizations:

  * **port reordering** — carry outputs are faster than sum outputs, so
    re-wiring cell-to-cell connections to put late-arriving signals on
    fast-propagating ports shaves the critical path (~10%);
  * **retiming** — the register at the tree output can be moved *before* the
    final ripple-carry stage (tt2 in Alg. 1), removing the RCA from the MAC
    critical path at the cost of one extra pipeline register stage.

``CSADesign`` captures one point in that family; :func:`characterize` returns
its PPA.  ``build_netlist`` emits a gate-level structural netlist for the
functional simulator (``repro.core.gatesim``), which is how we validate that
synthesized trees actually compute Σ (the paper's post-synthesis gate-level
simulation stage).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .tech import TechModel

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CSADesign:
    """One adder-tree design point.

    Attributes:
      rho:        fraction of reduction done by 4-2 compressors (1.0 = the
                  all-compressor tree of [11]; 0.0 = all-FA Wallace-style).
      reorder:    carry/sum port-delay-aware reordering (Fig. 4 right).
      retimed:    register moved before the final RCA stage (tt2).
      split:      column split factor (tt3): H rows are reduced by ``split``
                  independent sub-trees whose outputs merge in a registered
                  CSA stage; halving tree height shortens the critical path
                  at +1 cycle latency.
    """

    rho: float = 1.0
    reorder: bool = False
    retimed: bool = False
    split: int = 1

    def name(self) -> str:
        tag = f"csa_rho{int(round(self.rho * 100)):03d}"
        if self.reorder:
            tag += "_ro"
        if self.retimed:
            tag += "_rt"
        if self.split > 1:
            tag += f"_sp{self.split}"
        return tag


@dataclass(frozen=True)
class CSAReport:
    """PPA of one characterized tree (relative units; see tech.py)."""

    crit_path_rel: float        # tau units: operands-in -> registered output
    energy_rel: float           # eps units per cycle at 100% activity
    area_um2: float
    n_fa: int
    n_comp42: int
    n_ha: int
    n_reg_bits: int
    stages: int
    latency_cycles: int         # pipeline latency through the tree
    acc_width: int              # output width (bits)
    rca_width: int              # final RCA width


# ---------------------------------------------------------------------------
# Analytical characterization
# ---------------------------------------------------------------------------


def characterize(design: CSADesign, h_rows: int, product_bits: int,
                 tech: TechModel) -> CSAReport:
    """Analytical PPA of ``design`` reducing ``h_rows`` products of
    ``product_bits`` bits each.

    Modeling note (matches the paper's qualitative claims, §III-B): the tree
    *structure* is the 4-2 reduction tree of [11] — ceil(log2(H/2)) levels,
    each halving the operand count.  The mix parameter ``rho`` substitutes
    compressors with rebalanced full-adder pairs along the critical path:
    FA-based stage variants approach single-FA sum delay (faster), at ~2x the
    cells of a compressor (more power/area) — "for strict timing constraints,
    we replace 4-2 compressors with full adders to shorten the critical path,
    sacrificing power and area".
    """
    if h_rows < 2:
        raise ValueError(f"adder tree needs >= 2 rows, got {h_rows}")
    split = max(1, min(design.split, h_rows // 4 if h_rows >= 8 else 1))
    rows_per_tree = math.ceil(h_rows / split)

    # 4-2 tree structure: each level halves the operand count down to 2.
    n_stages = max(1, math.ceil(math.log2(max(2, rows_per_tree) / 2.0)))
    # Total 4->2 compression units: each removes 2 operands.
    n_units = max(1, (rows_per_tree - 2 + 1) // 2) * split

    # Bit growth: products enter at product_bits; widths grow ~1 bit per
    # stage of reduction.  Average active width across the tree:
    acc_width = product_bits + math.ceil(math.log2(max(2, h_rows)))
    avg_width = product_bits + math.ceil(math.log2(max(2, rows_per_tree))) / 2.0

    n_comp = int(round(n_units * design.rho))
    n_fapair = n_units - n_comp          # each realized as 2 full adders
    n_ha = n_stages * split              # column-edge half adders

    # Scale cell counts by bit width (cells are per bit column).
    n_comp_bits = int(round(n_comp * avg_width))
    n_fa_bits = int(round(n_fapair * 2 * avg_width))
    n_ha_bits = n_ha

    # --- critical path -----------------------------------------------------
    d_comp = tech.d_comp42_sum
    d_fa = tech.d_fa_sum
    if design.reorder:
        # Late signals wired onto carry ports: effective per-stage delay moves
        # toward the carry path.  (~10% observed in the paper's family.)
        d_comp = 0.65 * tech.d_comp42_sum + 0.35 * tech.d_comp42_carry
        d_fa = 0.65 * tech.d_fa_sum + 0.35 * tech.d_fa_carry
    # Critical-path cells interpolate from all-compressor (rho=1) to
    # rebalanced-FA (rho=0) stage variants.
    d_stage = design.rho * d_comp + (1.0 - design.rho) * d_fa
    tree_delay = d_stage * n_stages

    rca_width = acc_width
    rca_delay = tech.d_rca_per_bit * rca_width + tech.d_fa_sum

    # Split-merge: sub-tree outputs merge in their own *registered* CSA stage.
    merge_delay = 0.0
    latency = 1  # tree output register
    if split > 1:
        merge_delay = d_stage * math.ceil(math.log2(split)) * 2  # CS pairs
        latency += 1

    if design.retimed:
        # Register before the RCA: the RCA becomes its own pipeline stage.
        crit = max(tree_delay, merge_delay, rca_delay) + tech.d_reg_cq_su
        latency += 1
    else:
        crit = max(tree_delay, merge_delay + rca_delay) + tech.d_reg_cq_su
        if split == 1:
            crit = tree_delay + rca_delay + tech.d_reg_cq_su

    # --- energy (per cycle, 100% activity; caller applies activity factor) --
    energy = (n_comp_bits * tech.e_comp42 + n_fa_bits * tech.e_fa
              + n_ha_bits * tech.e_ha)
    energy += rca_width * tech.e_fa * split  # final RCA(s)
    n_reg_bits = acc_width * 2 * split  # carry-save pair registered
    if design.retimed:
        n_reg_bits += acc_width * split
    energy += n_reg_bits * (tech.e_reg * 0.25 + tech.e_clk_per_reg)

    # --- area ----------------------------------------------------------------
    area = (n_comp_bits * tech.a_comp42 + n_fa_bits * tech.a_fa
            + n_ha_bits * tech.a_ha + rca_width * tech.a_fa * split
            + n_reg_bits * tech.a_reg)

    return CSAReport(
        crit_path_rel=crit,
        energy_rel=energy,
        area_um2=area,
        n_fa=n_fa_bits,
        n_comp42=n_comp_bits,
        n_ha=n_ha_bits,
        n_reg_bits=n_reg_bits,
        stages=n_stages + (1 if split > 1 else 0),
        latency_cycles=latency,
        acc_width=acc_width,
        rca_width=rca_width,
    )


# Standard design-point family offered by the SCL (paper Fig. 4: "a series of
# bit-wise CSAs tailored for different PPA preferences").
FAMILY: tuple[CSADesign, ...] = tuple(
    CSADesign(rho=rho, reorder=ro, retimed=rt)
    for rho in (1.0, 0.75, 0.5, 0.25, 0.0)
    for ro in (False, True)
    for rt in (False, True)
)

# Column-split factors reachable by Alg. 1's tt3 transform (split < 4 guard).
SPLIT_STEPS: tuple[int, ...] = (1, 2, 4)


def valid_splits(h_rows: int) -> tuple[int, ...]:
    """Splits for which ``characterize`` does not clamp and tt3's
    ``h // (split) >= 4`` feasibility holds — the discrete split axis of the
    batched design lattice."""
    return tuple(s for s in SPLIT_STEPS if s == 1 or h_rows // s >= 4)


# ---------------------------------------------------------------------------
# Gate-level netlist construction (for repro.core.gatesim)
# ---------------------------------------------------------------------------


@dataclass
class Gate:
    kind: str                   # 'FA' | 'HA' | 'C42' | 'BUF'
    ins: list[str]
    outs: list[str]             # FA/HA/C42: [sum, carry(, cout)]


@dataclass
class TreeNetlist:
    """Structural netlist of one adder tree at a single bit-column granularity
    abstracted to operand granularity: each wire carries a full integer lane.

    gatesim evaluates it with integer carry-save semantics: an FA node maps
    (a, b, c) -> (a^b^c, majority<<1); a 4-2 compressor maps 5 inputs to
    (sum, carry<<1, cout<<1) using two chained FAs — exactly the paper's
    "4-2 compressor as a 5-3 carry-save adder" construction.
    """

    n_inputs: int
    gates: list[Gate] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)


def build_netlist(design: CSADesign, h_rows: int) -> TreeNetlist:
    """Build an executable carry-save reduction netlist for ``h_rows`` operand
    lanes following the design's reduction schedule."""
    nl = TreeNetlist(n_inputs=h_rows)
    wires = [f"in{i}" for i in range(h_rows)]
    uid = 0

    def fresh(prefix: str) -> str:
        nonlocal uid
        uid += 1
        return f"{prefix}{uid}"

    cout_carry = None  # chain compressor cout within a stage
    while len(wires) > 2:
        nxt: list[str] = []
        i = 0
        n = len(wires)
        want_comp_in = int(round(design.rho * n / 4.0)) * 4
        want_comp_in = min(want_comp_in, (n // 4) * 4)
        ncomp = want_comp_in // 4
        cout_carry = None
        for _ in range(ncomp):
            a, b, c, d = wires[i:i + 4]
            i += 4
            cin = cout_carry if cout_carry is not None else "zero"
            s, cy, co = fresh("s"), fresh("c"), fresh("co")
            nl.gates.append(Gate("C42", [a, b, c, d, cin], [s, cy, co]))
            nxt += [s, cy]
            cout_carry = co
        if cout_carry is not None:
            nxt.append(cout_carry)
            cout_carry = None
        while len(wires) - i >= 3:
            a, b, c = wires[i:i + 3]
            i += 3
            s, cy = fresh("s"), fresh("c")
            nl.gates.append(Gate("FA", [a, b, c], [s, cy]))
            nxt += [s, cy]
        nxt += wires[i:]
        if len(nxt) >= len(wires):  # force progress on degenerate mixes
            a, b, c = nxt[0], nxt[1], nxt[2] if len(nxt) > 2 else "zero"
            s, cy = fresh("s"), fresh("c")
            nl.gates.append(Gate("FA", [a, b, c], [s, cy]))
            nxt = [s, cy] + nxt[3:]
        wires = nxt
    # Final RCA: modeled as one ADD node (gatesim evaluates exactly).
    out = fresh("rca")
    nl.gates.append(Gate("RCA", list(wires), [out]))
    nl.outputs = [out]
    return nl
