"""Spec-batched co-synthesis engine (multi-spec-oriented synthesis at scale).

The paper's pitch is *multi-spec-oriented* synthesis: one compiler run serves
many deployment scenarios (§I names vision, language, cloud and wearable
workloads with distinct PPA postures).  :mod:`repro.core.batched` evaluates
the full design lattice for ONE spec; this module is the **"vmap" strategy**
over the shared execution engine (:mod:`repro.core.engine`): specs are
grouped by lattice signature, each group's subcircuit tables are stacked
along a leading spec axis, and the same jitted float64 roll-up kernel runs
under ``jax.vmap``, so N macro specs are synthesized in one fused pass:

  ``evaluate_many``
      plan + execute through the engine with the "vmap" strategy.  The
      kernel and the numpy roll-up tail are the *same code* the single-spec
      engine runs, so per-spec results are bit-identical to
      :func:`repro.core.batched.evaluate`.

  ``mso_search_many``
      Algorithm 1 replayed per spec against the fused evaluation — frontiers
      are bit-identical to looping ``mso_search(backend="batched")`` over the
      specs, at a fraction of the dispatch cost.

  ``design_space_sweep_many`` / ``pareto_chunk_size``
      exhaustive multi-spec sweeps with chunked Pareto extraction sized for
      the accelerator's memory budget.

  ``scenario_specs``
      the §I deployment scenarios as concrete :class:`MacroSpec` values — the
      default multi-spec synthesis set for serving-time macro selection
      (:mod:`repro.serve.select`).

Grouping, packing and the shared numpy tail live in the engine layer
(:func:`repro.core.engine.pack_group` and friends); this module keeps only
the multi-spec entry points and the scenario/frontier-pooling helpers.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from . import batched as B
from . import engine as E
from . import subcircuits as sc
from .batched import BatchedPPA, BatchedSweep, DesignLattice, SpecTables
from .macro import MacroSpec
# Chunk sizing lives with the shared Pareto predicate; re-exported here
# because multi-spec sweeps are where accelerator-sized chunking matters.
from .pareto import PARETO_EPS, nondominated_mask_auto
from .pareto import DEFAULT_PARETO_BUDGET_BYTES, pareto_chunk_size  # noqa: F401  (re-export)
from .searcher import SearchResult
from .tech import TechModel

# Historical import surface: the vmapped kernel and the vmap-group key moved
# to the shared engine layer; these names are aliases, not copies.
_eval_kernel_many = E._eval_kernel_many
_group_key = E.group_key


def scenario_specs() -> dict[str, MacroSpec]:
    """The paper's §I deployment scenarios as compiler inputs.

    One shared geometry (64x64, INT + FP4/FP8) with scenario-specific
    postures, so all four land in one vmap group:

      vision    edge camera pipelines — the Fig. 8 balanced spec.
      language  LLM decode — MCR=4 buys weight residency for big GEMMs.
      cloud     datacenter throughput — 1.1 GHz at nominal-high voltage.
      wearable  always-on low power — 250 MHz at 0.7 V.
    """
    return {
        "vision": MacroSpec(h=64, w=64, mcr=2, int_precisions=(4, 8),
                            fp_precisions=("FP4", "FP8"), f_mac_hz=800e6,
                            f_wupdate_hz=800e6, vdd=0.9),
        "language": MacroSpec(h=64, w=64, mcr=4, int_precisions=(4, 8),
                              fp_precisions=("FP4", "FP8"), f_mac_hz=800e6,
                              f_wupdate_hz=100e6, vdd=0.9),
        "cloud": MacroSpec(h=64, w=64, mcr=2, int_precisions=(4, 8),
                           fp_precisions=("FP4", "FP8"), f_mac_hz=1.1e9,
                           f_wupdate_hz=1.1e9, vdd=1.2),
        "wearable": MacroSpec(h=64, w=64, mcr=2, int_precisions=(2, 4),
                              fp_precisions=("FP4", "FP8"), f_mac_hz=250e6,
                              f_wupdate_hz=250e6, vdd=0.7),
    }


# ---------------------------------------------------------------------------
# Multi-spec evaluation + search + sweep entry points
# ---------------------------------------------------------------------------


def evaluate_many(specs: Sequence[MacroSpec], tech: TechModel,
                  memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS,
                  config: B.LatticeConfig | None = None
                  ) -> list[tuple[DesignLattice, SpecTables, BatchedPPA]]:
    """Evaluate every design point of every spec, batching same-shape specs
    through one vmapped kernel launch.  Results are returned in input order
    and are bit-identical per spec to :func:`repro.core.batched.evaluate`.
    ``config`` selects the registered axis set (seed when None)."""
    return E.execute(E.plan(list(specs), tech, tuple(memcells), mode="vmap",
                            config=config))


def mso_search_many(specs: Sequence[MacroSpec], scl=None,
                    tech: TechModel = None, resolution: int = 4,
                    config: B.LatticeConfig | None = None
                    ) -> list[SearchResult]:
    """Synthesize N macro specs in one fused pass.

    Per-spec results (explored set, frontier, every PPA field) are
    bit-identical to looping ``mso_search(spec, backend="batched")`` — the
    vmapped kernel and shared roll-up tail compute the same float64
    arithmetic; only the dispatch is fused.  ``scl`` is accepted for
    signature parity with :func:`repro.core.searcher.mso_search`."""
    if tech is None:
        raise ValueError("tech model required")
    evals = evaluate_many(specs, tech, memcells=(sc.MemCellKind.SRAM_6T,),
                          config=config)
    return [B._alg1_replay(lat, tab, T, resolution)
            for lat, tab, T in evals]


def design_space_sweep_many(specs: Sequence[MacroSpec], tech: TechModel,
                            memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS,
                            config: B.LatticeConfig | None = None
                            ) -> list[BatchedSweep]:
    """Exhaustive sweeps for N specs in one fused pass (the multi-spec
    counterpart of :func:`repro.core.batched.design_space_sweep`)."""
    return [BatchedSweep(lattice=lat, tables=tab, ppa=T)
            for lat, tab, T in evaluate_many(specs, tech, memcells,
                                             config=config)]


def frontier_union(results: Iterable[SearchResult],
                   names: Sequence[str] | None = None,
                   extract: bool = False, eps: float = PARETO_EPS):
    """Union of per-spec frontiers, deduplicated by (spec, design name) — the
    serving-time candidate pool for cross-workload co-design.  Points from
    different specs always stay distinct (a design name does not encode its
    spec's geometry or constraints).

    With ``names`` (one label per result), returns ``(pool, labels)`` where
    each pool entry is labeled ``"<name>/<design name>"`` by the first result
    that contributed it; without, returns the pool alone.

    With ``extract=True`` the pooled points are additionally filtered to the
    *pooled* Pareto frontier under the shared ``eps`` band and the searcher's
    objective tuple (energy/cycle INT-lo, area, period) — a per-spec frontier
    point eps-dominated by another spec's point is dropped.  At lattice-scale
    pool sizes the mask runs device-sharded
    (:func:`repro.core.pareto.nondominated_mask_auto`, bit-identical to the
    host pass); pool order is preserved."""
    results = list(results)
    if names is not None and len(names) != len(results):
        raise ValueError("names must match results one-to-one")
    pool, labels, seen = [], [], set()
    for ri, res in enumerate(results):
        for p in res.frontier:
            key = (p.design.spec, p.design.name())
            if key not in seen:
                seen.add(key)
                pool.append(p)
                if names is not None:
                    labels.append(f"{names[ri]}/{p.design.name()}")
    if extract and pool:
        objs = np.asarray([(p.e_cycle_fj["int_lo"], p.area_um2,
                            1.0 / p.fmax_hz) for p in pool])
        mask = nondominated_mask_auto(objs, eps)
        pool = [p for p, keep in zip(pool, mask) if keep]
        labels = [lb for lb, keep in zip(labels, mask) if keep]
    return pool if names is None else (pool, labels)
