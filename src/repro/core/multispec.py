"""Spec-batched co-synthesis engine (multi-spec-oriented synthesis at scale).

The paper's pitch is *multi-spec-oriented* synthesis: one compiler run serves
many deployment scenarios (§I names vision, language, cloud and wearable
workloads with distinct PPA postures).  :mod:`repro.core.batched` evaluates
the full design lattice for ONE spec; this module stacks the per-spec
subcircuit tables (:class:`~repro.core.batched.SpecTables`) along a leading
spec axis and runs the same jitted float64 roll-up kernel under ``jax.vmap``,
so N macro specs are synthesized in one fused device pass:

  ``evaluate_many``
      group specs by lattice signature (same dims / split axis / mode count),
      stack each group's tables, and run the vmapped kernel once per group.
      The kernel and the numpy roll-up tail are the *same code* the
      single-spec engine runs, so per-spec results are bit-identical to
      :func:`repro.core.batched.evaluate`.

  ``mso_search_many``
      Algorithm 1 replayed per spec against the fused evaluation — frontiers
      are bit-identical to looping ``mso_search(backend="batched")`` over the
      specs, at a fraction of the dispatch cost.

  ``design_space_sweep_many`` / ``pareto_chunk_size``
      exhaustive multi-spec sweeps with chunked Pareto extraction sized for
      the accelerator's memory budget.

  ``scenario_specs``
      the §I deployment scenarios as concrete :class:`MacroSpec` values — the
      default multi-spec synthesis set for serving-time macro selection
      (:mod:`repro.serve.select`).
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import batched as B
from . import subcircuits as sc
from .batched import BatchedPPA, BatchedSweep, DesignLattice, SpecTables
from .macro import MacroSpec
# Chunk sizing lives with the shared Pareto predicate; re-exported here
# because multi-spec sweeps are where accelerator-sized chunking matters.
from .pareto import DEFAULT_PARETO_BUDGET_BYTES, pareto_chunk_size  # noqa: F401
from .searcher import SearchResult
from .tech import TechModel

# The single-spec kernel, vmapped over a leading spec axis: the gather-index
# tuple is shared (in_axes=None) while every table, constant and mode array
# carries one row per spec.  Gathers and adds are elementwise under batching,
# so per-spec lanes compute bit-identically to the unbatched kernel.
_eval_kernel_many = jax.jit(
    jax.vmap(B._eval_kernel, in_axes=(None, 0, 0, 0, 0)))


def scenario_specs() -> dict[str, MacroSpec]:
    """The paper's §I deployment scenarios as compiler inputs.

    One shared geometry (64x64, INT + FP4/FP8) with scenario-specific
    postures, so all four land in one vmap group:

      vision    edge camera pipelines — the Fig. 8 balanced spec.
      language  LLM decode — MCR=4 buys weight residency for big GEMMs.
      cloud     datacenter throughput — 1.1 GHz at nominal-high voltage.
      wearable  always-on low power — 250 MHz at 0.7 V.
    """
    return {
        "vision": MacroSpec(h=64, w=64, mcr=2, int_precisions=(4, 8),
                            fp_precisions=("FP4", "FP8"), f_mac_hz=800e6,
                            f_wupdate_hz=800e6, vdd=0.9),
        "language": MacroSpec(h=64, w=64, mcr=4, int_precisions=(4, 8),
                              fp_precisions=("FP4", "FP8"), f_mac_hz=800e6,
                              f_wupdate_hz=100e6, vdd=0.9),
        "cloud": MacroSpec(h=64, w=64, mcr=2, int_precisions=(4, 8),
                           fp_precisions=("FP4", "FP8"), f_mac_hz=1.1e9,
                           f_wupdate_hz=1.1e9, vdd=1.2),
        "wearable": MacroSpec(h=64, w=64, mcr=2, int_precisions=(2, 4),
                              fp_precisions=("FP4", "FP8"), f_mac_hz=250e6,
                              f_wupdate_hz=250e6, vdd=0.7),
    }


# ---------------------------------------------------------------------------
# Fused multi-spec evaluation
# ---------------------------------------------------------------------------


def _group_key(lattice: DesignLattice, tables: SpecTables):
    """Specs share a vmap group iff their lattices address identically and
    their mode axes have equal length (mode *names* may differ per spec)."""
    return (lattice.dims, lattice.splits, len(tables.modes))


def _pack_group(lattices: Sequence[DesignLattice],
                tables_list: Sequence[SpecTables]):
    """numpy-side operands for one vmapped group launch.

    Returns ``(csa_i, idx, operands)`` where ``idx`` is the shared gather
    tuple (one copy for the whole group) and ``operands`` stacks every
    per-spec kernel input along a leading spec axis.  The sharded engine
    (:mod:`repro.core.shardspec`) packs through this same helper and then
    pads/places the stacked axis across devices."""
    lat0, t0 = lattices[0], tables_list[0]
    csa_i = np.asarray(t0.csa_index(lat0.rho_i, lat0.ro, lat0.rt, lat0.sp_i))
    packed = [B._kernel_inputs(t) for t in tables_list]
    tabs_s = tuple(np.stack([p[0][j] for p in packed], dtype=np.float64)
                   for j in range(len(packed[0][0])))
    consts_s = np.stack([p[1] for p in packed], dtype=np.float64)
    e_ofu_s = np.stack([p[2] for p in packed], dtype=np.float64)
    e_align_s = np.stack([p[3] for p in packed], dtype=np.float64)
    idx = (lat0.mem_i, lat0.mm_i, csa_i, lat0.pipe_i, lat0.ort, lat0.fts,
           lat0.fso)
    return csa_i, idx, (tabs_s, consts_s, e_ofu_s, e_align_s)


def _unpack_group(lattices: Sequence[DesignLattice],
                  tables_list: Sequence[SpecTables], csa_i: np.ndarray,
                  out: dict) -> list[BatchedPPA]:
    """The shared single-spec numpy tail, applied per spec lane of one
    group's kernel outputs (bit-identity by construction)."""
    return [B._finish(lattices[s], tables_list[s], csa_i,
                      jax.tree.map(lambda a: a[s], out))
            for s in range(len(lattices))]


def _evaluate_group(lattices: Sequence[DesignLattice],
                    tables_list: Sequence[SpecTables]) -> list[BatchedPPA]:
    """One vmapped kernel launch for a group of same-shape specs, then the
    shared single-spec numpy tail per spec (bit-identity by construction)."""
    csa_i, idx_np, (tabs_s, consts_s, e_ofu_s, e_align_s) = \
        _pack_group(lattices, tables_list)
    with enable_x64():
        idx = tuple(jnp.asarray(a) for a in idx_np)
        out = _eval_kernel_many(idx, tuple(jnp.asarray(t) for t in tabs_s),
                                jnp.asarray(consts_s), jnp.asarray(e_ofu_s),
                                jnp.asarray(e_align_s))
        out = jax.tree.map(np.asarray, out)
    return _unpack_group(lattices, tables_list, csa_i, out)


def _grouped(specs: Sequence[MacroSpec], tech: TechModel,
             memcells: tuple[sc.MemCellKind, ...]
             ) -> tuple[list[DesignLattice], list[SpecTables],
                        dict[tuple, list[int]]]:
    """Characterize every spec and bucket them into vmap groups (shared with
    the sharded engine so both paths group identically)."""
    lattices = [DesignLattice.enumerate(s, tuple(memcells)) for s in specs]
    tables = [SpecTables(s, tech) for s in specs]
    groups: dict[tuple, list[int]] = {}
    for i, (lat, tab) in enumerate(zip(lattices, tables)):
        groups.setdefault(_group_key(lat, tab), []).append(i)
    return lattices, tables, groups


def evaluate_many(specs: Sequence[MacroSpec], tech: TechModel,
                  memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS
                  ) -> list[tuple[DesignLattice, SpecTables, BatchedPPA]]:
    """Evaluate every design point of every spec, batching same-shape specs
    through one vmapped kernel launch.  Results are returned in input order
    and are bit-identical per spec to :func:`repro.core.batched.evaluate`."""
    specs = list(specs)
    lattices, tables, groups = _grouped(specs, tech, memcells)
    out: list = [None] * len(specs)
    for members in groups.values():
        ppas = _evaluate_group([lattices[i] for i in members],
                               [tables[i] for i in members])
        for i, ppa in zip(members, ppas):
            out[i] = (lattices[i], tables[i], ppa)
    return out


# ---------------------------------------------------------------------------
# Multi-spec search + sweep entry points
# ---------------------------------------------------------------------------


def mso_search_many(specs: Sequence[MacroSpec], scl=None,
                    tech: TechModel = None,
                    resolution: int = 4) -> list[SearchResult]:
    """Synthesize N macro specs in one fused pass.

    Per-spec results (explored set, frontier, every PPA field) are
    bit-identical to looping ``mso_search(spec, backend="batched")`` — the
    vmapped kernel and shared roll-up tail compute the same float64
    arithmetic; only the dispatch is fused.  ``scl`` is accepted for
    signature parity with :func:`repro.core.searcher.mso_search`."""
    if tech is None:
        raise ValueError("tech model required")
    evals = evaluate_many(specs, tech, memcells=(sc.MemCellKind.SRAM_6T,))
    return [B._alg1_replay(lat, tab, T, resolution)
            for lat, tab, T in evals]


def design_space_sweep_many(specs: Sequence[MacroSpec], tech: TechModel,
                            memcells: tuple[sc.MemCellKind, ...] = B.MEMCELLS
                            ) -> list[BatchedSweep]:
    """Exhaustive sweeps for N specs in one fused pass (the multi-spec
    counterpart of :func:`repro.core.batched.design_space_sweep`)."""
    return [BatchedSweep(lattice=lat, tables=tab, ppa=T)
            for lat, tab, T in evaluate_many(specs, tech, memcells)]


def frontier_union(results: Iterable[SearchResult],
                   names: Sequence[str] | None = None):
    """Union of per-spec frontiers, deduplicated by (spec, design name) — the
    serving-time candidate pool for cross-workload co-design.  Points from
    different specs always stay distinct (a design name does not encode its
    spec's geometry or constraints).

    With ``names`` (one label per result), returns ``(pool, labels)`` where
    each pool entry is labeled ``"<name>/<design name>"`` by the first result
    that contributed it; without, returns the pool alone."""
    results = list(results)
    if names is not None and len(names) != len(results):
        raise ValueError("names must match results one-to-one")
    pool, labels, seen = [], [], set()
    for ri, res in enumerate(results):
        for p in res.frontier:
            key = (p.design.spec, p.design.name())
            if key not in seen:
                seen.add(key)
                pool.append(p)
                if names is not None:
                    labels.append(f"{names[ri]}/{p.design.name()}")
    return pool if names is None else (pool, labels)
