"""RTL / structural-netlist emission for synthesized macros (paper Fig. 2:
"Architecture RTL, subcircuit RTL and netlist").

Two outputs:
  * :func:`emit_verilog` — a Verilog-flavored, human-auditable RTL of the full
    macro: structural gate instances for the synthesized adder tree (the
    paper's custom subcircuit) and behavioral templates for the parameterized
    digital blocks (S&A, OFU, alignment), mirroring §III-B's split between
    custom cells and RTL templates.
  * :func:`tree_netlist` — the *executable* structural netlist consumed by
    :mod:`repro.core.gatesim` for functional verification.
"""

from __future__ import annotations

import textwrap

from .csa import TreeNetlist, build_netlist
from .macro import MacroDesign, MacroPPA


def tree_netlist(design: MacroDesign) -> TreeNetlist:
    h_eff = design.spec.h // max(1, design.csa.split)
    return build_netlist(design.csa, h_eff)


def _tree_instances(nl: TreeNetlist) -> str:
    lines = []
    for i, g in enumerate(nl.gates):
        if g.kind == "FA":
            lines.append(f"  FA u_fa{i} (.a({g.ins[0]}), .b({g.ins[1]}), "
                         f".ci({g.ins[2]}), .s({g.outs[0]}), .co({g.outs[1]}));")
        elif g.kind == "C42":
            lines.append(f"  CSA42 u_c42_{i} (.a({g.ins[0]}), .b({g.ins[1]}), "
                         f".c({g.ins[2]}), .d({g.ins[3]}), .cin({g.ins[4]}), "
                         f".s({g.outs[0]}), .carry({g.outs[1]}), .cout({g.outs[2]}));")
        elif g.kind == "RCA":
            ins = ", ".join(g.ins)
            lines.append(f"  RCA #(.W(ACC_W)) u_rca (.ops({{{ins}}}), .sum({g.outs[0]}));")
    return "\n".join(lines)


def emit_verilog(ppa: MacroPPA) -> str:
    d = ppa.design
    s = d.spec
    nl = tree_netlist(d)
    precisions = "_".join(str(p) for p in s.int_precisions)
    fp = "_".join(s.fp_precisions) if s.fp_precisions else "none"
    header = f"""\
    // ------------------------------------------------------------------
    // SynDCIM generated macro  —  {d.name()}
    // spec: H={s.h} W={s.w} MCR={s.mcr} INT={precisions} FP={fp}
    //       f_mac={s.f_mac_hz / 1e6:.0f}MHz @ {s.vdd:.2f}V
    // ppa : fmax={ppa.fmax_hz / 1e6:.0f}MHz area={ppa.area_um2 / 1e6:.4f}mm2
    //       latency={ppa.latency_cycles}cyc  TOPS(1b)={ppa.tops_1b:.2f}
    // audit:
    """
    audit = "\n".join(f"    //   - {a}" for a in d.audit) or "    //   (default)"
    body = f"""
    module dcim_macro #(
      parameter H = {s.h}, W = {s.w}, MCR = {s.mcr},
      parameter IB_MAX = {s.max_input_bits}, ACC_W = {ppa.csa_report.acc_width}
    ) (
      input  wire                clk, rst_n,
      input  wire [H-1:0]        in_bit,        // bit-serial activations
      input  wire [7:0]          in_mode,       // precision mode select
      input  wire                wl_we,         // weight-update strobe
      input  wire [$clog2(H*MCR)-1:0] wl_addr,
      input  wire [W-1:0]        bl_wdata,
      output wire [W*(ACC_W+IB_MAX)-1:0] macc_out,
      output wire                out_valid
    );

      // ---- memory array: {s.h}x{s.w} x MCR={s.mcr} {d.memcell.value} cells
      CELL_{d.memcell.value} u_array [H*MCR-1:0][W-1:0] (/* SDP-placed */);

      // ---- bitwise multiplier + multiplexer: {d.multmux.value}
      MULTMUX_{d.multmux.value.upper()} u_mult [H-1:0][W-1:0] (
        .in_bit(in_bit), .sel(/*mcr bank*/), .w(/*cell*/), .p(/*product*/));

      // ---- synthesized adder tree ({d.csa.name()}): one per column
      //      {len(nl.gates)} cells/column, {ppa.csa_report.stages} stages,
      //      retimed={d.csa.retimed}, reordered={d.csa.reorder}, split={d.csa.split}
{_tree_instances(nl)}

      // ---- shift & adder (bit-serial accumulation over IB_MAX cycles)
      always @(posedge clk) begin : shift_adder
        if (!rst_n) acc <= '0;
        else acc <= {{acc[ACC_W+IB_MAX-2:0], 1'b0}} + tree_sum; // shift-add
      end

      // ---- output fusion unit: {max(1, d.ofu_pipe_stages)} pipe stage(s),
      //      fuses column groups low->high precision
      OFU #(.W(W), .STAGES({d.ofu_pipe_stages})) u_ofu (
        .sa_out(acc), .mode(in_mode), .fused(macc_out), .valid(out_valid));

      // ---- FP/INT alignment unit ({fp})
      ALIGN #(.W(W)) u_align (.fp_in(/*...*/), .aligned(/*...*/));

    endmodule
    """
    return textwrap.dedent(header) + audit + "\n" + textwrap.dedent(body)
