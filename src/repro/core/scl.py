"""Subcircuit Library (SCL) with PPA lookup tables (paper §III-B, Fig. 3).

The paper characterizes each subcircuit topology over grids of dimensions and
timing constraints into PPA LUTs ("custom cell characterization flow" for
array cells, "parameterized RTL templates ... estimated and scaled from
synthesis data" for digital blocks).  This module reproduces that flow:

  * :meth:`SubcircuitLibrary.build` runs the characterization sweep once and
    stores PPA records keyed by (type, variant, dims, ...) — the LUT.
  * Queries hit the LUT when the key is on-grid and otherwise *scale* from the
    analytical model (the paper's own fallback for off-grid configurations).
  * ``query_adder_trees`` is the searcher's entry point for "check if faster
    adders are available in the SCL" (Alg. 1, tt1).
"""

from __future__ import annotations

from dataclasses import dataclass

from . import csa as csa_mod
from . import subcircuits as sc
from .tech import TechModel

DIM_GRID = (16, 32, 64, 128, 256, 512)
MCR_GRID = (1, 2, 4, 8)


@dataclass(frozen=True)
class LUTRecord:
    key: tuple
    delay_rel: float
    energy_rel: float
    area_um2: float
    latency_cycles: int
    meta: tuple = ()


class SubcircuitLibrary:
    """Characterized PPA LUTs for all seven subcircuit types."""

    def __init__(self, tech: TechModel):
        self.tech = tech
        self.lut: dict[tuple, LUTRecord] = {}
        self._built = False

    # ------------------------------------------------------------------ build
    def build(self) -> "SubcircuitLibrary":
        t = self.tech
        # Memory cells.
        for kind in sc.MemCellKind:
            p = sc.memcell_ppa(kind, t)
            self._put((sc.SC.MEMCELL, kind.value), p)
        # Multiplier + multiplexer variants x MCR.
        for kind in sc.MultMuxKind:
            for mcr in MCR_GRID:
                if not sc.multmux_valid(kind, mcr):
                    continue
                p = sc.multmux_ppa(kind, mcr, t)
                self._put((sc.SC.MULTMUX, kind.value, mcr), p)
        # Adder trees: full CSA family x row counts.
        for design in csa_mod.FAMILY:
            for h in DIM_GRID:
                p, rep = sc.adder_tree_ppa(design, h, 2, t)
                self._put((sc.SC.ADDER_TREE, design.name(), h), p,
                          meta=(design,))
        # Drivers.
        for h in DIM_GRID:
            for w in DIM_GRID:
                for mcr in MCR_GRID:
                    self._put((sc.SC.WLBL_DRIVER, "wl", h, w, mcr),
                              sc.wl_driver_ppa(h, w, mcr, t))
                    self._put((sc.SC.WLBL_DRIVER, "bl", h, w, mcr),
                              sc.bl_driver_ppa(h, w, mcr, t))
        # Shift & adder over accumulator widths x input bits.
        for acc_w in range(6, 22, 2):
            for ib in (1, 2, 4, 8, 16):
                self._put((sc.SC.SHIFT_ADDER, acc_w, ib),
                          sc.shift_adder_ppa(acc_w, ib, t))
        # OFU over widths x precision sets x pipeline stages.
        for w in DIM_GRID:
            for precs in ((1, 2, 4, 8), (4, 8), (8,), (2, 4), (1, 4, 8)):
                for ow in (12, 16, 20):
                    for ps in (0, 1, 2, 3):
                        self._put((sc.SC.OFU, w, precs, ow, ps),
                                  sc.ofu_ppa(w, precs, ow, ps, t))
        # Alignment units over width x FP format combos.
        combos = ((), ("FP4",), ("FP8",), ("BF16",), ("FP4", "FP8"),
                  ("FP8", "BF16"), ("FP4", "FP8", "BF16"))
        for w in DIM_GRID:
            for c in combos:
                self._put((sc.SC.ALIGN, w, c), sc.align_ppa(w, c, t))
        self._built = True
        return self

    def _put(self, key: tuple, p: sc.PPA, meta: tuple = ()) -> None:
        self.lut[key] = LUTRecord(key, p.delay_rel, p.energy_rel, p.area_um2,
                                  p.latency_cycles, meta or p.meta)

    # ---------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self.lut)

    def get(self, key: tuple) -> LUTRecord | None:
        return self.lut.get(key)

    def adder_tree(self, design: csa_mod.CSADesign, h: int) -> LUTRecord:
        """LUT hit when on-grid; otherwise scaled from the model (the paper's
        'estimated and scaled from synthesis data' path)."""
        rec = self.lut.get((sc.SC.ADDER_TREE, design.name(), h))
        if rec is not None:
            return rec
        p, rep = sc.adder_tree_ppa(design, h, 2, self.tech)
        return LUTRecord((sc.SC.ADDER_TREE, design.name(), h), p.delay_rel,
                         p.energy_rel, p.area_um2, p.latency_cycles, (design,))

    def query_adder_trees(self, h: int, max_delay_rel: float | None = None,
                          ) -> list[tuple[csa_mod.CSADesign, LUTRecord]]:
        """All tree designs for ``h`` rows meeting ``max_delay_rel``, sorted by
        energy (the searcher picks the most efficient one that meets timing)."""
        out = []
        for design in csa_mod.FAMILY:
            rec = self.adder_tree(design, h)
            if max_delay_rel is None or rec.delay_rel <= max_delay_rel:
                out.append((design, rec))
        out.sort(key=lambda dr: (dr[1].energy_rel, dr[1].area_um2))
        return out

    def fastest_adder_tree(self, h: int) -> tuple[csa_mod.CSADesign, LUTRecord]:
        best = None
        for design in csa_mod.FAMILY:
            rec = self.adder_tree(design, h)
            if best is None or rec.delay_rel < best[1].delay_rel:
                best = (design, rec)
        return best
