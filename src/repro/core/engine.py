"""Unified synthesis execution engine: one plan → place → execute → extract
pipeline shared by every way the compiler runs Algorithm 1's evaluation.

Before this layer existed the repo had four execution paths — the scalar
per-point hierarchy, the single-spec batched lattice (:mod:`repro.core.
batched`), the multi-spec vmapped pass (:mod:`repro.core.multispec`) and the
device-sharded pass (:mod:`repro.core.shardspec`) — and the last three each
re-implemented spec grouping, operand packing, lane padding, device placement
and the numpy frontier tail.  This module is the single owner of that
pipeline; the path modules are now thin strategies over it:

  plan      characterize specs (``DesignLattice`` + ``SpecTables``) and
            bucket them into vmap groups by lattice signature
            (:func:`group_key` / :func:`plan`);
  place     resolve an execution mode by capability probe (``hasattr``,
            never version pins) and bind it to a device mesh
            (:func:`place` / :class:`Placement`);
  execute   pack each group's operands (:func:`pack_group`), run the shared
            jitted float64 kernel under the placed strategy, and finish with
            the shared single-spec numpy tail (:func:`unpack_group`) —
            per-spec results are bit-identical across every strategy because
            the kernel is elementwise per spec lane (:func:`execute`);
  extract   the frontier tail: a survivor mask (host predicate, on-device
            chunked, or device-sharded map-reduce — all computing the same
            eps-band verdicts) followed by the exact dedup/order pass
            (:func:`extract_frontier`).

Execution strategies live in a registry (:data:`STRATEGIES`,
:func:`register_strategy`), so scaling further is a strategy registration,
not a fifth reimplementation of the pipeline:

  ``"jit"``          one spec, unbatched kernel launch (the
                     :mod:`repro.core.batched` path);
  ``"vmap"``         a fused same-shape group on one device
                     (:mod:`repro.core.multispec`);
  ``"sharded-jit"``  the vmapped group with its spec axis partitioned by a
                     ``Mesh``/``NamedSharding`` over a ``('spec',)`` mesh
                     (:mod:`repro.core.shardspec`'s preferred mode);
  ``"pmap"``         the vmapped group folded over a leading device axis —
                     the fallback for runtimes whose ``jax.sharding``
                     surface is incomplete;
  ``"multihost"``    the spec axis partitioned over a ``('host', 'spec')``
                     mesh — one mesh axis per host — registered by
                     :mod:`repro.core.multihost` (the ROADMAP's multi-host
                     next step, landed as exactly the promised
                     ``register_strategy`` call).

Execution is observable: :func:`add_execute_hook` registers a callback fired
once per :func:`execute` call with the plan being run.  The online synthesis
service (:mod:`repro.service`) counts engine executions through this hook to
prove cache hits and request coalescing never re-enter the engine.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import batched as B
from . import subcircuits as sc
from .batched import BatchedPPA, DesignLattice, SpecTables
from .macro import MacroSpec
# Chunk sizing lives with the shared Pareto predicate; re-exported here for
# strategies sizing frontier chunks against the accelerator budget.
from .pareto import pareto_chunk_size, pareto_indices  # noqa: F401
from .tech import TechModel

# ---------------------------------------------------------------------------
# Shared kernels: the single-spec kernel, vmapped / pmapped over a spec axis
# ---------------------------------------------------------------------------

# The single-spec kernel, vmapped over a leading spec axis: the gather-index
# tuple is shared (in_axes=None) while every table, constant and mode array
# carries one row per spec.  Gathers and adds are elementwise under batching,
# so per-spec lanes compute bit-identically to the unbatched kernel.
_eval_kernel_many = jax.jit(
    jax.vmap(B._eval_kernel, in_axes=(None, 0, 0, 0, 0)))

# The pmap fallback: the same vmapped kernel, mapped over a leading device
# axis.  Both maps are elementwise per spec lane so per-lane arithmetic is
# the unbatched kernel's, bit for bit.
_eval_kernel_pmap = jax.pmap(
    jax.vmap(B._eval_kernel, in_axes=(None, 0, 0, 0, 0)),
    in_axes=(None, 0, 0, 0, 0))


# ---------------------------------------------------------------------------
# Plan: spec grouping + operand packing
# ---------------------------------------------------------------------------


def group_key(lattice: DesignLattice, tables: SpecTables):
    """Specs share a vmap group iff their lattices address identically —
    same registered axes at the same sizes — and their mode axes have equal
    length (mode *names* may differ per spec).  Axis names participate so an
    extended lattice (precision / approx_cell axes enabled) can never fuse
    with a seed lattice that happens to share its flat shape."""
    return (tuple(a.name for a in lattice.axes), lattice.dims,
            lattice.splits, len(tables.modes))


@dataclass(frozen=True)
class PackedGroup:
    """numpy-side operands for one group launch: the shared gather tuple
    (one copy for the whole group) plus every per-spec kernel input stacked
    along a leading spec axis."""

    lattices: tuple[DesignLattice, ...]
    tables_list: tuple[SpecTables, ...]
    csa_i: np.ndarray
    ofu_j: np.ndarray
    idx: tuple[np.ndarray, ...]
    operands: tuple      # (tabs_s, consts_s, e_ofu_s, e_align_s)

    def __len__(self) -> int:
        return len(self.lattices)


def pack_group(lattices: Sequence[DesignLattice],
               tables_list: Sequence[SpecTables]) -> PackedGroup:
    """Pack one vmap group's kernel operands (every strategy — vmap, sharded
    jit, pmap, and the single-spec jit launch — executes from this one
    packing, so the paths cannot drift).  Gather indices come from the
    tables' axis-flattening helpers (``csa_index`` / ``ofu_index``), so an
    optional axis's coordinates reach the kernel as wider gathers into the
    flattened tables — never as new kernel code."""
    lat0, t0 = lattices[0], tables_list[0]
    for lat, tab in zip(lattices, tables_list):
        if not tab.compatible_with(lat):
            raise ValueError(
                f"tables built for axes {[(a.name, a.size) for a in tab.axes]}"
                f" cannot serve lattice axes "
                f"{[(a.name, a.size) for a in lat.axes]}")
    csa_i = np.asarray(t0.csa_index(lat0.rho_i, lat0.ro, lat0.rt, lat0.sp_i,
                                    lat0.apx_i))
    ofu_j = np.asarray(t0.ofu_index(lat0.pipe_i, lat0.prec_i))
    packed = [B._kernel_inputs(t) for t in tables_list]
    tabs_s = tuple(np.stack([p[0][j] for p in packed], dtype=np.float64)
                   for j in range(len(packed[0][0])))
    consts_s = np.stack([p[1] for p in packed], dtype=np.float64)
    e_ofu_s = np.stack([p[2] for p in packed], dtype=np.float64)
    e_align_s = np.stack([p[3] for p in packed], dtype=np.float64)
    idx = (lat0.mem_i, lat0.mm_i, csa_i, ofu_j, lat0.prec_i, lat0.ort,
           lat0.fts, lat0.fso)
    return PackedGroup(lattices=tuple(lattices),
                       tables_list=tuple(tables_list), csa_i=csa_i,
                       ofu_j=ofu_j, idx=idx,
                       operands=(tabs_s, consts_s, e_ofu_s, e_align_s))


def unpack_group(packed: PackedGroup, out: dict) -> list[BatchedPPA]:
    """The shared single-spec numpy tail, applied per spec lane of one
    group's kernel outputs (bit-identity by construction)."""
    return [B._finish(packed.lattices[s], packed.tables_list[s], packed.csa_i,
                      packed.ofu_j, jax.tree.map(lambda a: a[s], out))
            for s in range(len(packed))]


def pad_lanes(arr: np.ndarray, pad: int) -> np.ndarray:
    """Pad the leading spec axis with copies of lane 0 (cheap, NaN-free
    filler — padded lanes are computed and discarded, never compared)."""
    if pad == 0:
        return arr
    return np.concatenate([arr, np.repeat(arr[:1], pad, axis=0)], axis=0)


# ---------------------------------------------------------------------------
# Place: capability-probed mode dispatch + strategy registry
# ---------------------------------------------------------------------------


def _supports_named_sharding() -> bool:
    """Capability probe for the NamedSharding execution path (hasattr, not a
    version pin — the same detection style the distributed tests use)."""
    return (hasattr(jax, "sharding")
            and hasattr(jax.sharding, "Mesh")
            and hasattr(jax.sharding, "NamedSharding")
            and hasattr(jax.sharding, "PartitionSpec")
            and hasattr(jax, "device_put"))


@dataclass(frozen=True)
class Placement:
    """A resolved execution mode bound to its devices."""

    mode: str
    mesh: Any = None
    n_dev: int = 1


@dataclass(frozen=True)
class Strategy:
    """One way to run a packed group: ``run(packed, placement)`` returns the
    kernel outputs as host numpy with a leading spec axis of exactly
    ``len(packed)`` lanes.  ``default_mesh`` (when set) builds the mesh
    :func:`place` binds when the caller passes none — each sharded strategy
    owns its placement geometry instead of :func:`place` special-casing
    names."""

    name: str
    available: Callable[[], bool]
    run: Callable[[PackedGroup, Placement], dict]
    sharded: bool = False
    default_mesh: Callable[[], Any] | None = None


#: The capability-probed strategy registry — scaling the engine further
#: (multi-host meshes, new runtimes) is a :func:`register_strategy` call,
#: not another execution-path module.
STRATEGIES: dict[str, Strategy] = {}


def register_strategy(strategy: Strategy) -> Strategy:
    STRATEGIES[strategy.name] = strategy
    return strategy


#: Public mode names of the device-sharded surface (shardspec + sharded
#: Pareto extraction): "jit" = NamedSharding placement, "pmap" = the
#: fallback, "multihost" = the ('host', 'spec') mesh strategy.
SHARDED_MODES = ("auto", "jit", "pmap", "multihost")

#: Public sharded mode -> engine strategy name.
_SHARDED_STRATEGY = {"jit": "sharded-jit", "pmap": "pmap",
                     "multihost": "multihost"}


def resolve_sharded_mode(mode: str = "auto") -> str:
    """'auto' picks NamedSharding+jit when the runtime has it, else pmap.
    This is the one capability-probed dispatcher every sharded surface
    (spec sweeps and Pareto extraction) resolves through.  "multihost" falls
    back to the single-host auto pick when the multi-host strategy is
    unavailable on this runtime — the fallback contract of the ROADMAP's
    multi-host registration."""
    if mode not in SHARDED_MODES:
        raise ValueError(f"unknown shardspec mode: {mode!r}; "
                         f"pick from {SHARDED_MODES}")
    if mode == "auto":
        return "jit" if STRATEGIES["sharded-jit"].available() else "pmap"
    if mode == "multihost" and not STRATEGIES["multihost"].available():
        return resolve_sharded_mode("auto")
    return mode


def place(mode: str = "auto", mesh=None, *, sharded: bool = False
          ) -> Placement:
    """Resolve an execution mode and bind it to devices.

    ``mode`` is an engine strategy name or ``"auto"``; ``sharded=True`` makes
    "auto" resolve across devices (NamedSharding-jit when the runtime has it,
    else pmap) instead of to the single-device vmap strategy.  A sharded
    strategy with no caller-provided mesh is bound to its own
    ``default_mesh`` (a ``('spec',)`` mesh over every visible device for
    "sharded-jit", a ``('host', 'spec')`` mesh for "multihost"); the pmap
    strategy needs nothing from ``jax.sharding``."""
    if mode == "auto":
        mode = (_SHARDED_STRATEGY[resolve_sharded_mode("auto")] if sharded
                else "vmap")
    if mode not in STRATEGIES:
        raise ValueError(f"unknown engine mode: {mode!r}; "
                         f"pick from {sorted(STRATEGIES)}")
    if not STRATEGIES[mode].available():
        raise ValueError(f"engine mode {mode!r} is not available "
                         "on this runtime")
    if mesh is None and STRATEGIES[mode].default_mesh is not None:
        mesh = STRATEGIES[mode].default_mesh()
    if mesh is not None:
        n_dev = int(mesh.devices.size)
    elif STRATEGIES[mode].sharded:
        n_dev = len(jax.devices())
    else:
        n_dev = 1
    return Placement(mode=mode, mesh=mesh, n_dev=n_dev)


# ---------------------------------------------------------------------------
# Execute: the registered strategies
# ---------------------------------------------------------------------------


def _run_jit(packed: PackedGroup, placement: Placement) -> dict:
    """Single-spec unbatched launch — the :mod:`repro.core.batched` path."""
    if len(packed) != 1:
        raise ValueError("the 'jit' strategy runs exactly one spec; "
                         "use 'vmap' or a sharded mode for groups")
    tabs_s, consts_s, e_ofu_s, e_align_s = packed.operands
    with enable_x64():
        idx = tuple(jnp.asarray(a) for a in packed.idx)
        out = B._eval_kernel(idx, tuple(jnp.asarray(t[0]) for t in tabs_s),
                             jnp.asarray(consts_s[0]),
                             jnp.asarray(e_ofu_s[0]),
                             jnp.asarray(e_align_s[0]))
        out = jax.tree.map(np.asarray, out)
    return jax.tree.map(lambda a: a[None], out)


def _run_vmap(packed: PackedGroup, placement: Placement) -> dict:
    """One vmapped kernel launch for a group of same-shape specs."""
    tabs_s, consts_s, e_ofu_s, e_align_s = packed.operands
    with enable_x64():
        idx = tuple(jnp.asarray(a) for a in packed.idx)
        out = _eval_kernel_many(idx, tuple(jnp.asarray(t) for t in tabs_s),
                                jnp.asarray(consts_s), jnp.asarray(e_ofu_s),
                                jnp.asarray(e_align_s))
        out = jax.tree.map(np.asarray, out)
    return out


def _padded_operands(packed: PackedGroup, n_dev: int):
    """Pad the ragged spec count of a packed group up to the device count."""
    tabs_s, consts_s, e_ofu_s, e_align_s = packed.operands
    pad = (-len(packed)) % n_dev
    return (pad, tuple(pad_lanes(t, pad) for t in tabs_s),
            pad_lanes(consts_s, pad), pad_lanes(e_ofu_s, pad),
            pad_lanes(e_align_s, pad))


def _run_sharded_jit(packed: PackedGroup, placement: Placement) -> dict:
    """The vmapped kernel with its spec axis partitioned by Mesh/NamedSharding
    over a ``('spec',)`` mesh — the kernel is elementwise per spec lane, so
    partitioning the lane axis cannot change per-lane float64 arithmetic."""
    from jax.sharding import NamedSharding

    from ..parallel.sharding import logical_to_spec, rules_for_mesh
    mesh = placement.mesh
    if mesh is None:
        raise ValueError("the 'sharded-jit' strategy needs a mesh "
                         "(use engine.place to resolve one)")
    pad, tabs_p, consts_p, e_ofu_p, e_align_p = \
        _padded_operands(packed, placement.n_dev)
    rules = rules_for_mesh(mesh)

    with enable_x64():
        def put(a, leading_spec: bool):
            axes = (("spec",) if leading_spec else (None,)) \
                + (None,) * (np.ndim(a) - 1)
            sharding = NamedSharding(mesh, logical_to_spec(axes, rules))
            return jax.device_put(jnp.asarray(a), sharding)

        idx = tuple(put(a, False) for a in packed.idx)
        out = _eval_kernel_many(idx, tuple(put(t, True) for t in tabs_p),
                                put(consts_p, True), put(e_ofu_p, True),
                                put(e_align_p, True))
        out = jax.tree.map(np.asarray, out)
    if pad:
        out = jax.tree.map(lambda a: a[:len(packed)], out)
    return out


def _run_pmap(packed: PackedGroup, placement: Placement) -> dict:
    """The vmapped kernel folded over a leading device axis — the fallback
    for runtimes whose ``jax.sharding`` surface is incomplete."""
    n_dev = placement.n_dev
    pad, tabs_p, consts_p, e_ofu_p, e_align_p = \
        _padded_operands(packed, n_dev)
    per_dev = (len(packed) + pad) // n_dev

    def fold(a):
        a = np.asarray(a)
        return a.reshape((n_dev, per_dev) + a.shape[1:])

    with enable_x64():
        idx = tuple(jnp.asarray(a) for a in packed.idx)
        out = _eval_kernel_pmap(idx, tuple(fold(t) for t in tabs_p),
                                fold(consts_p), fold(e_ofu_p),
                                fold(e_align_p))
        # unfold (devices, specs/device) -> specs on the host copy: a numpy
        # view, and no further jax dispatch on this branch
        out = jax.tree.map(
            lambda a: np.asarray(a).reshape((n_dev * per_dev,) + a.shape[2:]),
            out)
    if pad:
        out = jax.tree.map(lambda a: a[:len(packed)], out)
    return out


def _spec_sweep_mesh():
    from ..parallel.sharding import spec_sweep_mesh
    return spec_sweep_mesh()


register_strategy(Strategy("jit", lambda: True, _run_jit))
register_strategy(Strategy("vmap", lambda: hasattr(jax, "vmap"), _run_vmap))
register_strategy(Strategy("sharded-jit", _supports_named_sharding,
                           _run_sharded_jit, sharded=True,
                           default_mesh=_spec_sweep_mesh))
register_strategy(Strategy("pmap", lambda: hasattr(jax, "pmap"), _run_pmap,
                           sharded=True))


# ---------------------------------------------------------------------------
# The plan object + end-to-end execution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecutionPlan:
    """A placed evaluation of N specs: characterized lattices/tables, the
    vmap grouping, and the resolved device placement."""

    lattices: tuple[DesignLattice, ...]
    tables: tuple[SpecTables, ...]
    groups: tuple[tuple[int, ...], ...]
    placement: Placement

    def __len__(self) -> int:
        return len(self.lattices)


def plan_for(lattices: Sequence[DesignLattice],
             tables: Sequence[SpecTables], mode: str = "auto", mesh=None,
             sharded: bool = False,
             placement: Placement | None = None) -> ExecutionPlan:
    """Group already-characterized specs into an :class:`ExecutionPlan`.
    An already-resolved ``placement`` skips the :func:`place` call (callers
    that time planning and placement as separate phases resolve it first)."""
    groups: dict[tuple, list[int]] = {}
    for i, (lat, tab) in enumerate(zip(lattices, tables)):
        groups.setdefault(group_key(lat, tab), []).append(i)
    if placement is None:
        placement = place(mode, mesh, sharded=sharded)
    return ExecutionPlan(lattices=tuple(lattices), tables=tuple(tables),
                         groups=tuple(tuple(m) for m in groups.values()),
                         placement=placement)


def plan(specs: Sequence[MacroSpec], tech: TechModel,
         memcells: tuple[sc.MemCellKind, ...] | None = None,
         mode: str = "auto", mesh=None, sharded: bool = False,
         config: "B.LatticeConfig | None" = None) -> ExecutionPlan:
    """Characterize every spec and bucket them into vmap groups — the one
    grouping every execution path shares, so all paths group identically.
    ``config`` selects the lattice axis set (seed when None); ``memcells``
    overrides its memcell axis (the historical argument)."""
    if config is None:
        config = B.seed_config(memcells)
    elif memcells is not None:
        config = config.with_memcells(memcells)
    lattices = [DesignLattice.enumerate(s, config=config) for s in specs]
    tables = [SpecTables(s, tech, config=config) for s in specs]
    return plan_for(lattices, tables, mode=mode, mesh=mesh, sharded=sharded)


#: Observers fired once per :func:`execute` call with the plan being run —
#: the instrumentation point the synthesis service and its tests use to
#: count engine entries (a cache hit or coalesced duplicate must cause
#: zero of them).
_EXECUTE_HOOKS: list[Callable[[ExecutionPlan], None]] = []


def add_execute_hook(hook: Callable[[ExecutionPlan], None]
                     ) -> Callable[[ExecutionPlan], None]:
    """Register an observer called with every :class:`ExecutionPlan` the
    engine runs.  Returns ``hook`` so it can be used as a decorator."""
    _EXECUTE_HOOKS.append(hook)
    return hook


def remove_execute_hook(hook: Callable[[ExecutionPlan], None]) -> None:
    _EXECUTE_HOOKS.remove(hook)


#: Observers fired once per :func:`execute` call with the plan and the
#: wall-clock seconds the pass took — the feedback signal the async serving
#: front's batching window adapts to (a slow engine grows batches instead
#: of queues).
_LATENCY_HOOKS: list[Callable[[ExecutionPlan, float], None]] = []


def add_latency_hook(hook: Callable[[ExecutionPlan, float], None]
                     ) -> Callable[[ExecutionPlan, float], None]:
    """Register an observer called with ``(plan, elapsed_s)`` after every
    :func:`execute` pass completes.  Returns ``hook`` so it can be used as
    a decorator."""
    _LATENCY_HOOKS.append(hook)
    return hook


def remove_latency_hook(hook: Callable[[ExecutionPlan, float], None]) -> None:
    _LATENCY_HOOKS.remove(hook)


def execute(p: ExecutionPlan
            ) -> list[tuple[DesignLattice, SpecTables, BatchedPPA]]:
    """Run every group of the plan under its placed strategy and finish with
    the shared numpy tail.  Results are returned in input order and are
    bit-identical per spec across every strategy."""
    for hook in tuple(_EXECUTE_HOOKS):
        hook(p)
    t0 = time.perf_counter()
    strategy = STRATEGIES[p.placement.mode]
    out: list = [None] * len(p)
    for members in p.groups:
        packed = pack_group([p.lattices[i] for i in members],
                            [p.tables[i] for i in members])
        ppas = unpack_group(packed, strategy.run(packed, p.placement))
        for i, ppa in zip(members, ppas):
            out[i] = (p.lattices[i], p.tables[i], ppa)
    elapsed = time.perf_counter() - t0
    for hook in tuple(_LATENCY_HOOKS):
        hook(p, elapsed)
    return out


# ---------------------------------------------------------------------------
# Extract: the shared frontier tail
# ---------------------------------------------------------------------------


def extract_frontier(objs, mask_fn: Callable[[np.ndarray], np.ndarray]
                     ) -> list[int]:
    """The numpy frontier tail every sweep shares: a survivor mask from
    ``mask_fn`` (host :func:`repro.core.pareto.nondominated_mask`, the
    on-device chunked :func:`repro.core.batched.pareto_mask`, or the
    device-sharded :func:`repro.core.pareto.nondominated_mask_sharded` — all
    bit-identical by construction), then the exact dedup/order pass of
    :func:`repro.core.pareto.pareto_indices` on the survivors.  Returns
    indices into ``objs`` sorted by objective tuple."""
    objs = np.asarray(objs, dtype=np.float64)
    mask = np.asarray(mask_fn(objs)).astype(bool)
    survivors = np.flatnonzero(mask)
    order = pareto_indices([tuple(o) for o in objs[mask]])
    return [int(survivors[i]) for i in order]


# The multi-host strategy registers itself against this module's registry;
# importing it last keeps the registration a plain `register_strategy` call
# (the ROADMAP contract) without a circular-import dance.
from . import multihost as _multihost  # noqa: E402,F401
