"""Functional gate-level simulation of synthesized adder-tree netlists.

The paper validates generated macros through post-synthesis gate-level
simulation (§III-D).  This module plays that role: it *executes* the
structural netlists emitted by :func:`repro.core.csa.build_netlist` on numpy
integer tensors using exact carry-save algebra:

  FA  : (a, b, c)          -> sum = a ^ b ^ c, carry = majority(a,b,c) << 1
  C42 : (a, b, c, d, cin)  -> two chained FAs — the paper's "4-2 compressor as
                              a 5-3 carry-save adder" construction (Fig. 4)
  RCA : final ripple-carry -> exact integer addition

Carry-save invariant: every gate preserves Σ(outputs) == Σ(inputs), so the
tree's final output must equal the integer sum of its operand lanes.  Tests
(and the macro functional-verification step) assert exactly that against
arbitrary signed operands.
"""

from __future__ import annotations

import numpy as np

from .csa import TreeNetlist


def _fa(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    s = a ^ b ^ c
    carry = ((a & b) | (b & c) | (a & c)) << 1
    return s, carry


def _c42(a, b, c, d, cin) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    # First FA compresses (a, b, c); its carry is the stage cout (chained to
    # the neighbor compressor); second FA compresses (s1, d, cin).
    s1, cout = _fa(a, b, c)
    s, carry = _fa(s1, d, cin)
    return s, carry, cout


def simulate(nl: TreeNetlist, operands: np.ndarray) -> np.ndarray:
    """Evaluate the netlist on ``operands`` of shape (n_inputs, ...) int64.

    Returns the tree output (shape ``operands.shape[1:]``).
    """
    if operands.shape[0] != nl.n_inputs:
        raise ValueError(f"netlist expects {nl.n_inputs} operand lanes, "
                         f"got {operands.shape[0]}")
    operands = operands.astype(np.int64)
    wires: dict[str, np.ndarray] = {"zero": np.zeros(operands.shape[1:], np.int64)}
    for i in range(nl.n_inputs):
        wires[f"in{i}"] = operands[i]
    for g in nl.gates:
        ins = [wires[w] for w in g.ins]
        if g.kind == "FA":
            s, c = _fa(*ins)
            wires[g.outs[0]], wires[g.outs[1]] = s, c
        elif g.kind == "C42":
            s, c, co = _c42(*ins)
            wires[g.outs[0]], wires[g.outs[1]], wires[g.outs[2]] = s, c, co
        elif g.kind == "RCA":
            wires[g.outs[0]] = sum(ins)
        else:  # pragma: no cover - defensive
            raise ValueError(f"unknown gate kind {g.kind}")
    return wires[nl.outputs[0]]


def verify_tree(nl: TreeNetlist, operands: np.ndarray) -> bool:
    """Carry-save invariant check: netlist output == integer sum of lanes."""
    out = simulate(nl, operands)
    ref = operands.astype(np.int64).sum(axis=0)
    return bool(np.array_equal(out, ref))
